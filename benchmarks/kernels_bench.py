"""Micro-benchmarks for the Pallas kernels (interpret mode on CPU: these
numbers validate plumbing, not TPU throughput -- the roofline table is the
TPU performance story) plus the pure-jnp reference timings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash import flash_attention
from repro.kernels.linattn import rwkv_linattn
from repro.kernels.sdca import sdca_epoch
from repro.kernels.svrg import svrg_inner

from .common import emit_csv_row, save_result, timed


def main(argv=None):
    rng = np.random.default_rng(0)
    out = {}

    n_p, m_q, steps = 256, 256, 256
    x = jnp.asarray(rng.normal(size=(n_p, m_q)), jnp.float32)
    y = jnp.asarray(np.sign(rng.normal(size=n_p)), jnp.float32)
    mask = jnp.ones((n_p,))
    a0 = jnp.zeros((n_p,))
    w0 = jnp.zeros((m_q,))
    idx = jnp.asarray(rng.integers(0, n_p, steps), jnp.int32)
    # pallas runs in interpret mode on CPU -- the number tracks kernel
    # plumbing cost over time, not TPU throughput (see module docstring)
    for backend in ("ref", "pallas"):
        t = timed(lambda: sdca_epoch(x, y, mask, a0, w0, idx, lam=0.1,
                                     n=1000, Q=2, backend=backend))
        emit_csv_row(f"kernels/sdca_{backend}", t * 1e6,
                     f"rows={n_p};feat={m_q};steps={steps}")
        out[f"sdca_{backend}_us"] = t * 1e6

    wa = jnp.zeros((m_q,))
    za = jnp.zeros((n_p,))
    for backend in ("ref", "pallas"):
        t = timed(lambda: svrg_inner(x, y, mask, za, wa, jnp.zeros((m_q,)),
                                     idx, lam=0.1, eta=0.01, backend=backend))
        emit_csv_row(f"kernels/svrg_{backend}", t * 1e6, f"L={steps}")
        out[f"svrg_{backend}_us"] = t * 1e6

    B, S, H, KV, D = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
    t = timed(lambda: flash_attention(q, k, v, backend="ref"))
    emit_csv_row("kernels/flash_ref", t * 1e6, f"S={S}")
    out["flash_ref_us"] = t * 1e6

    r = jnp.asarray(rng.normal(size=(4, 256, 64)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(4, 256, 64)), jnp.float32))
    u = jnp.ones((64,))
    t = timed(lambda: rwkv_linattn(r, r, r, logw, u, backend="ref"))
    emit_csv_row("kernels/linattn_ref", t * 1e6, "S=256")
    out["linattn_ref_us"] = t * 1e6

    save_result("kernels", out)


if __name__ == "__main__":
    main()
