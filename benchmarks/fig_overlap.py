"""Communication-overlap sweep: exposed-vs-hidden wire time per tau.

Runs every solver under ``engine="overlap"`` across a staleness grid on
the core-benchmark instance and lands the rows in ``BENCH_core.json``:

  * one cell per (solver, tau):
    ``{solver}/overlap/{backend}/tau{tau}`` with s_per_iter, final
    rel_opt, and the overlap-aware phase split (``comm_exposed_s`` /
    ``comm_hidden_s`` next to ``local_s`` / ``comm_s``);
  * topology cells ``{solver}/overlap/{backend}/tau{tau}/{topo}`` for
    each ``--topologies`` entry (hierarchical intra/inter-pod bytes);
  * an ``overlap_sweep`` block: convergence curves per tau, the
    matched async-engine comparison (same tau, no overlap), and the
    alpha-beta wire-time model fitted on this sweep's own measured
    ``comm_s`` (``fit_link``) with per-cell predicted seconds and
    relative error -- predicted-vs-measured is the figure's payload.

tau = 0 is asserted to reproduce the sync shard_map engine exactly
(max-abs iterate diff == 0); at tau >= 1 the overlap engine's iterates
equal the async engine's (same consumption contract), which is also
asserted.

    PYTHONPATH=src python -m benchmarks.fig_overlap [--quick] \\
        [--taus 0,1,2,4] [--solvers d3ca,radisa,admm] \\
        [--topologies pods=2:int8]

Forces a fake 8-device host platform before jax init (the overlap
engine is a mesh engine).  The payload carries the standard provenance
stamp (git_sha / date / quick).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np  # noqa: E402

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.core.comm_model import Topology  # noqa: E402
from repro.data import make_svm_data  # noqa: E402
from repro.obs import Registry  # noqa: E402

try:
    from .common import (annotate_wire_predictions, emit_csv_row,
                         phase_fields, provenance, timed)
except ImportError:                     # `python benchmarks/fig_overlap.py`
    from common import (annotate_wire_predictions, emit_csv_row,
                        phase_fields, provenance, timed)


def _topo_slug(spec: str) -> str:
    return spec.replace("pods=", "pods").replace(":", "-")


def run_cell(name, cfg, X, y, P, Q, engine, tau, backend, f_star, reps,
             topology=None):
    """One timed solve.  Returns (entry, res)."""
    solver = get_solver(name)(engine=engine, staleness=tau,
                              local_backend=backend, topology=topology)
    prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
    state = prog.step(1, prog.state)              # compile + warm
    if getattr(prog, "donated", False):
        t = None                  # donation invalidates the saved state
    else:
        t = timed(lambda: prog.step(2, state), reps=reps, warmup=0)
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       registry=Registry())
    entry = {"rel_opt": res.history[-1]["rel_opt"],
             "iters": res.iters, "staleness": tau, "engine": engine}
    entry.update(phase_fields(res.history))
    if t is None:
        t = entry.get("step_s", 0.0)
    entry["s_per_iter"] = t
    acct = res.comm_bytes
    entry["comm_bytes_per_step"] = acct["bytes_per_step"]
    for tier in ("intra_bytes_per_step", "inter_bytes_per_step"):
        if tier in acct:
            entry[tier] = acct[tier]
    if topology is not None:
        entry["topology"] = res.topology
    return entry, res


def sweep_solver(name, cfg, X, y, P, Q, taus, backend, f_star, reps,
                 topologies):
    """One solver across the staleness grid under overlap + async.
    Returns (cells, curves, samples) where samples feed fit_link."""
    sync = get_solver(name)(engine="shard_map", local_backend=backend)
    w_sync = sync.solve("hinge", X, y, P=P, Q=Q, cfg=cfg,
                        record_history=False).w
    sizes = {"data": P, "model": Q}
    cells, curves, samples = {}, {}, []
    for tau in taus:
        entry, res = run_cell(name, cfg, X, y, P, Q, "overlap", tau,
                              backend, f_star, reps)
        # the engine contracts: tau = 0 IS the sync engine, and the
        # overlap engine consumes reductions exactly like the async one
        w_async = get_solver(name)(
            engine="async", staleness=tau, local_backend=backend).solve(
            "hinge", X, y, P=P, Q=Q, cfg=cfg, record_history=False).w
        diff_async = float(np.abs(np.asarray(res.w)
                                  - np.asarray(w_async)).max())
        entry["max_abs_diff_vs_async"] = diff_async
        assert diff_async == 0.0, (
            f"{name}: overlap(tau={tau}) diverged from async(tau={tau}) "
            f"by {diff_async:.3e}")
        if tau == 0:
            diff = float(np.abs(np.asarray(res.w)
                                - np.asarray(w_sync)).max())
            entry["max_abs_diff_vs_sync"] = diff
            assert diff == 0.0, (
                f"{name}: overlap(staleness=0) diverged from shard_map "
                f"by {diff:.3e}")
        else:
            # the tentpole's win: the async engine pays the same wire
            # but exposes all of it; overlap hides up to tau*local_s
            a_entry, _ = run_cell(name, cfg, X, y, P, Q, "async", tau,
                                  backend, f_star, reps)
            step_s = entry.get("step_s")
            a_step = a_entry.get("step_s")
            if step_s and a_step:
                entry["exposed_share"] = (entry.get("comm_exposed_s", 0.0)
                                          / step_s)
                entry["async_comm_share"] = (a_entry.get("comm_s", 0.0)
                                             / a_step)
        if "comm_s" in entry:
            samples.append((res.comm_bytes, sizes, entry["comm_s"],
                            f"{name}/overlap/{backend}/tau{tau}", None))
        cells[f"{name}/overlap/{backend}/tau{tau}"] = entry
        curves[str(tau)] = [h["rel_opt"] for h in res.history]
        emit_csv_row(f"fig_overlap/{name}/tau{tau}",
                     entry["s_per_iter"] * 1e6,
                     f"rel_opt={entry['rel_opt']:.4f}")
        for topo in topologies:
            tau_t = tau if tau else max(taus)
            if tau != tau_t:
                continue          # one topology row per solver, max tau
            t_entry, t_res = run_cell(name, cfg, X, y, P, Q, "overlap",
                                      tau, backend, f_star, reps,
                                      topology=topo)
            key = f"{name}/overlap/{backend}/tau{tau}/{_topo_slug(topo)}"
            if "comm_s" in t_entry:
                samples.append((t_res.comm_bytes, sizes, t_entry["comm_s"],
                                key, Topology.from_spec(topo)))
            cells[key] = t_entry
            emit_csv_row(f"fig_overlap/{name}/tau{tau}/{_topo_slug(topo)}",
                         t_entry["s_per_iter"] * 1e6,
                         f"rel_opt={t_entry['rel_opt']:.4f}")
    return cells, curves, samples


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--taus", default="0,1,2,4",
                    help="comma-separated staleness grid")
    ap.add_argument("--solvers", default="d3ca,radisa,admm")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--topologies", default="pods=2:int8",
                    help="comma-separated hierarchical topology specs "
                         "(empty string skips the topology cells)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    taus = [int(t) for t in args.taus.split(",") if t != ""]
    bad = [t for t in taus if t < 0]
    if bad:
        ap.error(f"--taus contains negative staleness values {bad}; "
                 "tau must be >= 0")
    topologies = [t.strip() for t in args.topologies.split(",") if t.strip()]

    P, Q = 4, 2
    n, m = (256, 96) if args.quick else (768, 256)
    inner = 32 if args.quick else 96
    iters = 6 if args.quick else 12
    lam = 1e-1
    X, y = make_svm_data(n, m, seed=0)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=100)
    f_star = float(objective("hinge", X, y, w_ref, lam))

    configs = {
        "d3ca": D3CAConfig(lam=lam, outer_iters=iters, local_steps=inner),
        "radisa": RADiSAConfig(lam=lam, gamma=0.05, outer_iters=iters,
                               L=inner),
        "admm": ADMMConfig(lam=lam, rho=lam, outer_iters=iters),
    }

    if os.path.exists(args.out):
        with open(args.out) as fh:
            payload = json.load(fh)
    else:
        payload = {"cells": {}, "ratios": {}}
    payload.setdefault("cells", {})
    payload["overlap_sweep"] = {"taus": taus, "n": n, "m": m, "P": P,
                                "Q": Q, "lam": lam, "iters": iters,
                                "backend": args.backend,
                                "topologies": topologies, "curves": {}}
    payload["provenance"] = provenance(args.quick)

    all_samples = []
    for name in args.solvers.split(","):
        cells, curves, samples = sweep_solver(
            name, configs[name], X, y, P, Q, taus, args.backend, f_star,
            args.reps, topologies)
        payload["cells"].update(cells)
        payload["overlap_sweep"]["curves"][name] = curves
        all_samples.extend(samples)

    if all_samples:
        payload["overlap_sweep"]["wire_model"] = annotate_wire_predictions(
            payload["cells"], all_samples)

    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[fig_overlap] wrote {args.out} "
          f"({len(taus)} taus x {len(args.solvers.split(','))} solvers)")
    return payload


if __name__ == "__main__":
    main()
