"""Core solver benchmark: outer-step throughput of the unified solver API
under every (engine, local_backend) pair.

Forces a fake 8-device host platform (before jax init) so the shard_map
engine runs its real collectives on CPU.  On CPU the pallas backend runs
in interpret mode -- those numbers validate plumbing and track the perf
trajectory, not TPU throughput (the dry-run/roofline path is the TPU
performance story).

    PYTHONPATH=src python -m benchmarks.core_bench [--quick]

Emits ``BENCH_core.json`` (repo root by default): seconds per outer
iteration per (solver, engine, backend[, sparse]) cell plus the
headline ratios -- ref vs pallas per engine, simulated vs shard_map per
backend, and sparse vs dense per (engine, backend) on the low-density
instance.  The payload carries a provenance stamp (git_sha / date /
quick) that ``benchmarks.check_regression`` requires before gating.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

from repro.core import (D3CAConfig, RADiSAConfig, ADMMConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_sparse_svm_data, make_svm_data  # noqa: E402

try:
    from .common import emit_csv_row, provenance, timed
except ImportError:                       # `python benchmarks/core_bench.py`
    from common import emit_csv_row, provenance, timed


def bench_combo(name, cfg, X, y, P, Q, engine, backend, f_star, reps,
                block_format="dense", compression=None):
    solver = get_solver(name)(engine=engine, local_backend=backend,
                              block_format=block_format,
                              compression=compression)
    prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
    state = prog.step(1, prog.state)          # compile + warm
    t = timed(lambda: prog.step(2, state), reps=reps, warmup=0)
    # a short solve for a correctness anchor on the same combo
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       record_history=True)
    return {"s_per_iter": t, "rel_opt": res.history[-1]["rel_opt"],
            "iters": res.iters,
            "comm_bytes_per_step": res.comm_bytes["bytes_per_step"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    P, Q = 4, 2
    n, m = (256, 96) if args.quick else (768, 256)
    inner = 32 if args.quick else 96
    iters = 3 if args.quick else 5
    density = 0.05
    X, y = make_svm_data(n, m, seed=0)
    # the sparse grid runs on a low-density instance (weak-scaling
    # regime); dense np array in, partitioned into ELL cells by the
    # block_format knob
    Xs, ys = make_sparse_svm_data(n, m, density=density, seed=0)
    lam = 1e-1
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=100)
    f_star = float(objective("hinge", X, y, w_ref, lam))
    ws_ref, _ = serial_sdca("hinge", Xs, ys, lam=lam, epochs=100)
    fs_star = float(objective("hinge", Xs, ys, ws_ref, lam))

    configs = {
        "d3ca": D3CAConfig(lam=lam, outer_iters=iters, local_steps=inner),
        "radisa": RADiSAConfig(lam=lam, gamma=0.05, outer_iters=iters,
                               L=inner),
        "admm": ADMMConfig(lam=lam, rho=lam, outer_iters=iters),
    }
    out = {"n": n, "m": m, "P": P, "Q": Q, "lam": lam, "inner": inner,
           "sparse_density": density,
           "note": "pallas numbers are interpret-mode on CPU unless run "
                   "on a TPU host",
           "provenance": provenance(args.quick),
           "cells": {}, "ratios": {}}

    for name, cfg in configs.items():
        backends = ("ref",) if name == "admm" else ("ref", "pallas")
        for engine in ("simulated", "shard_map"):
            for backend in backends:
                key = f"{name}/{engine}/{backend}"
                cell = bench_combo(name, cfg, X, y, P, Q, engine, backend,
                                   f_star, args.reps)
                out["cells"][key] = cell
                emit_csv_row(f"core/{key}", cell["s_per_iter"] * 1e6,
                             f"rel_opt={cell['rel_opt']:.4f}")
                skey = f"{key}/sparse"
                scell = bench_combo(name, cfg, Xs, ys, P, Q, engine,
                                    backend, fs_star, args.reps,
                                    block_format="sparse")
                out["cells"][skey] = scell
                emit_csv_row(f"core/{skey}", scell["s_per_iter"] * 1e6,
                             f"rel_opt={scell['rel_opt']:.4f}")

    cells = out["cells"]
    for name in configs:
        for engine in ("simulated", "shard_map"):
            r = cells.get(f"{name}/{engine}/ref")
            p = cells.get(f"{name}/{engine}/pallas")
            if r and p:
                out["ratios"][f"{name}/{engine}/pallas_over_ref"] = (
                    p["s_per_iter"] / r["s_per_iter"])
        for backend in ("ref", "pallas"):
            s = cells.get(f"{name}/simulated/{backend}")
            d = cells.get(f"{name}/shard_map/{backend}")
            if s and d:
                out["ratios"][f"{name}/{backend}/shard_map_over_simulated"] \
                    = (d["s_per_iter"] / s["s_per_iter"])
            for engine in ("simulated", "shard_map"):
                dn = cells.get(f"{name}/{engine}/{backend}")
                sp = cells.get(f"{name}/{engine}/{backend}/sparse")
                if dn and sp:
                    out["ratios"][
                        f"{name}/{engine}/{backend}/sparse_over_dense"] = (
                        sp["s_per_iter"] / dn["s_per_iter"])

    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=1)
    print(f"[core_bench] wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
