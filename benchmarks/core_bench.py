"""Core solver benchmark: outer-step throughput of the unified solver API
under every (engine, local_backend) pair.

Forces a fake 8-device host platform (before jax init) so the shard_map
engine runs its real collectives on CPU.  On CPU the pallas backend runs
in interpret mode -- those numbers validate plumbing and track the perf
trajectory, not TPU throughput (the dry-run/roofline path is the TPU
performance story).

    PYTHONPATH=src python -m benchmarks.core_bench [--quick]

Emits ``BENCH_core.json`` (repo root by default): seconds per outer
iteration per (solver, engine, backend[, sparse]) cell plus the
headline ratios -- ref vs pallas per engine, simulated vs shard_map per
backend, and sparse vs dense per (engine, backend) on the low-density
instance.  The payload carries a provenance stamp (git_sha / date /
quick) that ``benchmarks.check_regression`` requires before gating.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import time  # noqa: E402

import jax  # noqa: E402

from repro.core import (D3CAConfig, RADiSAConfig, ADMMConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_sparse_svm_data, make_svm_data  # noqa: E402
from repro.obs import Registry, Tracer  # noqa: E402

try:
    from .common import emit_csv_row, phase_fields, provenance, timed
except ImportError:                       # `python benchmarks/core_bench.py`
    from common import emit_csv_row, phase_fields, provenance, timed


def bench_combo(name, cfg, X, y, P, Q, engine, backend, f_star, reps,
                block_format="dense", compression=None, staleness=0):
    solver = get_solver(name)(engine=engine, local_backend=backend,
                              block_format=block_format,
                              compression=compression, staleness=staleness)
    prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
    state = prog.step(1, prog.state)          # compile + warm
    t = timed(lambda: prog.step(2, state), reps=reps, warmup=0)
    # a short solve for a correctness anchor on the same combo; the
    # registry switches it to the timed drive path, so its history also
    # carries the per-phase attribution (step_s / local_s / comm_s /
    # host_s means land in the cell)
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       record_history=True, registry=Registry())
    cell = {"s_per_iter": t, "rel_opt": res.history[-1]["rel_opt"],
            "iters": res.iters,
            "comm_bytes_per_step": res.comm_bytes["bytes_per_step"]}
    cell.update(phase_fields(res.history))
    return cell


def trace_overhead(name, cfg, X, y, P, Q, iters, reps):
    """Per-iter drive-loop cost with tracing on vs off, same warm program.

    This measures exactly what an enabled Tracer adds to the hot loop
    (span bookkeeping + the per-step block_until_ready the timed path
    needs) without the one-time phase calibration, which amortizes to
    zero over a long solve.  min-over-reps on both sides to shed
    scheduler noise.

    The tracer's cost is a fixed few microseconds per outer iteration,
    so the *fraction* depends on step duration; the probe uses a
    realistic inner-epoch count rather than the quick grid's micro-step
    (on a 0.1 ms step even a perfect tracer misses a 3% budget).

    The same probe also measures the FlightRecorder (the ring-buffer
    tracer the long-running services leave on): its capacity is set
    BELOW the span count of the run so every recorded iteration pays
    the drop-oldest path -- the steady state of a service that has been
    up for hours."""
    from repro.core.engines import drive
    from repro.obs import FlightRecorder

    cfg = type(cfg)(lam=cfg.lam, outer_iters=cfg.outer_iters,
                    local_steps=max(1024, cfg.local_steps))
    solver = get_solver(name)(engine="simulated", local_backend="ref")
    prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
    jax.block_until_ready(prog.step(1, prog.state))      # compile + warm

    def run(tracer):
        t0 = time.perf_counter()
        state, _, _ = drive(prog, iters, tracer=tracer)
        jax.block_until_ready(state)
        return (time.perf_counter() - t0) / iters

    run(Tracer())                                        # warm both paths
    untraced = min(run(None) for _ in range(reps))
    traced = min(run(Tracer()) for _ in range(reps))
    # capacity < spans per run (2/iter: outer_iter + step) => the whole
    # run exercises the at-capacity drop path
    recorded = min(run(FlightRecorder(capacity=max(2, iters)))
                   for _ in range(reps))
    return {"untraced_s_per_iter": untraced, "traced_s_per_iter": traced,
            "overhead_frac": traced / untraced - 1.0,
            "recorder_s_per_iter": recorded,
            "recorder_overhead_frac": recorded / untraced - 1.0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="also run one traced d3ca/simulated/ref solve "
                         "and write its Chrome-trace JSON here (the CI "
                         "bench job uploads it as an artifact)")
    ap.add_argument("--max-trace-overhead", type=float, default=0.03,
                    help="fail when an enabled tracer slows s_per_iter "
                         "by more than this fraction")
    args = ap.parse_args(argv)

    P, Q = 4, 2
    n, m = (256, 96) if args.quick else (768, 256)
    inner = 32 if args.quick else 96
    iters = 3 if args.quick else 5
    density = 0.05
    X, y = make_svm_data(n, m, seed=0)
    # the sparse grid runs on a low-density instance (weak-scaling
    # regime); dense np array in, partitioned into ELL cells by the
    # block_format knob
    Xs, ys = make_sparse_svm_data(n, m, density=density, seed=0)
    lam = 1e-1
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=100)
    f_star = float(objective("hinge", X, y, w_ref, lam))
    ws_ref, _ = serial_sdca("hinge", Xs, ys, lam=lam, epochs=100)
    fs_star = float(objective("hinge", Xs, ys, ws_ref, lam))

    configs = {
        "d3ca": D3CAConfig(lam=lam, outer_iters=iters, local_steps=inner),
        "radisa": RADiSAConfig(lam=lam, gamma=0.05, outer_iters=iters,
                               L=inner),
        "admm": ADMMConfig(lam=lam, rho=lam, outer_iters=iters),
    }
    out = {"n": n, "m": m, "P": P, "Q": Q, "lam": lam, "inner": inner,
           "sparse_density": density,
           "note": "pallas numbers are interpret-mode on CPU unless run "
                   "on a TPU host",
           "provenance": provenance(args.quick),
           "cells": {}, "ratios": {}}

    # the overlap engine rides the grid at a fixed tau (its own tau
    # sweep lives in fig_overlap); tau > 0 hides comm behind local solve
    overlap_tau = 2
    for name, cfg in configs.items():
        backends = ("ref",) if name == "admm" else ("ref", "pallas")
        for engine in ("simulated", "shard_map", "overlap"):
            tau = overlap_tau if engine == "overlap" else 0
            for backend in backends:
                key = f"{name}/{engine}/{backend}"
                cell = bench_combo(name, cfg, X, y, P, Q, engine, backend,
                                   f_star, args.reps, staleness=tau)
                out["cells"][key] = cell
                emit_csv_row(f"core/{key}", cell["s_per_iter"] * 1e6,
                             f"rel_opt={cell['rel_opt']:.4f}")
                skey = f"{key}/sparse"
                scell = bench_combo(name, cfg, Xs, ys, P, Q, engine,
                                    backend, fs_star, args.reps,
                                    block_format="sparse", staleness=tau)
                out["cells"][skey] = scell
                emit_csv_row(f"core/{skey}", scell["s_per_iter"] * 1e6,
                             f"rel_opt={scell['rel_opt']:.4f}")

    cells = out["cells"]
    for name in configs:
        for engine in ("simulated", "shard_map", "overlap"):
            r = cells.get(f"{name}/{engine}/ref")
            p = cells.get(f"{name}/{engine}/pallas")
            if r and p:
                out["ratios"][f"{name}/{engine}/pallas_over_ref"] = (
                    p["s_per_iter"] / r["s_per_iter"])
        for backend in ("ref", "pallas"):
            s = cells.get(f"{name}/simulated/{backend}")
            d = cells.get(f"{name}/shard_map/{backend}")
            if s and d:
                out["ratios"][f"{name}/{backend}/shard_map_over_simulated"] \
                    = (d["s_per_iter"] / s["s_per_iter"])
            o = cells.get(f"{name}/overlap/{backend}")
            if d and o:
                out["ratios"][f"{name}/{backend}/overlap_over_shard_map"] \
                    = (o["s_per_iter"] / d["s_per_iter"])
            for engine in ("simulated", "shard_map", "overlap"):
                dn = cells.get(f"{name}/{engine}/{backend}")
                sp = cells.get(f"{name}/{engine}/{backend}/sparse")
                if dn and sp:
                    out["ratios"][
                        f"{name}/{engine}/{backend}/sparse_over_dense"] = (
                        sp["s_per_iter"] / dn["s_per_iter"])

    # tracing-overhead gate: an enabled tracer must stay within
    # --max-trace-overhead of the untraced drive loop (s_per_iter).  The
    # absolute floor absorbs timer granularity on sub-millisecond iters.
    ov = trace_overhead("d3ca", configs["d3ca"], X, y, P, Q,
                        iters=max(10, 4 * iters), reps=max(3, args.reps))
    out["trace_overhead"] = ov
    print(f"[core_bench] trace overhead: "
          f"{ov['untraced_s_per_iter'] * 1e3:.3f} -> "
          f"{ov['traced_s_per_iter'] * 1e3:.3f} ms/iter "
          f"({100 * ov['overhead_frac']:+.2f}%); recorder "
          f"{ov['recorder_s_per_iter'] * 1e3:.3f} ms/iter "
          f"({100 * ov['recorder_overhead_frac']:+.2f}%)")
    budget = (ov["untraced_s_per_iter"] * (1.0 + args.max_trace_overhead)
              + 5e-4)
    assert ov["traced_s_per_iter"] <= budget, (
        f"enabled tracer adds {100 * ov['overhead_frac']:.1f}% per iter "
        f"(> {100 * args.max_trace_overhead:.0f}% budget)")
    assert ov["recorder_s_per_iter"] <= budget, (
        f"flight recorder adds "
        f"{100 * ov['recorder_overhead_frac']:.1f}% per iter at capacity "
        f"(> {100 * args.max_trace_overhead:.0f}% budget)")

    if args.trace_out:
        tracer = Tracer()
        solver = get_solver("d3ca")(engine="simulated", local_backend="ref")
        solver.solve("hinge", X, y, P=P, Q=Q, cfg=configs["d3ca"],
                     f_star=f_star, tracer=tracer)
        tracer.write_chrome_trace(args.trace_out)
        print(f"[core_bench] trace: {len(tracer.events)} events -> "
              f"{args.trace_out}")

    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=1)
    print(f"[core_bench] wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
