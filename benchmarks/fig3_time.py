"""Paper Figure 3: relative optimality difference vs elapsed time for the
three synthetic instances (P,Q) in {(4,2), (5,3), (7,4)} x two lambdas,
comparing RADiSA / RADiSA-avg / D3CA / block-splitting ADMM.

All methods run through the unified solver API, so the figure can be
produced under any (engine, local_backend) pair:

    python -m benchmarks.fig3_time --engine shard_map --backend pallas

CPU-scaled instances by default (--scale 0.1 of the paper's 2000x3000
blocks); pass --full for paper-sized blocks.  ADMM's Cholesky setup runs
at program-build time and is excluded from iteration timings, as in the
paper.
"""
from __future__ import annotations

import argparse
import sys

from .common import add_engine_args, emit_csv_row, ensure_host_devices, \
    save_result

ensure_host_devices(sys.argv)

from repro.configs.svm_paper import PART1                   # noqa: E402
from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_svm_data                        # noqa: E402


def run_instance(exp, lam, scale, iters, engine, backend, seed=0,
                 staleness=0, compression=None):
    bn, bm = int(exp.block_n * scale), int(exp.block_m * scale)
    n, m = exp.P * bn, exp.Q * bm
    X, y = make_svm_data(n, m, seed=seed)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam,
                           epochs=max(200, 3 * iters))
    f_star = float(objective("hinge", X, y, w_ref, lam))
    out = {"n": n, "m": m, "P": exp.P, "Q": exp.Q, "lam": lam,
           "f_star": f_star, "engine": engine, "backend": backend,
           "methods": {}}

    def trace(name, cfg, label):
        solver = get_solver(name)(engine=engine, local_backend=backend,
                                  staleness=staleness,
                                  compression=compression)
        res = solver.solve("hinge", X, y, P=exp.P, Q=exp.Q, cfg=cfg,
                           f_star=f_star)
        hist = [{"iter": h["iter"], "time_s": h["time_s"],
                 "rel_opt": h["rel_opt"]} for h in res.history]
        out["methods"][label] = hist
        emit_csv_row(f"fig3/{exp.name}/lam{lam}/{label}",
                     hist[-1]["time_s"] * 1e6 / len(hist),
                     f"rel_opt={hist[-1]['rel_opt']:.4f}")

    trace("d3ca", D3CAConfig(lam=lam, outer_iters=iters), "d3ca")
    gamma = 0.02 if lam <= 1e-2 else 0.05
    trace("radisa", RADiSAConfig(lam=lam, gamma=gamma, outer_iters=iters),
          "radisa")
    trace("radisa", RADiSAConfig(lam=lam, gamma=gamma, outer_iters=iters,
                                 variant="avg"), "radisa_avg")
    trace("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=3 * iters),
          "admm")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=15)
    add_engine_args(ap)
    args = ap.parse_args(argv)
    scale = 1.0 if args.full else args.scale

    results = []
    for exp in PART1:
        for lam in (1e-1, 1e-2):
            results.append(run_instance(exp, lam, scale, args.iters,
                                        args.engine, args.backend,
                                        staleness=args.staleness,
                                        compression=args.compression))
    save_result("fig3_time", {"scale": scale, "engine": args.engine,
                              "backend": args.backend,
                              "compression": args.compression,
                              "results": results})


if __name__ == "__main__":
    main()
