"""Paper Figure 3: relative optimality difference vs elapsed time for the
three synthetic instances (P,Q) in {(4,2), (5,3), (7,4)} x two lambdas,
comparing RADiSA / RADiSA-avg / D3CA / block-splitting ADMM.

CPU-scaled instances by default (--scale 0.1 of the paper's 2000x3000
blocks); pass --full for paper-sized blocks.  ADMM's Cholesky setup is
excluded from timing, as in the paper.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.svm_paper import PART1
from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,
                        admm_setup_simulated, admm_simulated, d3ca_simulated,
                        objective, partition, radisa_simulated, rel_opt,
                        serial_sdca)
from repro.data import make_svm_data

from .common import emit_csv_row, save_result


def run_instance(exp, lam, scale, iters, seed=0):
    bn, bm = int(exp.block_n * scale), int(exp.block_m * scale)
    n, m = exp.P * bn, exp.Q * bm
    X, y = make_svm_data(n, m, seed=seed)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam,
                           epochs=max(200, 3 * iters))
    f_star = float(objective("hinge", X, y, w_ref, lam))
    data = partition(X, y, exp.P, exp.Q)
    out = {"n": n, "m": m, "P": exp.P, "Q": exp.Q, "lam": lam,
           "f_star": f_star, "methods": {}}

    def trace(runner, label):
        hist = []
        t0 = time.perf_counter()

        def cb(t, w, *rest):
            hist.append({
                "iter": t, "time_s": time.perf_counter() - t0,
                "rel_opt": float(rel_opt(
                    objective("hinge", X, y, w, lam), f_star))})
        runner(cb)
        out["methods"][label] = hist
        emit_csv_row(f"fig3/{exp.name}/lam{lam}/{label}",
                     hist[-1]["time_s"] * 1e6 / len(hist),
                     f"rel_opt={hist[-1]['rel_opt']:.4f}")

    trace(lambda cb: d3ca_simulated(
        "hinge", data, D3CAConfig(lam=lam, outer_iters=iters), callback=cb),
        "d3ca")
    gamma = 0.02 if lam <= 1e-2 else 0.05
    trace(lambda cb: radisa_simulated(
        "hinge", data, RADiSAConfig(lam=lam, gamma=gamma,
                                    outer_iters=iters), callback=cb),
        "radisa")
    trace(lambda cb: radisa_simulated(
        "hinge", data, RADiSAConfig(lam=lam, gamma=gamma, outer_iters=iters,
                                    variant="avg"), callback=cb),
        "radisa_avg")
    chol = admm_setup_simulated(data, ADMMConfig(lam=lam, rho=lam))
    trace(lambda cb: admm_simulated(
        "hinge", data, ADMMConfig(lam=lam, rho=lam,
                                  outer_iters=3 * iters),
        callback=cb, chol=chol), "admm")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=15)
    args = ap.parse_args(argv)
    scale = 1.0 if args.full else args.scale

    results = []
    for exp in PART1:
        for lam in (1e-1, 1e-2):
            results.append(run_instance(exp, lam, scale, args.iters))
    save_result("fig3_time", {"scale": scale, "results": results})


if __name__ == "__main__":
    main()
