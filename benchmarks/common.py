"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

OUT_DIR = os.environ.get(
    "REPRO_BENCH_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "experiments", "bench"))


def save_result(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1)


def timed(fn, *args, reps=1, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit_csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
