"""Shared benchmark utilities.

This module imports jax lazily: the fig benchmarks call
``ensure_host_devices`` BEFORE the first jax import so that the
shard_map engine can fake a P x Q device grid on CPU.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import sys
import time

OUT_DIR = os.environ.get(
    "REPRO_BENCH_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "experiments", "bench"))


def ensure_host_devices(argv, count: int = 32):
    """Force ``count`` host devices when the argv selects the shard_map
    engine.  Must run before anything imports jax (the device count is
    locked at first init) -- call it between the stdlib imports and the
    ``repro.*`` imports of a benchmark script."""
    if not any("shard_map" in a or "async" in a or "overlap" in a
               for a in argv):
        return      # also matches the --engine=shard_map / =async forms
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return      # already forced (possibly by an earlier fig module)
    if "jax" in sys.modules:
        print("warning: jax already initialized; --engine shard_map needs "
              "XLA_FLAGS=--xla_force_host_platform_device_count=N set "
              "before the first jax import", file=sys.stderr)
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={count}").strip()


def add_engine_args(ap):
    """--engine / --backend / --block-format / --staleness /
    --compression knobs shared by the fig benchmarks."""
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map", "sync", "async",
                             "overlap"])
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="cell-local solver backend")
    ap.add_argument("--block-format", default="dense",
                    choices=["dense", "sparse"],
                    help="per-cell layout (sparse = padded-ELL cells)")
    ap.add_argument("--staleness", type=int, default=0, metavar="TAU",
                    help="async/overlap engines: reduction delay tau "
                         "(0 = synchronous)")
    ap.add_argument("--compression", default=None, metavar="SPEC",
                    help="codec spec for the declared collectives "
                         "('int8', 'fp8', 'topk:0.1', per-collective "
                         "'dw=int8,z=identity', or an "
                         "'adaptive[:...]' schedule); default: none")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="hierarchical reduction topology, e.g. "
                         "'pods=2:int8' (default: flat)")
    return ap


def provenance(quick: bool) -> dict:
    """Stamp for BENCH_*.json payloads: the regression gate and
    trajectory plots must be able to trust what produced a number."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    return {
        "git_sha": sha,
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "quick": bool(quick),
    }


def phase_fields(history) -> dict:
    """Mean per-iteration phase attribution over a timed solve's history
    (``step_s`` / ``local_s`` / ``comm_s`` / ``host_s`` -- present when
    the solve ran under a tracer or registry).  Empty dict when
    telemetry was off, so callers can ``cell.update(...)`` blindly."""
    timed_hist = [h for h in history if "step_s" in h]
    out = {}
    if timed_hist:
        k = float(len(timed_hist))
        for field in ("step_s", "local_s", "comm_s", "host_s",
                      "comm_exposed_s", "comm_hidden_s"):
            vals = [h[field] for h in timed_hist if field in h]
            if len(vals) == len(timed_hist):
                out[field] = sum(vals) / k
    return out


def annotate_wire_predictions(cells: dict, samples, algo: str = "ring"):
    """Fit the alpha-beta wire-time model on a sweep's own measured
    per-step ``comm_s`` and stamp every sampled cell with predicted
    seconds + relative error (``predicted_comm_s`` /
    ``predicted_rel_err``).

    Each sample is ``(acct, sizes, measured_comm_s, cell_key,
    topology_or_None)`` -- ``acct`` the program's wire accounting,
    ``sizes`` the logical axis extents.  Returns the ``wire_model``
    report block for the sweep payload (fitted alpha/beta + per-cell
    predicted-vs-measured).
    """
    import dataclasses

    from repro.core.comm_model import fit_link, predict_comm_s
    link = fit_link([(acct, sizes, t) for acct, sizes, t, _, _ in samples],
                    algo=algo, name="fitted")
    report = {"alpha_s": link.alpha_s,
              "beta_s_per_byte": link.beta_s_per_byte,
              "bandwidth_gbps": link.bandwidth_gbps, "algo": algo,
              "cells": {}}
    for acct, sizes, measured, key, topo in samples:
        if topo is not None:
            topo = dataclasses.replace(topo, intra=link, inter=link)
        pred = predict_comm_s(acct, sizes, topology=topo, link=link,
                              algo=algo)
        rel_err = (abs(pred["total_s"] - measured) / measured
                   if measured > 0 else None)
        cells[key]["predicted_comm_s"] = pred["total_s"]
        cells[key]["predicted_rel_err"] = rel_err
        report["cells"][key] = {"predicted_s": pred["total_s"],
                                "measured_s": measured,
                                "rel_err": rel_err}
    return report


def save_result(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1)


def timed(fn, *args, reps=1, warmup=1):
    import jax
    import numpy as np
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit_csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
