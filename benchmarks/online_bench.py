"""Online service benchmark: staleness vs throughput under mixed load.

Drives the streaming service (``repro.online``) with an interleaved
train/score workload at several ingest rates and measures what the
paper's batch benchmarks cannot: how stale the *served* model runs when
updates and scoring contend, and how update throughput scales with the
batch the admission queue coalesces.

    PYTHONPATH=src python -m benchmarks.online_bench [--quick]

Emits ``BENCH_online.json`` (repo root by default):

  * ``cells`` -- one per (solver, engine, load level) with
    ``s_per_iter`` (seconds per warm-started gated update pass, the
    same field name the regression gate keys on), rows/s absorbed,
    swap latency, and the staleness percentiles observed at score time;
  * ``trace`` -- the staleness-vs-throughput curve: one point per load
    level (ingest rows/s attempted vs staleness p50/p90 at the scorer);
  * a provenance stamp (``benchmarks.common.provenance``) so
    ``benchmarks.check_regression`` can gate the quick cells against
    ``benchmarks/baselines/BENCH_online_quick.json``.

The baseline reflects the per-update compiled-program cache in
``Solver.update`` (one trace per (shape, config) across the whole
stream): before it every gated update retraced, costing ~1.8 s per
update on the quick instance; with it an update is ~20 ms.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

import numpy as np  # noqa: E402

from repro.core import D3CAConfig  # noqa: E402
from repro.obs import Registry  # noqa: E402
from repro.online import OnlineConfig, OnlineSolverService  # noqa: E402

try:
    from .common import provenance, save_result
except ImportError:                     # `python benchmarks/online_bench.py`
    from common import provenance, save_result


def _stream(rng, b, m, w_star):
    X = rng.normal(size=(b, m)).astype(np.float32)
    y = np.where(X @ w_star >= 0, 1.0, -1.0).astype(np.float32)
    return X, y


def bench_load(*, m, capacity, P, Q, batch, rounds, passes, score_batch,
               engine="simulated", backend="ref", seed=0):
    """One mixed-load cell: ``rounds`` of submit -> update -> score.

    Returns the cell dict.  ``s_per_iter`` is seconds per update pass
    (median), staleness is sampled right before every score call --
    i.e. the age of the model a request actually hits.
    """
    import time

    rng = np.random.default_rng(seed)
    w_star = np.linspace(-1.0, 1.0, m).astype(np.float32)
    reg = Registry()
    svc = OnlineSolverService(
        OnlineConfig(m=m, capacity=capacity, P=P, Q=Q,
                     solver_cfg=D3CAConfig(lam=1e-2), passes=passes,
                     engine=engine, local_backend=backend,
                     queue_capacity=0),
        registry=reg)
    # warm the jit cache so compile time doesn't pollute the cells
    svc.submit(*_stream(rng, batch, m, w_star))
    svc.run_pending()
    svc.score(_stream(rng, score_batch, m, w_star)[0])

    update_s, stale_s = [], []
    t_start = time.perf_counter()
    for _ in range(rounds):
        svc.submit(*_stream(rng, batch, m, w_star))
        t0 = time.perf_counter()
        svc.run_pending()
        update_s.append(time.perf_counter() - t0)
        stale_s.append(svc.staleness_s)     # age the next request sees
        svc.score(_stream(rng, score_batch, m, w_star)[0])
    wall = time.perf_counter() - t_start

    snap = reg.snapshot()
    swap = next((h for k, h in snap["histograms"].items()
                 if k.startswith("online/swap_s")), {})
    u = np.asarray(update_s)
    st = np.asarray(stale_s)
    return {
        "s_per_iter": float(np.median(u)),
        "update_p90_s": float(np.percentile(u, 90)),
        "rows_per_update": batch,
        "train_rows_per_s": float(batch * rounds / u.sum()),
        "ingest_rows_per_s_attempted": float(batch * rounds / wall),
        "staleness_p50_s": float(np.percentile(st, 50)),
        "staleness_p90_s": float(np.percentile(st, 90)),
        "swap_p50_s": float(swap.get("p50", 0.0)),
        "score_rows_per_s": float(svc.scorer.rows_per_sec),
        "version": int(svc.book.current().version),
        "version_lag": int(svc.version_lag),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small instance + fewer rounds (the CI gate "
                         "compares quick runs only)")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_online.json"))
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map"])
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    args = ap.parse_args(argv)

    if args.quick:
        m, capacity, rounds, passes, score_batch = 24, 96, 4, 1, 64
        loads = [8, 24]
    else:
        m, capacity, rounds, passes, score_batch = 64, 512, 10, 2, 256
        loads = [8, 32, 128]
    P, Q = 2, 2

    cells, trace = {}, []
    for batch in loads:
        key = f"d3ca/{args.engine}/{args.backend}/batch{batch}"
        cell = bench_load(m=m, capacity=capacity, P=P, Q=Q, batch=batch,
                          rounds=rounds, passes=passes,
                          score_batch=score_batch, engine=args.engine,
                          backend=args.backend)
        cells[key] = cell
        trace.append({
            "load_rows_per_round": batch,
            "ingest_rows_per_s": cell["ingest_rows_per_s_attempted"],
            "train_rows_per_s": cell["train_rows_per_s"],
            "staleness_p50_s": cell["staleness_p50_s"],
            "staleness_p90_s": cell["staleness_p90_s"],
        })
        print(f"{key}: {cell['s_per_iter'] * 1e3:.1f} ms/update, "
              f"{cell['train_rows_per_s']:.0f} rows/s trained, "
              f"staleness p50 {cell['staleness_p50_s'] * 1e3:.1f} ms "
              f"p90 {cell['staleness_p90_s'] * 1e3:.1f} ms")

    out = {
        "m": m, "capacity": capacity, "P": P, "Q": Q,
        "rounds": rounds, "passes": passes, "score_batch": score_batch,
        "note": "s_per_iter = seconds per warm-started gated update "
                "pass; staleness sampled at score time under the "
                "interleaved train/score load",
        "provenance": provenance(args.quick),
        "cells": cells,
        "trace": trace,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=1)
    save_result("BENCH_online", out)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
