"""Serving benchmark: continuous batching vs the seed static-batch loop.

Drives a mixed-length request trace (8-128 token prompts, varied
generation lengths) through ``repro.serve.InferenceEngine`` and through
the seed-era static loop (``repro.launch.serve.static_batch_generate``),
and reports aggregate tokens/s plus p50/p99 request latency for each.
Both paths get one untimed warmup pass over the same trace so the
numbers compare steady-state throughput, not XLA compile time.

    PYTHONPATH=src python -m benchmarks.serve_bench [--requests 16] \
        [--slots 4] [--out BENCH_serve.json]

Emits ``BENCH_serve.json`` (repo root by default) with both summaries
and the speedup ratio.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config
from repro.launch.serve import build_trace, static_batch_generate
from repro.models import Transformer, reduced
from repro.obs import Registry
from repro.serve import (EngineConfig, InferenceEngine, RequestMetrics,
                         SamplingParams, percentiles)

try:
    from .common import provenance
except ImportError:                     # `python benchmarks/serve_bench.py`
    from common import provenance

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_trace(cfg, n_requests, seed=0, rid_base=0):
    """Mixed-length trace (the CLI's builder): prompts 8-128 tokens,
    8-128 generated, greedy."""
    return build_trace(cfg, n_requests, 8, 128, 8, 128, SamplingParams(),
                       seed=seed, rid_base=rid_base)


def run_static(model, params, reqs, batch_size):
    """The seed loop, chunk by chunk, recording per-request latency
    (every request arrives at t0; its latency is its batch's finish)."""
    lat = []
    n_tokens = 0
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), batch_size):
        chunk = reqs[lo: lo + batch_size]
        out = static_batch_generate(model, params, chunk, batch_size)
        t = time.perf_counter() - t0
        lat.extend([t] * len(chunk))
        n_tokens += sum(len(v) for v in out.values())
    elapsed = time.perf_counter() - t0
    return {"requests": len(reqs), "generated_tokens": n_tokens,
            "elapsed_s": elapsed, "tokens_per_sec": n_tokens / elapsed,
            "latency_s": percentiles(lat)}


def run_engine(engine, reqs):
    reg = Registry()
    engine.metrics = RequestMetrics(registry=reg)   # count only this pass
    out = engine.run(reqs)
    s = engine.metrics.summary()
    missing = [r.rid for r in reqs if r.rid not in out]
    assert not missing, f"requests rejected or unfinished: {missing}"
    return {"requests": s["requests_finished"],
            "generated_tokens": s["generated_tokens"],
            "elapsed_s": s["elapsed_s"],
            "tokens_per_sec": s["tokens_per_sec"],
            "ttft_s": s["ttft_s"], "latency_s": s["latency_s"],
            "decode_steps": s["decode_steps"],
            "preemptions": s["preemptions"],
            # the unified telemetry schema, embedded verbatim
            "metrics": reg.snapshot()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_serve.json"))
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (marks the payload's provenance; "
                         "the default trace is already CI-sized)")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    model = Transformer(cfg)
    params = jax.jit(lambda k: model.init(k)[0])(jax.random.PRNGKey(0))

    max_seq = 128 + 128
    ecfg = EngineConfig(
        max_slots=args.slots, page_size=args.page_size,
        num_pages=max(64, args.slots * ((max_seq // args.page_size) + 1)),
        max_seq_len=max_seq)
    engine = InferenceEngine(model, params, ecfg)

    if args.requests > ecfg.max_queue:
        ap.error(f"--requests > engine max_queue ({ecfg.max_queue})")
    trace = bench_trace(cfg, args.requests, seed=args.seed)
    warmup = bench_trace(cfg, args.requests, seed=args.seed,
                         rid_base=10_000)   # same shapes, fresh rids

    # warmup: compile every prefill bucket + the decode step on each path
    static_batch_generate(model, params, warmup, args.slots)
    engine.run(warmup)

    static = run_static(model, params, trace, args.slots)
    served = run_engine(engine, trace)

    result = {
        "provenance": provenance(args.quick),
        "arch": args.arch, "requests": args.requests, "slots": args.slots,
        "trace": {"prompt_len": [len(r.prompt) for r in trace],
                  "max_new_tokens": [r.max_new_tokens for r in trace]},
        "static": static, "engine": served,
        "speedup_tokens_per_sec":
            served["tokens_per_sec"] / static["tokens_per_sec"],
    }
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)

    print(f"static  : {static['tokens_per_sec']:8.1f} tok/s  "
          f"p50 {static['latency_s']['p50']:.3f}s "
          f"p99 {static['latency_s']['p99']:.3f}s")
    print(f"engine  : {served['tokens_per_sec']:8.1f} tok/s  "
          f"p50 {served['latency_s']['p50']:.3f}s "
          f"p99 {served['latency_s']['p99']:.3f}s "
          f"(ttft p50 {served['ttft_s']['p50']:.3f}s)")
    print(f"speedup : {result['speedup_tokens_per_sec']:.2f}x  "
          f"-> {args.out}")
    return result


if __name__ == "__main__":
    main()
