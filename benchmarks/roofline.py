"""Roofline table assembly (deliverable g).

Reads experiments/dryrun/*.json (full-model compiles + per-period
calibrations produced by repro.launch.dryrun) and emits per
(arch x shape) on the single-pod 16x16 mesh:

  * the three roofline terms (compute / memory / collective, seconds),
  * the dominant bottleneck,
  * MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) + attention quadratic,
  * MODEL_FLOPS / HLO_FLOPs utilization ratio,
  * a one-line "what would move the dominant term" note.

HLO numbers are scan-corrected: cost_analysis counts a lax.scan body once,
so totals are extrapolated with the calibrated per-period costs:
    total = full + (n_periods - 1) * per_period,   per_period = B - A.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCHS, get_config
from repro.models.config import LM_SHAPES
from repro.roofline.model import HW_V5E, model_flops, roofline_terms

DRY_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "experiments", "dryrun"))
OUT = os.path.join(os.path.dirname(DRY_DIR), "roofline")

CHIPS = 256


def _load(name):
    p = os.path.join(DRY_DIR, name)
    if not os.path.exists(p):
        return None
    with open(p) as fh:
        return json.load(fh)


def _advice(dominant, cfg, shape):
    if dominant == "compute":
        return ("compute-bound: raise MXU utilization (fuse attention "
                "blocks, bf16 everywhere, avoid remat recompute)")
    if dominant == "memory":
        if shape.kind == "decode":
            return ("HBM-bound (weight streaming): shrink bytes/step via "
                    "weight quantization or larger decode batch per chip")
        return ("HBM-bound: fuse elementwise chains, keep activations "
                "bf16, reuse tiles in VMEM (bigger attention blocks)")
    return ("collective-bound: overlap collectives with compute (latency "
            "hiding scheduler), reduce-scatter instead of all-reduce, "
            "shard so the gradient reduction crosses fewer links, or "
            "int8-compress the DP all-reduce")


def build_table(emit=print):
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        n_full, n_rem = cfg.n_periods()
        for shape in LM_SHAPES:
            full = _load(f"{arch}__{shape.name}__16x16.json")
            if full is None:
                continue
            if full.get("status") == "skipped":
                rows.append({"arch": cfg.name, "shape": shape.name,
                             "status": "skipped",
                             "reason": full.get("reason", "")})
                continue
            calib = _load(f"{arch}__{shape.name}__calib.json")
            flops = full["flops"]
            bts = full["bytes_accessed"]
            wire = full["collectives"]["total_bytes"]
            extrap = False
            if calib and n_full >= 1:
                A, B = calib["variants"]["A"], calib["variants"]["B"]
                pp_f = max(B["flops"] - A["flops"], 0.0)
                pp_b = max(B["bytes_accessed"] - A["bytes_accessed"], 0.0)
                pp_w = max(B["collectives"]["total_bytes"]
                           - A["collectives"]["total_bytes"], 0.0)
                flops += (n_full - 1) * pp_f
                bts += (n_full - 1) * pp_b
                wire += (n_full - 1) * pp_w
                extrap = True
            terms = roofline_terms(flops, bts, wire)
            mf = model_flops(cfg, shape) / CHIPS   # per device
            ratio = mf / flops if flops else 0.0
            rows.append({
                "arch": cfg.name, "shape": shape.name, "status": "ok",
                "scan_corrected": extrap,
                "hlo_flops_per_dev": flops,
                "hlo_bytes_per_dev": bts,
                "wire_bytes_per_dev": wire,
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
                "dominant": terms["dominant"],
                "bound_s": terms["bound_s"],
                "model_flops_per_dev": mf,
                "useful_ratio": ratio,
                "advice": _advice(terms["dominant"], cfg, shape),
            })
    # the paper's own SVM workload (hinge, one 40960x5120 block per chip)
    for algo in ("d3ca", "radisa"):
        d = _load(f"paper_svm_{algo}__16x16.json")
        if d is None:
            continue
        A, B, F = d["calib_A"], d["calib_B"], d["full"]
        steps = d["inner_steps"]
        pf = max(B["flops"] - A["flops"], 0.0)
        pb = max(B["bytes_accessed"] - A["bytes_accessed"], 0.0)
        flops = F["flops"] + (steps - 1) * pf
        bts = F["bytes_accessed"] + (steps - 1) * pb
        wire = F["collectives"]["total_bytes"]
        terms = roofline_terms(flops, bts, wire)
        rows.append({
            "arch": f"paper-svm-{algo}", "shape": d["shape"], "status": "ok",
            "scan_corrected": True,
            "hlo_flops_per_dev": flops, "hlo_bytes_per_dev": bts,
            "wire_bytes_per_dev": wire,
            "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": terms["dominant"], "bound_s": terms["bound_s"],
            "model_flops_per_dev": flops, "useful_ratio": 1.0,
            "advice": ("sequential coordinate updates are latency-bound; "
                       "the Pallas kernel keeps (w, dalpha) in VMEM so HBM "
                       "traffic/step is one x-row"),
        })

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "roofline.json"), "w") as fh:
        json.dump(rows, fh, indent=1)

    md = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| useful FLOP ratio | note |",
          "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            md.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                      f"skipped | -- | {r['reason'][:60]} |")
        else:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                f"{r['advice'][:58]} |")
    table = "\n".join(md)
    with open(os.path.join(OUT, "roofline.md"), "w") as fh:
        fh.write(table + "\n")
    emit(table)
    return rows


def main(argv=None):
    argparse.ArgumentParser().parse_args(argv)
    rows = build_table()
    ok = [r for r in rows if r.get("status") == "ok"]
    print(f"\n{len(ok)} cells analysed, "
          f"{sum(1 for r in rows if r['status'] == 'skipped')} skipped")


if __name__ == "__main__":
    main()
