"""Codec x solver sweep for the compressed-communication subsystem.

Runs every solver under ``engine="shard_map"`` across a codec grid
(default: none, identity, int8, fp8, topk:0.25) on the same instance
the core benchmark uses, and lands the rows in ``BENCH_core.json``:

  * one cell per (solver, codec):
    ``{solver}/compress/{backend}/{codec}`` with s_per_iter, final
    rel_opt, and the exact per-step bytes-on-wire (total + per
    collective) -- so the CI regression gate and the trajectory plots
    see compressed runs the same way they see every other cell;
  * a ``compress_sweep`` block with the full suboptimality-vs-epoch
    curves per codec AND the bytes-vs-epoch axis (cumulative
    ``comm_bytes`` from the Solver history) -- the figure's payload:
    rel_opt against *bytes moved*, which is the paper's real cost axis.

Two contracts are asserted, mirroring fig_async's tau-0 check:

  * the identity codec reproduces the uncompressed run exactly
    (max-abs iterate diff == 0) and reports exactly the uncompressed
    payload bytes;
  * int8 cuts the reported reduction bytes >= 3x vs float32.

    PYTHONPATH=src python -m benchmarks.fig_compress [--quick] \\
        [--codecs none,identity,int8,fp8,topk:0.25] \\
        [--solvers d3ca,radisa,admm]

Forces a fake 8-device host platform before jax init (the sweep runs
the mesh engine).  The payload carries the standard provenance stamp
(git_sha / date / quick).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np  # noqa: E402

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_svm_data  # noqa: E402

try:
    from .common import (annotate_wire_predictions, emit_csv_row,
                         phase_fields, provenance, timed)
except ImportError:                    # `python benchmarks/fig_compress.py`
    from common import (annotate_wire_predictions, emit_csv_row,
                        phase_fields, provenance, timed)


def codec_label(spec: str) -> str:
    """Cell-key-friendly codec name ('topk:0.25' -> 'topk0.25')."""
    return spec.replace(":", "")


def sweep_solver(name, cfg, X, y, P, Q, codecs, backend, f_star, reps):
    """One solver across the codec grid.  Returns (cells, curves,
    samples) -- samples feed the wire-time model fit."""
    plain = get_solver(name)(engine="shard_map", local_backend=backend)
    w_plain = plain.solve("hinge", X, y, P=P, Q=Q, cfg=cfg,
                          record_history=False).w
    cells, curves, samples = {}, {}, []
    for codec in codecs:
        compression = None if codec == "none" else codec
        solver = get_solver(name)(engine="shard_map", local_backend=backend,
                                  compression=compression)
        prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
        state = prog.step(1, prog.state)          # compile + warm
        t = timed(lambda: prog.step(2, state), reps=reps, warmup=0)
        from repro.obs import Registry
        res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                           registry=Registry())
        acct = res.comm_bytes
        entry = {"s_per_iter": t,
                 "rel_opt": res.history[-1]["rel_opt"],
                 "iters": res.iters,
                 "codec": codec,
                 "comm_bytes_per_step": acct["bytes_per_step"],
                 "uncompressed_bytes_per_step":
                     acct["uncompressed_bytes_per_step"],
                 "comm_bytes_by_collective": {
                     cname: c["bytes_per_step"]
                     for cname, c in acct["collectives"].items()}}
        entry.update(phase_fields(res.history))
        if "duality_gap" in res.history[-1]:
            entry["duality_gap"] = res.history[-1]["duality_gap"]
        if codec in ("none", "identity"):
            # contract: identity (and of course none) IS the
            # uncompressed engine, bit for bit -- and reports exactly
            # the uncompressed payload bytes
            diff = float(np.abs(np.asarray(res.w)
                                - np.asarray(w_plain)).max())
            entry["max_abs_diff_vs_uncompressed"] = diff
            assert diff == 0.0, (
                f"{name}: compression={codec!r} diverged from the "
                f"uncompressed engine by {diff:.3e} (expected 0.0)")
            assert (acct["bytes_per_step"]
                    == acct["uncompressed_bytes_per_step"]), (
                f"{name}: {codec} accounting reports "
                f"{acct['bytes_per_step']} B/step, expected the exact "
                f"uncompressed {acct['uncompressed_bytes_per_step']}")
        label = codec_label(codec)
        key = f"{name}/compress/{backend}/{label}"
        if "comm_s" in entry:
            samples.append((acct, {"data": P, "model": Q},
                            entry["comm_s"], key, None))
        cells[key] = entry
        curves[label] = {
            "rel_opt": [h["rel_opt"] for h in res.history],
            "comm_bytes": [h["comm_bytes"] for h in res.history]}
        emit_csv_row(f"fig_compress/{name}/{label}", t * 1e6,
                     f"rel_opt={entry['rel_opt']:.4f},"
                     f"bytes={entry['comm_bytes_per_step']}")
    return cells, curves, samples


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--codecs", default="none,identity,int8,fp8,topk:0.25",
                    help="comma-separated codec grid ('none' = "
                         "compression disabled entirely)")
    ap.add_argument("--solvers", default="d3ca,radisa,admm")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    codecs = [c.strip() for c in args.codecs.split(",") if c.strip()]

    P, Q = 4, 2
    n, m = (256, 96) if args.quick else (768, 256)
    inner = 32 if args.quick else 96
    iters = 6 if args.quick else 12
    lam = 1e-1
    X, y = make_svm_data(n, m, seed=0)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=100)
    f_star = float(objective("hinge", X, y, w_ref, lam))

    configs = {
        "d3ca": D3CAConfig(lam=lam, outer_iters=iters, local_steps=inner),
        "radisa": RADiSAConfig(lam=lam, gamma=0.05, outer_iters=iters,
                               L=inner),
        "admm": ADMMConfig(lam=lam, rho=lam, outer_iters=iters),
    }

    # land the rows in BENCH_core.json next to the core grid (fresh
    # payload when core_bench has not run in this checkout)
    if os.path.exists(args.out):
        with open(args.out) as fh:
            payload = json.load(fh)
    else:
        payload = {"cells": {}, "ratios": {}}
    payload.setdefault("cells", {})
    payload["compress_sweep"] = {"codecs": codecs, "n": n, "m": m,
                                 "P": P, "Q": Q, "lam": lam, "iters": iters,
                                 "backend": args.backend, "curves": {}}
    payload["provenance"] = provenance(args.quick)

    all_samples = []
    for name in args.solvers.split(","):
        cells, curves, samples = sweep_solver(name, configs[name], X, y,
                                              P, Q, codecs, args.backend,
                                              f_star, args.reps)
        payload["cells"].update(cells)
        payload["compress_sweep"]["curves"][name] = curves
        all_samples.extend(samples)
        # headline contract: int8 cuts the reported reduction bytes
        # >= 3x vs float32 (int8 payload + one f32 scale per collective)
        none_cell = cells.get(f"{name}/compress/{args.backend}/none")
        int8_cell = cells.get(f"{name}/compress/{args.backend}/int8")
        if none_cell and int8_cell:
            ratio = (none_cell["comm_bytes_per_step"]
                     / int8_cell["comm_bytes_per_step"])
            payload.setdefault("ratios", {})[
                f"{name}/compress/int8_bytes_cut"] = ratio
            assert ratio >= 3.0, (
                f"{name}: int8 cut reduction bytes only {ratio:.2f}x "
                "(expected >= 3x vs float32)")

    if all_samples:
        payload["compress_sweep"]["wire_model"] = annotate_wire_predictions(
            payload["cells"], all_samples)

    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[fig_compress] wrote {args.out} "
          f"({len(codecs)} codecs x {len(args.solvers.split(','))} solvers)")
    return payload


if __name__ == "__main__":
    main()
