"""Fleet benchmark: batched multi-tenant solves vs a sequential loop.

Measures the point of ``repro.fleet``: T independent tenant problems
solved as ONE vmapped program (one compiled step, one collective round
shared by all tenants) against the best sequential alternative -- a
solo :class:`~repro.core.solver.Solver` with its compiled-program
cache on, so the loop pays trace/compile once and the comparison
isolates per-solve dispatch + drive-loop overhead, not compilation.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--quick]

Emits ``BENCH_fleet.json`` (repo root by default):

  * ``cells`` -- one per (solver, engine, tenant count) with
    ``s_per_iter`` (fleet seconds per outer iteration over the whole
    batch, the field the regression gate keys on), fleet and
    sequential solves/s, and the speedup ratio;
  * a provenance stamp so ``benchmarks.check_regression`` can gate the
    quick cells against ``benchmarks/baselines/BENCH_fleet_quick.json``.

The quick 32-tenant cell doubles as the PR acceptance check: fleet
solves/s must be >= 3x the sequential loop.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core import D3CAConfig, get_solver  # noqa: E402
from repro.data import make_svm_data  # noqa: E402
from repro.fleet import FleetProblem, FleetSolver, solo_config  # noqa: E402

try:
    from .common import provenance, save_result
except ImportError:                     # `python benchmarks/fleet_bench.py`
    from common import provenance, save_result


def make_problems(T, n, m, loss="hinge", lam=0.5):
    """T tenants, one shape bucket, one shared lam (so the sequential
    baseline's program cache gets its best case: a single trace)."""
    probs = []
    for i in range(T):
        X, y = make_svm_data(n, m, seed=100 + i)
        probs.append(FleetProblem(tenant_id=f"t{i}", loss_name=loss,
                                  X=X, y=y, lam=lam, seed=i))
    return probs


def bench_cell(*, solver, engine, T, n, m, P, Q, cfg, reps):
    probs = make_problems(T, n, m)
    fleet = FleetSolver(solver=solver, engine=engine)

    def fleet_once():
        return fleet.solve_batch(probs, P=P, Q=Q, cfg=cfg,
                                 record_history=False)

    fleet_once()                                    # compile warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        fleet_once()
    fleet_s = (time.perf_counter() - t0) / reps

    solo = get_solver(solver)(engine=engine, program_cache=True)

    def solo_loop():
        return [solo.solve(p.loss_name, p.X, p.y, P=P, Q=Q,
                           cfg=solo_config(cfg, p), record_history=False)
                for p in probs]

    solo_loop()                                     # compile warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        solo_loop()
    seq_s = (time.perf_counter() - t0) / reps

    return {
        "s_per_iter": fleet_s / cfg.outer_iters,
        "tenants": T,
        "outer_iters": cfg.outer_iters,
        "fleet_s": fleet_s,
        "sequential_s": seq_s,
        "fleet_solves_per_s": T / fleet_s,
        "sequential_solves_per_s": T / seq_s,
        "speedup": seq_s / fleet_s,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small instance + fewer reps (the CI gate "
                         "compares quick runs only)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_fleet.json"))
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map"])
    args = ap.parse_args(argv)

    if args.quick:
        n, m, iters, reps = 64, 24, 6, 2
        grid = [("d3ca", 8), ("d3ca", 32), ("radisa", 8)]
    else:
        n, m, iters, reps = 256, 64, 10, 3
        grid = [("d3ca", 8), ("d3ca", 32), ("d3ca", 128), ("radisa", 32)]
    P, Q = 2, 2

    cells = {}
    for solver, T in grid:
        if solver == "d3ca":
            cfg = D3CAConfig(lam=0.5, local_steps=8, outer_iters=iters)
        else:
            cfg = get_solver(solver).config_cls(
                lam=0.5, gamma=0.125, L=8, outer_iters=iters)
        key = f"{solver}/{args.engine}/T{T}"
        cell = bench_cell(solver=solver, engine=args.engine, T=T, n=n,
                          m=m, P=P, Q=Q, cfg=cfg, reps=reps)
        cells[key] = cell
        print(f"{key}: fleet {cell['fleet_solves_per_s']:.1f} solves/s "
              f"vs sequential {cell['sequential_solves_per_s']:.1f} "
              f"({cell['speedup']:.1f}x)")

    out = {
        "n": n, "m": m, "P": P, "Q": Q, "outer_iters": iters,
        "reps": reps,
        "note": "s_per_iter = fleet seconds per outer iteration over "
                "the whole tenant batch; speedup = sequential loop "
                "(program-cached solo solver) over fleet, same "
                "problems",
        "provenance": provenance(args.quick),
        "cells": cells,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=1)
    save_result("BENCH_fleet", out)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
