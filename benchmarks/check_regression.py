"""CI bench-regression gate over ``BENCH_core.json``.

Compares a freshly produced core-solver benchmark against the committed
baseline (``benchmarks/baselines/BENCH_core_quick.json``) and fails --
exit code 1 -- when any (solver, engine, backend[, sparse]) cell got
more than ``--threshold`` slower.  Runs in the CI ``bench`` job after
the artifact upload, so the numbers are preserved even when the gate
trips.

    PYTHONPATH=src python -m benchmarks.check_regression \\
        [--fresh BENCH_core.json] [--baseline benchmarks/baselines/...]
        [--threshold 1.25]

The baseline is committed from whatever machine produced it, and CI
runners are a different (and varying) machine, so raw wall-clock ratios
would trip on hardware alone.  The gate therefore compares
**host-normalized** ratios: each payload's cells are divided by that
payload's median s_per_iter over the cells both sides share, cancelling
uniform machine-speed factors; what remains is the *relative* cost of a
cell within the grid, which is what a code regression moves.  (The
tradeoff: a regression that slows every cell by the same factor is
indistinguishable from a slower runner -- the raw median shift is
printed so humans can spot that case.)

Provenance rules (the stamps written by ``benchmarks.common.provenance``):
  * both payloads must carry a provenance block;
  * both must be ``--quick`` runs -- full-size and quick numbers are not
    comparable, so a mismatch is an error, not a silent pass;
  * cells present on only one side are reported but never fail the gate
    (new cells appear whenever the grid grows; the baseline is refreshed
    by re-running ``core_bench --quick`` and committing the JSON).

Speedups beyond the inverse threshold are reported too, as a nudge to
refresh the baseline so the gate keeps teeth.

Besides raw speed, the gate also watches the **exposed communication
share** of every cell that carries the telemetry per-phase fields: the
fraction of a step the wire actually adds to the critical path
(``comm_exposed_s / step_s`` for overlap-engine cells -- hidden comm is
free -- and ``comm_s / step_s`` elsewhere).  A cell whose normalized
exposed share grows more than ``--comm-threshold`` (default the same
1.25x) fails the gate: that is a communication regression even when the
total step time moved little.  Shares below a small absolute floor on
both sides are skipped (pure timing noise on comm-free quick cells).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines",
    "BENCH_core_quick.json")


def load(path):
    with open(path) as fh:
        return json.load(fh)


def validate_payload(payload, name: str):
    """Structural check of one BENCH_core payload.  Returns a list of
    per-key failure messages naming the payload and the missing field --
    a malformed baseline/fresh file must fail the gate with an
    actionable message, never a bare KeyError."""
    errs = []
    if not isinstance(payload, dict):
        return [f"{name} payload is {type(payload).__name__}, not a JSON "
                "object; re-run benchmarks.core_bench"]
    cells = payload.get("cells")
    if not isinstance(cells, dict):
        return [f"{name} payload field 'cells' is "
                f"{'missing' if cells is None else type(cells).__name__}; "
                "expected a dict of benchmark cells (re-run "
                "benchmarks.core_bench)"]
    for key, cell in sorted(cells.items()):
        if not isinstance(cell, dict):
            errs.append(f"{name} payload cell {key!r} is "
                        f"{type(cell).__name__}, not a dict")
        elif "s_per_iter" not in cell:
            errs.append(f"{name} payload cell {key!r} is missing "
                        f"'s_per_iter' (has: {sorted(cell) or 'nothing'})")
        elif not isinstance(cell["s_per_iter"], (int, float)):
            errs.append(f"{name} payload cell {key!r} has non-numeric "
                        f"s_per_iter={cell['s_per_iter']!r}")
    return errs


def phase_line(payload: dict, name: str):
    """One-line local/comm/host breakdown over the cells that carry the
    telemetry subsystem's per-phase fields (older payloads have none --
    return None and print nothing rather than fail validation)."""
    cells = payload.get("cells") or {}
    ph = [c for c in cells.values() if isinstance(c, dict)
          and all(k in c for k in ("step_s", "local_s", "comm_s", "host_s"))]
    if not ph:
        return None
    tot = sum(c["step_s"] + c["host_s"] for c in ph)
    if tot <= 0:
        return None
    loc = sum(c["local_s"] for c in ph)
    com = sum(c["comm_s"] for c in ph)
    hst = sum(c["host_s"] for c in ph)
    return (f"  {name} phases: local {100 * loc / tot:.1f}% / "
            f"comm {100 * com / tot:.1f}% / host {100 * hst / tot:.1f}% "
            f"(mean per-iter over {len(ph)} cells)")


#: exposed-comm shares below this on both sides are timing noise, not
#: signal -- quick-grid cells move sub-millisecond payloads
SHARE_FLOOR = 0.02


def exposed_share(cell) -> float | None:
    """Fraction of a step's wall-clock the wire adds to the critical
    path.  Overlap-engine cells report ``comm_exposed_s`` (hidden comm
    runs under the local solve and costs nothing); everything else
    exposes all of ``comm_s``."""
    if not isinstance(cell, dict):
        return None
    step = cell.get("step_s")
    if not isinstance(step, (int, float)) or step <= 0:
        return None
    if "comm_exposed_s" in cell:
        return float(cell["comm_exposed_s"]) / step
    if "comm_s" in cell:
        return float(cell["comm_s"]) / step
    return None


def compare_comm_shares(fcells, bcells, shared, comm_threshold,
                        report=None):
    """Exposed-comm-share gate (see module docstring).  Returns
    (failures, report_lines); when ``report`` is given, per-cell share
    ratios land under ``report["comm_shares"]``."""
    failures, lines = [], []
    shares = {} if report is None else report.setdefault("comm_shares", {})
    pairs = {}
    for key in shared:
        fs, bs = exposed_share(fcells[key]), exposed_share(bcells[key])
        if fs is not None and bs is not None:
            pairs[key] = (fs, bs)
    if not pairs:
        return failures, lines

    def median(xs):
        xs = sorted(xs)
        mid = len(xs) // 2
        return xs[mid] if len(xs) % 2 else (xs[mid - 1] + xs[mid]) / 2.0

    # comm shares are within-step ratios, but compute and wire speed
    # scale differently across hosts -- normalize by each payload's
    # median share like the wall-clock gate normalizes s_per_iter
    med_f = median([fs for fs, _ in pairs.values()])
    med_b = median([bs for _, bs in pairs.values()])
    lines.append(f"  exposed comm share (median over {len(pairs)} phased "
                 f"cells): baseline {100 * med_b:.1f}%, fresh "
                 f"{100 * med_f:.1f}%")
    for key, (fs, bs) in sorted(pairs.items()):
        if fs < SHARE_FLOOR and bs < SHARE_FLOOR:
            continue                       # comm-free cell, pure noise
        fn = fs / med_f if med_f > SHARE_FLOOR else fs
        bn = bs / med_b if med_b > SHARE_FLOOR else bs
        if bn <= 0:
            lines.append(f"  {key}: exposed comm share "
                         f"0% -> {100 * fs:.1f}% (no baseline share)")
            continue
        ratio = fn / bn
        verdict = "ok"
        if ratio > comm_threshold:
            verdict = "COMM REGRESSION"
            failures.append(
                f"{key}: exposed comm share {100 * bs:.1f}% -> "
                f"{100 * fs:.1f}% of step "
                f"({ratio:.2f}x normalized > {comm_threshold:.2f}x)")
        shares[key] = {"baseline_share": bs, "fresh_share": fs,
                       "ratio": ratio,
                       "ok": verdict == "ok"}
        lines.append(f"  {key}: exposed comm {100 * bs:.1f}% -> "
                     f"{100 * fs:.1f}% ({ratio:.2f}x {verdict})")
    return failures, lines


def compare(fresh: dict, baseline: dict, threshold: float,
            comm_threshold: float | None = None):
    """Returns (failures, report_lines, report) where ``report`` is the
    machine-readable summary ``--json`` emits: per-cell normalized
    ratios + verdicts, the comm-share gate's shares, the payload
    medians, and this payload's pass/fail."""
    lines = []
    failures = []
    report = {"threshold": threshold, "cells": {}}

    def done():
        report["failures"] = list(failures)
        report["pass"] = not failures
        return failures, lines, report

    for payload, name in ((fresh, "fresh"), (baseline, "baseline")):
        failures.extend(validate_payload(payload, name))
    if failures:
        return done()
    for payload, name in ((fresh, "fresh"), (baseline, "baseline")):
        prov = payload.get("provenance")
        if not prov:
            failures.append(f"{name} payload has no provenance stamp; "
                            "re-run benchmarks.core_bench")
            return done()
        if not prov.get("quick"):
            failures.append(
                f"{name} payload is not a --quick run "
                f"(git_sha={prov.get('git_sha', '?')[:12]}); the gate only "
                "compares quick grids")
            return done()
    report["baseline_sha"] = baseline["provenance"].get("git_sha")

    fcells = fresh.get("cells", {})
    bcells = baseline.get("cells", {})
    shared = sorted(set(fcells) & set(bcells))
    if not shared:
        failures.append("no cells shared between fresh and baseline")
        return done()

    def median(xs):
        xs = sorted(xs)
        mid = len(xs) // 2
        return xs[mid] if len(xs) % 2 else (xs[mid - 1] + xs[mid]) / 2.0

    # cancel uniform machine-speed factors: compare each cell's share of
    # its own payload's median, not raw wall clock (see module docstring)
    med_f = median([fcells[k]["s_per_iter"] for k in shared])
    med_b = median([bcells[k]["s_per_iter"] for k in shared])
    report["median_s_per_iter"] = {"fresh": med_f, "baseline": med_b}
    lines.append(f"  host speed (median s_per_iter): baseline "
                 f"{med_b * 1e3:.2f} ms, fresh {med_f * 1e3:.2f} ms "
                 f"({med_f / med_b:.2f}x raw -- normalized out below)")
    for payload, name in ((fresh, "fresh"), (baseline, "baseline")):
        pl = phase_line(payload, name)
        if pl:
            lines.append(pl)

    for key in sorted(set(fcells) | set(bcells)):
        f, b = fcells.get(key), bcells.get(key)
        if f is None:
            report["cells"][key] = {"status": "baseline_only"}
            lines.append(f"  {key}: only in baseline (grid shrank?)")
            continue
        if b is None:
            report["cells"][key] = {
                "status": "new", "fresh_s_per_iter": f["s_per_iter"]}
            lines.append(f"  {key}: new cell {f['s_per_iter'] * 1e3:.2f} ms "
                         "(no baseline yet)")
            continue
        ratio = (f["s_per_iter"] / med_f) / (b["s_per_iter"] / med_b)
        verdict = "ok"
        if ratio > threshold:
            verdict = "REGRESSION"
            failures.append(
                f"{key}: {b['s_per_iter'] * 1e3:.2f} -> "
                f"{f['s_per_iter'] * 1e3:.2f} ms per iter "
                f"({ratio:.2f}x normalized > {threshold:.2f}x)")
        elif ratio < 1.0 / threshold:
            verdict = "faster (consider refreshing the baseline)"
        report["cells"][key] = {
            "status": "regression" if ratio > threshold else "ok",
            "ratio": ratio,
            "fresh_s_per_iter": f["s_per_iter"],
            "baseline_s_per_iter": b["s_per_iter"]}
        lines.append(f"  {key}: {ratio:.2f}x {verdict}")

    cfails, clines = compare_comm_shares(
        fcells, bcells, shared,
        threshold if comm_threshold is None else comm_threshold,
        report=report)
    failures.extend(cfails)
    lines.extend(clines)
    return done()


DEFAULT_ONLINE_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines",
    "BENCH_online_quick.json")

DEFAULT_FLEET_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines",
    "BENCH_fleet_quick.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when fresh/baseline s_per_iter exceeds this")
    ap.add_argument("--comm-threshold", type=float, default=None,
                    help="fail when a cell's normalized exposed-comm "
                         "share grows beyond this (default: --threshold)")
    ap.add_argument("--online-fresh",
                    default=os.path.join(ROOT, "BENCH_online.json"),
                    help="benchmarks.online_bench --quick payload; gated "
                         "against --online-baseline when the file exists "
                         "(skipped with a note otherwise, so the core "
                         "gate keeps working standalone)")
    ap.add_argument("--online-baseline", default=DEFAULT_ONLINE_BASELINE)
    ap.add_argument("--fleet-fresh",
                    default=os.path.join(ROOT, "BENCH_fleet.json"),
                    help="benchmarks.fleet_bench --quick payload; gated "
                         "against --fleet-baseline when the file exists "
                         "(skipped with a note otherwise)")
    ap.add_argument("--fleet-baseline", default=DEFAULT_FLEET_BASELINE)
    ap.add_argument("--fleet-min-speedup", type=float, default=3.0,
                    help="fail when the largest fleet cell's batched-vs-"
                         "sequential solves/s ratio drops below this")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    dest="json_out",
                    help="write a machine-readable summary here: "
                         "per-payload pass/fail, per-cell normalized "
                         "ratios, comm shares, and the failure list "
                         "(the CI bench job annotates runs from it)")
    args = ap.parse_args(argv)

    fresh = load(args.fresh)
    baseline = load(args.baseline)
    failures, lines, core_report = compare(
        fresh, baseline, args.threshold,
        comm_threshold=args.comm_threshold)
    reports = {"core": core_report}

    print(f"[check_regression] fresh={args.fresh}")
    print(f"[check_regression] baseline={args.baseline} "
          f"(sha {baseline.get('provenance', {}).get('git_sha', '?')[:12]},"
          f" {baseline.get('provenance', {}).get('date', '?')})")
    for line in lines:
        print(line)

    # online-service gate: same normalized-ratio machinery over the
    # online_bench quick cells (s_per_iter = seconds per update pass)
    if os.path.exists(args.online_fresh):
        ofresh = load(args.online_fresh)
        obase = load(args.online_baseline)
        ofails, olines, oreport = compare(
            ofresh, obase, args.threshold,
            comm_threshold=args.comm_threshold)
        failures.extend(f"[online] {f}" for f in ofails)
        reports["online"] = oreport
        print(f"[check_regression] online fresh={args.online_fresh} "
              f"baseline={args.online_baseline}")
        for line in olines:
            print(line)
    else:
        reports["online"] = {"status": "skipped",
                             "reason": f"no {args.online_fresh}"}
        print(f"[check_regression] online: no {args.online_fresh}; "
              "skipping the online-service gate (run "
              "benchmarks.online_bench --quick to produce it)")

    # fleet gate: the same normalized-ratio machinery over the
    # fleet_bench quick cells (s_per_iter = fleet seconds per outer
    # iteration over the whole batch), plus an absolute floor on the
    # batched-vs-sequential speedup -- the subsystem's reason to exist
    if os.path.exists(args.fleet_fresh):
        ffresh = load(args.fleet_fresh)
        fbase = load(args.fleet_baseline)
        ffails, flines, freport = compare(
            ffresh, fbase, args.threshold,
            comm_threshold=args.comm_threshold)
        failures.extend(f"[fleet] {f}" for f in ffails)
        reports["fleet"] = freport
        print(f"[check_regression] fleet fresh={args.fleet_fresh} "
              f"baseline={args.fleet_baseline}")
        for line in flines:
            print(line)
        big = max(ffresh.get("cells", {}).values(),
                  key=lambda c: c.get("tenants", 0), default=None)
        if big is not None and "speedup" in big:
            line = (f"  fleet speedup at T={big['tenants']}: "
                    f"{big['speedup']:.2f}x batched vs sequential")
            freport["speedup"] = {"tenants": big["tenants"],
                                  "value": big["speedup"],
                                  "floor": args.fleet_min_speedup,
                                  "ok": big["speedup"]
                                  >= args.fleet_min_speedup}
            if big["speedup"] < args.fleet_min_speedup:
                failures.append(
                    f"[fleet] speedup {big['speedup']:.2f}x at "
                    f"T={big['tenants']} below the "
                    f"{args.fleet_min_speedup:.1f}x floor")
                freport["pass"] = False
                freport["failures"].append(failures[-1])
                line += f" (< {args.fleet_min_speedup:.1f}x FLOOR)"
            print(line)
    else:
        reports["fleet"] = {"status": "skipped",
                            "reason": f"no {args.fleet_fresh}"}
        print(f"[check_regression] fleet: no {args.fleet_fresh}; "
              "skipping the fleet gate (run benchmarks.fleet_bench "
              "--quick to produce it)")

    if args.json_out:
        summary = {"pass": not failures, "failures": failures,
                   "threshold": args.threshold, "payloads": reports}
        with open(args.json_out, "w") as fh:
            json.dump(summary, fh, indent=1)
        print(f"[check_regression] json -> {args.json_out}")

    if failures:
        print(f"[check_regression] FAIL ({len(failures)}):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("[check_regression] PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
