"""Render experiments/dryrun/*.json into the §Dry-run markdown table
(experiments/dryrun/summary.md): per cell and mesh, status, FLOPs, HBM
bytes, wire bytes, and per-device memory (args+temp vs the 16 GB budget).
"""
from __future__ import annotations

import argparse
import json
import os

DRY_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "experiments", "dryrun"))


def main(argv=None):
    argparse.ArgumentParser().parse_args(argv)
    rows = []
    for fn in sorted(os.listdir(DRY_DIR)):
        if not fn.endswith(".json") or "calib" in fn:
            continue
        with open(os.path.join(DRY_DIR, fn)) as fh:
            d = json.load(fh)
        if d.get("kind") == "paper":
            f = d["full"]
            mem = f.get("memory", {})
            rows.append((d["arch"], d["shape"], d["mesh"], "ok",
                         f["flops"], f["bytes_accessed"],
                         f["collectives"]["total_bytes"], mem))
            continue
        if d.get("status") == "skipped":
            rows.append((d["arch"], d["shape"], d["mesh"], "skipped",
                         0, 0, 0, {}))
            continue
        rows.append((d["arch"], d["shape"], d["mesh"], "ok",
                     d["flops"], d["bytes_accessed"],
                     d["collectives"]["total_bytes"], d.get("memory", {})))

    md = ["| arch | shape | mesh | status | GFLOP/dev | HBM GB/dev "
          "| wire GB/dev | mem GB/dev (args+temp) |",
          "|---|---|---|---|---|---|---|---|"]
    for a, s, m, st, fl, by, wi, mem in rows:
        if st == "skipped":
            md.append(f"| {a} | {s} | {m} | skipped | -- | -- | -- | -- |")
            continue
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 2**30
        fit = "" if gb <= 15.5 else " **OVER**"
        md.append(f"| {a} | {s} | {m} | ok | {fl/1e9:.1f} | {by/1e9:.1f} | "
                  f"{wi/1e9:.3f} | {gb:.2f}{fit} |")
    out = "\n".join(md) + "\n"
    path = os.path.join(DRY_DIR, "summary.md")
    with open(path, "w") as fh:
        fh.write(out)
    print(out)
    print(f"-> {path}")


if __name__ == "__main__":
    main()
