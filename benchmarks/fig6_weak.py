"""Paper Figure 6: weak scaling.  Per-processor workload constant
(block 40,000 x 5,000 scaled by --scale); P grows 1..7 for Q in {2,3,4}
and two sparsity levels; efficiency = t(P=1) / t(P).  Runs through the
unified solver API (any engine x backend x block format).

``--profile news20`` (or realsim) swaps the synthetic blocks for a
paper-scale stand-in of the real dataset: block sizes chosen so that at
``--scale 1.0`` the largest grid reaches the dataset's true (n, m) at
its true density (~0.034% for news20), generated directly as CSR and
solved with ``block_format="sparse"`` -- a dense news20 grid would need
~100 GB, so the profile forces the sparse path and times outer
iterations instead of time-to-tolerance (no dense serial reference at
this scale).  The default ``--scale 0.01`` is a smoke-test size; the
payload records ``scale`` and the effective per-grid (n, m) so scaled
runs are never mistaken for paper-scale ones.
"""
from __future__ import annotations

import argparse
import sys

from .common import add_engine_args, emit_csv_row, ensure_host_devices, \
    save_result

ensure_host_devices(sys.argv)

from repro.configs.svm_paper import (REAL_DATASETS, WEAK_P, WEAK_Q,  # noqa: E402,E501
                                     WEAK_SPARSITY, synthetic_profile)
from repro.core import (D3CAConfig, RADiSAConfig, get_solver,  # noqa: E402
                        objective, serial_sdca)
from repro.data import make_sparse_svm_csr, make_sparse_svm_data  # noqa: E402


def time_to_tol(solver, X, y, P, Q, cfg, f_star, tol=0.05):
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       tol=tol)
    hit = next((h for h in res.history if h["rel_opt"] < tol), None)
    return (hit or res.history[-1])["time_s"]


def time_iters(solver, X, y, P, Q, cfg):
    """Wall time of ``cfg.outer_iters`` outer iterations (history off --
    at news20 scale the per-iter objective pass would dominate)."""
    import time

    import jax
    prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
    state = prog.step(1, prog.state)            # compile + warm
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for t in range(2, cfg.outer_iters + 2):
        state = prog.step(t, state)
    jax.block_until_ready(state)
    return time.perf_counter() - t0


def run_grid(args, make_data, sparsities, out):
    """Shared weak-scaling sweep; ``make_data(P, Q, r) -> (X, y, bn, bm)``."""
    for r in sparsities:
        for Q in WEAK_Q[:2] if args.max_p < 7 else WEAK_Q:
            base = {}
            for P in [p for p in WEAK_P if p <= args.max_p]:
                X, y, n, m = make_data(P, Q, r)
                for method, lam in (("radisa", 0.1), ("d3ca", 1.0)):
                    solver = get_solver(method)(
                        engine=args.engine, local_backend=args.backend,
                        block_format=args.block_format,
                        staleness=args.staleness,
                        compression=args.compression)
                    if method == "radisa":
                        cfg = RADiSAConfig(lam=lam, gamma=0.05 / P,
                                           outer_iters=args.iters)
                    else:
                        cfg = D3CAConfig(lam=lam, outer_iters=args.iters)
                    if args.profile:
                        t = time_iters(solver, X, y, P, Q, cfg)
                    else:
                        w_ref, _ = serial_sdca("hinge", X, y, lam=lam,
                                               epochs=60)
                        f_star = float(objective("hinge", X, y, w_ref, lam))
                        t = time_to_tol(solver, X, y, P, Q, cfg, f_star)
                    kk = f"{method}_r{r}_Q{Q}"
                    base.setdefault(kk, {})
                    base[kk][P] = t
                    eff = base[kk][min(base[kk])] / t * 100.0
                    emit_csv_row(f"fig6/{kk}/P{P}", t * 1e6,
                                 f"efficiency={eff:.1f}%")
            out.update(base)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--max-p", type=int, default=4)
    ap.add_argument("--profile", default=None,
                    choices=sorted(REAL_DATASETS),
                    help="paper-scale synthetic stand-in for a real "
                         "dataset (forces --block-format sparse)")
    add_engine_args(ap)
    args = ap.parse_args(argv)

    out = {"engine": args.engine, "backend": args.backend,
           "block_format": args.block_format, "profile": args.profile,
           "scale": args.scale}

    if args.profile:
        args.block_format = "sparse"    # dense cells cannot hold news20
        out["block_format"] = "sparse"
        out["profile_full_size"] = REAL_DATASETS[args.profile]
        out["grid_sizes"] = {}          # label -> effective (n, m) per P
        if args.scale != 1.0:
            print(f"[fig6] NOTE: --scale {args.scale} shrinks the "
                  f"{args.profile} profile blocks by that factor; pass "
                  "--scale 1.0 for true paper-scale runs", file=sys.stderr)

        def make_data(P, Q, r):
            bn, bm, density = synthetic_profile(args.profile, args.max_p, Q)
            bn, bm = max(int(bn * args.scale), 8), max(int(bm * args.scale), 8)
            n, m = P * bn, Q * bm
            out["grid_sizes"][f"Q{Q}_P{P}"] = [n, m]
            X, y = make_sparse_svm_csr(n, m, density=density, seed=P)
            return X, y, n, m

        sparsities = [REAL_DATASETS[args.profile]["density"]]
    else:
        def make_data(P, Q, r):
            bn, bm = int(40000 * args.scale), int(5000 * args.scale)
            n, m = P * bn, Q * bm
            X, y = make_sparse_svm_data(n, m, density=max(r, 0.05), seed=P)
            return X, y, n, m

        sparsities = WEAK_SPARSITY

    out = run_grid(args, make_data, sparsities, out)
    save_result("fig6_weak", out)


if __name__ == "__main__":
    main()
