"""Paper Figure 6: weak scaling.  Per-processor workload constant
(block 40,000 x 5,000 scaled by --scale); P grows 1..7 for Q in {2,3,4}
and two sparsity levels; efficiency = t(P=1) / t(P).  Runs through the
unified solver API (any engine x backend)."""
from __future__ import annotations

import argparse
import sys

from .common import add_engine_args, emit_csv_row, ensure_host_devices, \
    save_result

ensure_host_devices(sys.argv)

from repro.configs.svm_paper import WEAK_P, WEAK_Q, WEAK_SPARSITY  # noqa: E402
from repro.core import (D3CAConfig, RADiSAConfig, get_solver,  # noqa: E402
                        objective, serial_sdca)
from repro.data import make_sparse_svm_data                 # noqa: E402


def time_to_tol(solver, X, y, P, Q, cfg, f_star, tol=0.05):
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       tol=tol)
    hit = next((h for h in res.history if h["rel_opt"] < tol), None)
    return (hit or res.history[-1])["time_s"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--max-p", type=int, default=4)
    add_engine_args(ap)
    args = ap.parse_args(argv)

    bn, bm = int(40000 * args.scale), int(5000 * args.scale)
    out = {"engine": args.engine, "backend": args.backend}
    for r in WEAK_SPARSITY:
        for Q in WEAK_Q[:2] if args.max_p < 7 else WEAK_Q:
            base = {}
            for P in [p for p in WEAK_P if p <= args.max_p]:
                n, m = P * bn, Q * bm
                X, y = make_sparse_svm_data(n, m, density=max(r, 0.05),
                                            seed=P)
                for method, lam in (("radisa", 0.1), ("d3ca", 1.0)):
                    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=60)
                    f_star = float(objective("hinge", X, y, w_ref, lam))
                    solver = get_solver(method)(engine=args.engine,
                                                local_backend=args.backend)
                    if method == "radisa":
                        cfg = RADiSAConfig(lam=lam, gamma=0.05 / P,
                                           outer_iters=args.iters)
                    else:
                        cfg = D3CAConfig(lam=lam, outer_iters=args.iters)
                    t = time_to_tol(solver, X, y, P, Q, cfg, f_star)
                    kk = f"{method}_r{r}_Q{Q}"
                    base.setdefault(kk, {})
                    base[kk][P] = t
                    eff = base[kk][min(base[kk])] / t * 100.0
                    emit_csv_row(f"fig6/{kk}/P{P}", t * 1e6,
                                 f"efficiency={eff:.1f}%")
            out.update(base)
    save_result("fig6_weak", out)


if __name__ == "__main__":
    main()
