"""Paper Figure 6: weak scaling.  Per-processor workload constant
(block 40,000 x 5,000 scaled by --scale); P grows 1..7 for Q in {2,3,4}
and two sparsity levels; efficiency = t(P=1) / t(P)."""
from __future__ import annotations

import argparse
import time

from repro.configs.svm_paper import WEAK_P, WEAK_Q, WEAK_SPARSITY
from repro.core import (D3CAConfig, RADiSAConfig, d3ca_simulated, objective,
                        partition, radisa_simulated, rel_opt, serial_sdca)
from repro.data import make_sparse_svm_data

from .common import emit_csv_row, save_result


def time_to_tol(runner, f, f_star, tol=0.05):
    t0 = time.perf_counter()
    done = {}

    def cb(t, w, *rest):
        if "t" not in done and float(rel_opt(f(w), f_star)) < tol:
            done["t"] = time.perf_counter() - t0
    runner(cb)
    return done.get("t", time.perf_counter() - t0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--max-p", type=int, default=4)
    args = ap.parse_args(argv)

    bn, bm = int(40000 * args.scale), int(5000 * args.scale)
    out = {}
    for r in WEAK_SPARSITY:
        for Q in WEAK_Q[:2] if args.max_p < 7 else WEAK_Q:
            base = {}
            for P in [p for p in WEAK_P if p <= args.max_p]:
                n, m = P * bn, Q * bm
                X, y = make_sparse_svm_data(n, m, density=max(r, 0.05),
                                            seed=P)
                for method, lam in (("radisa", 0.1), ("d3ca", 1.0)):
                    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=60)
                    f_star = float(objective("hinge", X, y, w_ref, lam))
                    f = lambda w: float(objective("hinge", X, y, w, lam))
                    data = partition(X, y, P, Q)
                    if method == "radisa":
                        if data.m_q % P:
                            continue
                        runner = lambda cb: radisa_simulated(
                            "hinge", data, RADiSAConfig(
                                lam=lam, gamma=0.05 / P,
                                outer_iters=args.iters), callback=cb)
                    else:
                        runner = lambda cb: d3ca_simulated(
                            "hinge", data, D3CAConfig(
                                lam=lam, outer_iters=args.iters), callback=cb)
                    t = time_to_tol(runner, f, f_star)
                    kk = f"{method}_r{r}_Q{Q}"
                    base.setdefault(kk, {})
                    base[kk][P] = t
                    eff = base[kk][min(base[kk])] / t * 100.0
                    emit_csv_row(f"fig6/{kk}/P{P}", t * 1e6,
                                 f"efficiency={eff:.1f}%")
            out.update(base)
    save_result("fig6_weak", out)


if __name__ == "__main__":
    main()
