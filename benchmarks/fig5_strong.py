"""Paper Figure 5: strong scaling.  Fixed problem; grow K = P*Q through the
partition ladder and measure time (and iterations) to reach 1% relative
optimality.  Data sets shaped like realsim / news20 (synthetic sparse
stand-ins: the LIBSVM originals are not redistributable offline; identical
dimensions & sparsity).

Reproduces the paper's qualitative findings: RADiSA prefers P > Q, D3CA
prefers Q > P; more partitions help the larger data set.
"""
from __future__ import annotations

import argparse
import time

from repro.configs.svm_paper import STRONG_CONFIGS
from repro.core import (D3CAConfig, RADiSAConfig, d3ca_simulated, objective,
                        partition, radisa_simulated, rel_opt, serial_sdca)
from repro.data import make_sparse_svm_data

from .common import emit_csv_row, save_result

DATASETS = {
    # name: (n, m, density)  -- paper Table II, scaled for CPU by --scale
    "realsim": (72309, 20958, 0.0024),
    "news20": (19996, 135519, 0.0003),   # m scaled 10x down to bound memory
}


def time_to_tol(runner, f, f_star, tol):
    hist = []
    t0 = time.perf_counter()
    done = {}

    def cb(t, w, *rest):
        ro = float(rel_opt(f(w), f_star))
        hist.append(ro)
        if ro < tol and "t" not in done:
            done["t"] = time.perf_counter() - t0
            done["iters"] = t
    runner(cb)
    done.setdefault("t", time.perf_counter() - t0)
    done.setdefault("iters", len(hist))
    done["final"] = hist[-1] if hist else float("inf")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--tol", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=25)
    args = ap.parse_args(argv)

    out = {}
    for ds, (n, m, dens) in DATASETS.items():
        n, m = int(n * args.scale), int(m * args.scale)
        X, y = make_sparse_svm_data(n, m, density=max(dens, 0.01), seed=0)
        res = {}
        # paper: lam=1e-3 for RADiSA, 1e-2 for D3CA
        for method, lam in (("radisa", 1e-3), ("d3ca", 1e-2)):
            w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=200)
            f_star = float(objective("hinge", X, y, w_ref, lam))
            f = lambda w: float(objective("hinge", X, y, w, lam))
            for (P, Q) in STRONG_CONFIGS:
                data = partition(X, y, P, Q)
                if method == "radisa":
                    if data.m_q % P:
                        continue
                    # keep total processed points constant as K grows
                    L = max(1, data.n_p // 2)
                    runner = lambda cb: radisa_simulated(
                        "hinge", data, RADiSAConfig(
                            lam=lam, gamma=0.05 / P, L=L,
                            outer_iters=args.iters), callback=cb)
                else:
                    runner = lambda cb: d3ca_simulated(
                        "hinge", data, D3CAConfig(
                            lam=lam, outer_iters=args.iters), callback=cb)
                r = time_to_tol(runner, f, f_star, args.tol)
                res[f"{method}_{P}x{Q}"] = r
                emit_csv_row(f"fig5/{ds}/{method}/{P}x{Q}",
                             r["t"] * 1e6,
                             f"iters={r['iters']};final={r['final']:.4f}")
        out[ds] = res
    save_result("fig5_strong", out)


if __name__ == "__main__":
    main()
