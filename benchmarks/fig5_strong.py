"""Paper Figure 5: strong scaling.  Fixed problem; grow K = P*Q through the
partition ladder and measure time (and iterations) to reach 1% relative
optimality.  Data sets shaped like realsim / news20 (synthetic sparse
stand-ins: the LIBSVM originals are not redistributable offline; identical
dimensions & sparsity).

Runs through the unified solver API (any engine x backend); the driver's
early stopping (tol + f_star) provides the time-to-tolerance measurement.
The unified API pads features to a multiple of P*Q, so every rung of the
ladder runs for RADiSA too (the old harness skipped P∤m_q rungs).

Reproduces the paper's qualitative findings: RADiSA prefers P > Q, D3CA
prefers Q > P; more partitions help the larger data set.
"""
from __future__ import annotations

import argparse
import sys

from .common import add_engine_args, emit_csv_row, ensure_host_devices, \
    save_result

ensure_host_devices(sys.argv)

from repro.configs.svm_paper import STRONG_CONFIGS          # noqa: E402
from repro.core import (D3CAConfig, RADiSAConfig, get_solver,  # noqa: E402
                        objective, serial_sdca)
from repro.data import make_sparse_svm_data                 # noqa: E402

DATASETS = {
    # name: (n, m, density)  -- paper Table II, scaled for CPU by --scale
    "realsim": (72309, 20958, 0.0024),
    "news20": (19996, 135519, 0.0003),   # m scaled 10x down to bound memory
}


def run_to_tol(solver, X, y, P, Q, cfg, f_star, tol):
    """Solve with early stopping; report time/iters to tolerance."""
    res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                       tol=tol)
    hit = next((h for h in res.history if h["rel_opt"] < tol), None)
    last = res.history[-1]
    return {"t": (hit or last)["time_s"],
            "iters": (hit or last)["iter"],
            "final": last["rel_opt"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--tol", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=25)
    add_engine_args(ap)
    args = ap.parse_args(argv)

    out = {"engine": args.engine, "backend": args.backend}
    for ds, (n, m, dens) in DATASETS.items():
        n, m = int(n * args.scale), int(m * args.scale)
        X, y = make_sparse_svm_data(n, m, density=max(dens, 0.01), seed=0)
        res = {}
        # paper: lam=1e-3 for RADiSA, 1e-2 for D3CA
        for method, lam in (("radisa", 1e-3), ("d3ca", 1e-2)):
            w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=200)
            f_star = float(objective("hinge", X, y, w_ref, lam))
            solver = get_solver(method)(engine=args.engine,
                                        local_backend=args.backend,
                                        staleness=args.staleness,
                                        compression=args.compression)
            for (P, Q) in STRONG_CONFIGS:
                n_p = -(-n // P)
                if method == "radisa":
                    # keep total processed points constant as K grows
                    cfg = RADiSAConfig(lam=lam, gamma=0.05 / P,
                                       L=max(1, n_p // 2),
                                       outer_iters=args.iters)
                else:
                    cfg = D3CAConfig(lam=lam, outer_iters=args.iters)
                r = run_to_tol(solver, X, y, P, Q, cfg, f_star, args.tol)
                res[f"{method}_{P}x{Q}"] = r
                emit_csv_row(f"fig5/{ds}/{method}/{P}x{Q}",
                             r["t"] * 1e6,
                             f"iters={r['iters']};final={r['final']:.4f}")
        out[ds] = res
    save_result("fig5_strong", out)


if __name__ == "__main__":
    main()
