"""Benchmark harness entry point: one benchmark per paper table/figure,
plus the core solver benchmark, kernel micro-benchmarks and (if dry-run
artifacts exist) the roofline table.  Prints ``name,us_per_call,derived``
CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
    PYTHONPATH=src python -m benchmarks.run --engine shard_map --backend pallas

The --engine / --backend pair is threaded through every fig benchmark via
the unified solver API.  ``core`` (the engine x backend throughput grid)
always runs in a subprocess: it forces a fake 8-device host platform,
which must happen before jax initializes.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from .common import ensure_host_devices  # noqa: E402

ensure_host_devices(sys.argv)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller instances (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig5,fig6,core,compress,"
                         "kernels,roofline")
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map"])
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    eb = ["--engine", args.engine, "--backend", args.backend]
    print("name,us_per_call,derived")

    if want("fig3"):
        from . import fig3_time
        fig3_time.main(["--scale", "0.05" if args.quick else "0.08",
                        "--iters", "8" if args.quick else "15"] + eb)
    if want("fig4"):
        from . import fig4_iters
        fig4_iters.main(["--scale", "0.05" if args.quick else "0.08",
                         "--iters", "20" if args.quick else "50"] + eb)
    if want("fig5"):
        from . import fig5_strong
        fig5_strong.main(["--scale", "0.02" if args.quick else "0.05",
                          "--iters", "10" if args.quick else "25"] + eb)
    if want("fig6"):
        from . import fig6_weak
        fig6_weak.main(["--scale", "0.005" if args.quick else "0.01",
                        "--iters", "6" if args.quick else "12",
                        "--max-p", "3" if args.quick else "4"] + eb)
    # these force their own host device count, which only takes effect
    # before jax initializes -> subprocess
    for bench, module in (("core", "benchmarks.core_bench"),
                          ("compress", "benchmarks.fig_compress")):
        if not want(bench):
            continue
        cmd = [sys.executable, "-m", module]
        if args.quick:
            cmd.append("--quick")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__))), "src"))
        r = subprocess.run(cmd, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        if r.returncode:
            # fail the harness like every other benchmark would
            print(f"{bench},0.0,failed(rc={r.returncode})")
            raise SystemExit(r.returncode)
    if want("kernels"):
        from . import kernels_bench
        kernels_bench.main([])
    if want("roofline"):
        from . import roofline
        try:
            roofline.main([])
        except Exception as e:
            print(f"roofline,0.0,unavailable({e!r})")


if __name__ == "__main__":
    main()
