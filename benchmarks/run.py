"""Benchmark harness entry point: one benchmark per paper table/figure,
plus kernel micro-benchmarks and (if dry-run artifacts exist) the roofline
table.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller instances (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig5,fig6,kernels,roofline")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")

    if want("fig3"):
        from . import fig3_time
        fig3_time.main(["--scale", "0.05" if args.quick else "0.08",
                        "--iters", "8" if args.quick else "15"])
    if want("fig4"):
        from . import fig4_iters
        fig4_iters.main(["--scale", "0.05" if args.quick else "0.08",
                         "--iters", "20" if args.quick else "50"])
    if want("fig5"):
        from . import fig5_strong
        fig5_strong.main(["--scale", "0.02" if args.quick else "0.05",
                          "--iters", "10" if args.quick else "25"])
    if want("fig6"):
        from . import fig6_weak
        fig6_weak.main(["--scale", "0.005" if args.quick else "0.01",
                        "--iters", "6" if args.quick else "12",
                        "--max-p", "3" if args.quick else "4"])
    if want("kernels"):
        from . import kernels_bench
        kernels_bench.main([])
    if want("roofline"):
        from . import roofline
        try:
            roofline.main([])
        except Exception as e:
            print(f"roofline,0.0,unavailable({e!r})")


if __name__ == "__main__":
    main()
