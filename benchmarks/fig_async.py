"""Staleness-vs-convergence sweep for the async engine (Engine API v2).

Runs every solver under ``engine="async"`` across a staleness grid
(default tau in {0, 1, 2, 4}) on the same instance the core benchmark
uses, and lands the rows in ``BENCH_core.json``:

  * one cell per (solver, tau): ``{solver}/async/{backend}/tau{tau}``
    with s_per_iter + final rel_opt (so the CI regression gate sees the
    async engine the same way it sees every other cell);
  * an ``async_sweep`` block with the full convergence trajectories
    (rel_opt per outer iteration per tau) -- the figure's payload.

tau = 0 is asserted to reproduce the sync shard_map engine exactly
(max-abs iterate diff == 0), which is the API's staleness contract.

    PYTHONPATH=src python -m benchmarks.fig_async [--quick] \\
        [--taus 0,1,2,4] [--solvers d3ca,radisa,admm]

Forces a fake 8-device host platform before jax init (the async engine
is a mesh engine).  The payload carries the standard provenance stamp
(git_sha / date / quick).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "src"))

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np  # noqa: E402

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_svm_data  # noqa: E402

try:
    from .common import (annotate_wire_predictions, emit_csv_row,
                         phase_fields, provenance, timed)
except ImportError:                       # `python benchmarks/fig_async.py`
    from common import (annotate_wire_predictions, emit_csv_row,
                        phase_fields, provenance, timed)


def sweep_solver(name, cfg, X, y, P, Q, taus, backend, f_star, reps):
    """One solver across the staleness grid.  Returns (cells, curves,
    samples) -- samples feed the wire-time model fit."""
    sync = get_solver(name)(engine="shard_map", local_backend=backend)
    w_sync = sync.solve("hinge", X, y, P=P, Q=Q, cfg=cfg,
                        record_history=False).w
    cells, curves, samples = {}, {}, []
    for tau in taus:
        solver = get_solver(name)(engine="async", staleness=tau,
                                  local_backend=backend)
        prog = solver.program("hinge", X, y, P=P, Q=Q, cfg=cfg)
        state = prog.step(1, prog.state)          # compile + warm
        t = timed(lambda: prog.step(2, state), reps=reps, warmup=0)
        from repro.obs import Registry
        res = solver.solve("hinge", X, y, P=P, Q=Q, cfg=cfg, f_star=f_star,
                           registry=Registry())
        entry = {"s_per_iter": t,
                 "rel_opt": res.history[-1]["rel_opt"],
                 "iters": res.iters, "staleness": tau}
        entry.update(phase_fields(res.history))
        # per-collective bytes-on-wire counters (the staleness model
        # launches every collective every step, so tau does not change
        # the wire cost -- which is exactly what makes async and
        # compressed runs comparable on the same axis)
        acct = res.comm_bytes
        entry["comm_bytes_per_step"] = acct["bytes_per_step"]
        entry["comm_bytes_by_collective"] = {
            cname: c["bytes_per_step"]
            for cname, c in acct["collectives"].items()}
        if "duality_gap" in res.history[-1]:
            entry["duality_gap"] = res.history[-1]["duality_gap"]
        if tau == 0:
            # the API contract: tau = 0 IS the sync engine
            diff = float(np.abs(np.asarray(res.w) - np.asarray(w_sync)).max())
            entry["max_abs_diff_vs_sync"] = diff
            assert diff <= 1e-8, (
                f"{name}: async(staleness=0) diverged from shard_map "
                f"by {diff:.3e} (> 1e-8)")
        key = f"{name}/async/{backend}/tau{tau}"
        if "comm_s" in entry:
            samples.append((acct, {"data": P, "model": Q},
                            entry["comm_s"], key, None))
        cells[key] = entry
        curves[str(tau)] = [h["rel_opt"] for h in res.history]
        emit_csv_row(f"fig_async/{name}/tau{tau}", t * 1e6,
                     f"rel_opt={entry['rel_opt']:.4f}")
    return cells, curves, samples


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--taus", default="0,1,2,4",
                    help="comma-separated staleness grid")
    ap.add_argument("--solvers", default="d3ca,radisa,admm")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_core.json"))
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    taus = [int(t) for t in args.taus.split(",") if t != ""]
    bad = [t for t in taus if t < 0]
    if bad:
        ap.error(f"--taus contains negative staleness values {bad}; "
                 "tau must be >= 0")

    P, Q = 4, 2
    n, m = (256, 96) if args.quick else (768, 256)
    inner = 32 if args.quick else 96
    iters = 6 if args.quick else 12
    lam = 1e-1
    X, y = make_svm_data(n, m, seed=0)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=100)
    f_star = float(objective("hinge", X, y, w_ref, lam))

    configs = {
        "d3ca": D3CAConfig(lam=lam, outer_iters=iters, local_steps=inner),
        "radisa": RADiSAConfig(lam=lam, gamma=0.05, outer_iters=iters,
                               L=inner),
        "admm": ADMMConfig(lam=lam, rho=lam, outer_iters=iters),
    }

    # land the rows in BENCH_core.json next to the core grid (fresh
    # payload when core_bench has not run in this checkout)
    if os.path.exists(args.out):
        with open(args.out) as fh:
            payload = json.load(fh)
    else:
        payload = {"cells": {}, "ratios": {}}
    payload.setdefault("cells", {})
    payload["async_sweep"] = {"taus": taus, "n": n, "m": m, "P": P, "Q": Q,
                              "lam": lam, "iters": iters,
                              "backend": args.backend, "curves": {}}
    payload["provenance"] = provenance(args.quick)

    all_samples = []
    for name in args.solvers.split(","):
        cells, curves, samples = sweep_solver(
            name, configs[name], X, y, P, Q, taus, args.backend, f_star,
            args.reps)
        payload["cells"].update(cells)
        payload["async_sweep"]["curves"][name] = curves
        all_samples.extend(samples)

    if all_samples:
        payload["async_sweep"]["wire_model"] = annotate_wire_predictions(
            payload["cells"], all_samples)

    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[fig_async] wrote {args.out} "
          f"({len(taus)} taus x {len(args.solvers.split(','))} solvers)")
    return payload


if __name__ == "__main__":
    main()
