"""Paper Figure 4: relative optimality difference vs ITERATION count
(50 iterations), separating algorithmic progress from wall time."""
from __future__ import annotations

import argparse

from repro.configs.svm_paper import PART1
from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, admm_simulated,
                        d3ca_simulated, objective, partition,
                        radisa_simulated, rel_opt, serial_sdca)
from repro.data import make_svm_data

from .common import emit_csv_row, save_result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--iters", type=int, default=50)
    args = ap.parse_args(argv)

    exp = PART1[0]            # the 4x2 instance, as in the paper's Fig. 4
    lam = 1e-2
    bn, bm = int(exp.block_n * args.scale), int(exp.block_m * args.scale)
    X, y = make_svm_data(exp.P * bn, exp.Q * bm, seed=0)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=300)
    f_star = float(objective("hinge", X, y, w_ref, lam))
    data = partition(X, y, exp.P, exp.Q)

    curves = {}

    def cb_for(label):
        curves[label] = []

        def cb(t, w, *rest):
            curves[label].append(float(rel_opt(
                objective("hinge", X, y, w, lam), f_star)))
        return cb

    d3ca_simulated("hinge", data,
                   D3CAConfig(lam=lam, outer_iters=args.iters),
                   callback=cb_for("d3ca"))
    radisa_simulated("hinge", data,
                     RADiSAConfig(lam=lam, gamma=0.02,
                                  outer_iters=args.iters),
                     callback=cb_for("radisa"))
    radisa_simulated("hinge", data,
                     RADiSAConfig(lam=lam, gamma=0.02, outer_iters=args.iters,
                                  variant="avg"),
                     callback=cb_for("radisa_avg"))
    admm_simulated("hinge", data,
                   ADMMConfig(lam=lam, rho=lam, outer_iters=args.iters),
                   callback=cb_for("admm"))

    for label, c in curves.items():
        emit_csv_row(f"fig4/{label}", 0.0,
                     f"final_rel_opt={c[-1]:.4f};iters={len(c)}")
    save_result("fig4_iters", {"lam": lam, "curves": curves})


if __name__ == "__main__":
    main()
