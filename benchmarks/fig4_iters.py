"""Paper Figure 4: relative optimality difference vs ITERATION count
(50 iterations), separating algorithmic progress from wall time.  Runs
through the unified solver API (any engine x backend)."""
from __future__ import annotations

import argparse
import sys

from .common import add_engine_args, emit_csv_row, ensure_host_devices, \
    save_result

ensure_host_devices(sys.argv)

from repro.configs.svm_paper import PART1                   # noqa: E402
from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        get_solver, objective, serial_sdca)
from repro.data import make_svm_data                        # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--iters", type=int, default=50)
    add_engine_args(ap)
    args = ap.parse_args(argv)

    exp = PART1[0]            # the 4x2 instance, as in the paper's Fig. 4
    lam = 1e-2
    bn, bm = int(exp.block_n * args.scale), int(exp.block_m * args.scale)
    X, y = make_svm_data(exp.P * bn, exp.Q * bm, seed=0)
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=300)
    f_star = float(objective("hinge", X, y, w_ref, lam))

    curves = {}

    def run(name, cfg, label):
        solver = get_solver(name)(engine=args.engine,
                                  local_backend=args.backend,
                                  staleness=args.staleness,
                                  compression=args.compression)
        res = solver.solve("hinge", X, y, P=exp.P, Q=exp.Q, cfg=cfg,
                           f_star=f_star)
        curves[label] = [h["rel_opt"] for h in res.history]

    run("d3ca", D3CAConfig(lam=lam, outer_iters=args.iters), "d3ca")
    run("radisa", RADiSAConfig(lam=lam, gamma=0.02, outer_iters=args.iters),
        "radisa")
    run("radisa", RADiSAConfig(lam=lam, gamma=0.02, outer_iters=args.iters,
                               variant="avg"), "radisa_avg")
    run("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=args.iters), "admm")

    for label, c in curves.items():
        emit_csv_row(f"fig4/{label}", 0.0,
                     f"final_rel_opt={c[-1]:.4f};iters={len(c)}")
    save_result("fig4_iters", {"lam": lam, "engine": args.engine,
                               "backend": args.backend, "curves": curves})


if __name__ == "__main__":
    main()
