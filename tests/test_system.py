"""End-to-end behaviour tests: the full train driver and serve driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_end_to_end(tmp_path):
    hist = train_mod.main([
        "--arch", "qwen3-1.7b", "--reduced", "--steps", "40",
        "--batch", "4", "--seq", "64", "--lr", "5e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "20"])
    losses = [h["loss"] for h in hist]
    assert len(losses) == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5])   # it learns


def test_train_driver_resume(tmp_path):
    train_mod.main([
        "--arch", "musicgen-large", "--reduced", "--steps", "10",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path)])
    hist = train_mod.main([
        "--arch", "musicgen-large", "--reduced", "--steps", "5",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--resume"])
    assert hist[0]["step"] == 10   # continued from the checkpoint


def test_serve_driver_end_to_end():
    out = serve_mod.main([
        "--arch", "qwen3-1.7b", "--reduced", "--requests", "2",
        "--slots", "2", "--prompt-len", "16", "--gen", "6",
        "--page-size", "8", "--max-seq-len", "64"])
    assert sorted(out) == [0, 1]
    for toks in out.values():
        assert toks.shape == (6,)
        assert (toks >= 0).all()


def test_core_example_paper_pipeline():
    """The paper pipeline end to end: generate -> partition -> train ->
    certificate."""
    from repro.core import (D3CAConfig, d3ca_simulated, duality_gap,
                            partition)
    from repro.data import make_svm_data
    X, y = make_svm_data(200, 60, seed=9)
    data = partition(X, y, 2, 2)
    w, alpha = d3ca_simulated("hinge", data,
                              D3CAConfig(lam=1.0, outer_iters=40))
    gap = float(duality_gap("hinge", X, y, w, alpha, 1.0))
    # the dual averaging leaves an intrinsic plateau; certificate is still
    # a valid (conservative) optimality bound
    assert gap < 0.1
