"""Optimizers: AdamW, RADiSA-SVRG-for-deep-nets, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update, compression,
                         radisa_svrg)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clipping():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0)
    _, _, gn = adamw_update(cfg, {"w": jnp.full((4,), 100.0)}, opt, params)
    assert float(gn) == 200.0   # reported norm is pre-clip


def test_radisa_svrg_on_least_squares():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    xstar = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    b = A @ xstar

    def grad_at(w, rows):
        r = A[rows] @ w["w"] - b[rows]
        return {"w": A[rows].T @ r / len(rows)}

    params = {"w": jnp.zeros((8,))}
    cfg = radisa_svrg.RadisaSVRGConfig(lr=0.3, block_fraction=1.0)
    state = radisa_svrg.init(params)
    key = jax.random.PRNGKey(0)
    for outer in range(8):
        state = radisa_svrg.refresh_anchor(
            state, params, grad_at(params, np.arange(64)))
        for inner in range(10):
            key, k1, k2 = jax.random.split(key, 3)
            rows = jax.random.randint(k1, (8,), 0, 64)
            g_now = grad_at(params, rows)
            g_anc = grad_at(state["anchor"], rows)
            params, state = radisa_svrg.step(cfg, params, state, g_now,
                                             g_anc, k2)
    err = float(jnp.linalg.norm(params["w"] - xstar))
    assert err < 0.05, err


def test_compression_roundtrip_error_feedback():
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    e = compression.init_error(g)
    # accumulated dequantized gradients track the true sum (EF property)
    total_true = np.zeros(32)
    total_deq = np.zeros(32)
    for _ in range(50):
        q, s, e = compression.compress(g, e)
        deq = compression.decompress(q, s)
        total_true += np.asarray(g["a"])
        total_deq += np.asarray(deq["a"])
    assert np.abs(total_true - total_deq).max() / 50 < 1e-2


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=2, max_size=40))
def test_compression_bounded_per_step_error(vals):
    g = {"a": jnp.asarray(np.array(vals, np.float32))}
    e = compression.init_error(g)
    q, s, e2 = compression.compress(g, e)
    deq = compression.decompress(q, s)
    scale = float(np.abs(np.array(vals)).max()) / 127.0 + 1e-12
    assert float(jnp.abs(deq["a"] - g["a"]).max()) <= scale * 0.5 + 1e-6


def test_sgd_with_compression_converges():
    """EF-int8 compressed 'all-reduce' keeps convergence on a quadratic."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    w = jnp.zeros((16,))
    e = compression.init_error({"w": w})
    for _ in range(200):
        g = {"w": w - target}
        q, s, e = compression.compress(g, e)
        g_hat = compression.decompress(q, s)["w"]
        w = w - 0.1 * g_hat
    assert float(jnp.abs(w - target).max()) < 1e-2
