"""Optimizers: AdamW, RADiSA-SVRG-for-deep-nets, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compression,
                         radisa_svrg)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clipping():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0)
    _, _, gn = adamw_update(cfg, {"w": jnp.full((4,), 100.0)}, opt, params)
    assert float(gn) == 200.0   # reported norm is pre-clip


def test_radisa_svrg_on_least_squares():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    xstar = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    b = A @ xstar

    def grad_at(w, rows):
        r = A[rows] @ w["w"] - b[rows]
        return {"w": A[rows].T @ r / len(rows)}

    params = {"w": jnp.zeros((8,))}
    cfg = radisa_svrg.RadisaSVRGConfig(lr=0.3, block_fraction=1.0)
    state = radisa_svrg.init(params)
    key = jax.random.PRNGKey(0)
    for outer in range(8):
        state = radisa_svrg.refresh_anchor(
            state, params, grad_at(params, np.arange(64)))
        for inner in range(10):
            key, k1, k2 = jax.random.split(key, 3)
            rows = jax.random.randint(k1, (8,), 0, 64)
            g_now = grad_at(params, rows)
            g_anc = grad_at(state["anchor"], rows)
            params, state = radisa_svrg.step(cfg, params, state, g_now,
                                             g_anc, k2)
    err = float(jnp.linalg.norm(params["w"] - xstar))
    assert err < 0.05, err


# The compression coverage moved to tests/test_compress.py with the
# code (repro.core.compress); what remains here is the deprecation-shim
# contract of the old module path.

def test_compression_shim_reexports_and_warns():
    import importlib
    import sys
    import warnings

    import repro.optim.compression  # ensure loaded (import may be cached)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        shim = importlib.reload(sys.modules["repro.optim.compression"])
    assert any(issubclass(w.category, DeprecationWarning) for w in rec), \
        "reimporting repro.optim.compression must emit DeprecationWarning"
    from repro.core import compress as new
    # same objects, not copies: the shim is thin
    assert shim.init_error is new.init_error
    assert shim.compress is new.compress
    assert shim.decompress is new.decompress
    # and the legacy `compression` attribute of repro.optim still works
    g = {"a": jnp.ones((8,), jnp.float32)}
    q, s, e = compression.compress(g, compression.init_error(g))
    np.testing.assert_allclose(
        np.asarray(compression.decompress(q, s)["a"]), 1.0, atol=1e-2)
