"""Property tests for the loss/conjugate machinery (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.losses import get_loss
from repro.core.admm import prox_loss

floats = st.floats(-5.0, 5.0, allow_nan=False)
labels = st.sampled_from([-1.0, 1.0])


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "logistic"])
@settings(max_examples=60, deadline=None)
@given(z=floats, y=labels, a=st.floats(0.01, 0.99))
def test_fenchel_young(loss_name, z, y, a):
    """f(z) + f*(-alpha) >= -alpha * z on the dual-feasible box."""
    loss = get_loss(loss_name)
    alpha = a * y  # feasible for hinge/logistic; any value ok for squared
    f = float(loss.value(jnp.float32(z), jnp.float32(y)))
    fstar = float(loss.conj(jnp.float32(alpha), jnp.float32(y)))
    assert f + fstar >= -alpha * z - 1e-4


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "logistic"])
@settings(max_examples=40, deadline=None)
@given(v=floats, y=labels, c=st.floats(0.01, 3.0))
def test_prox_is_minimizer(loss_name, v, y, c):
    """prox_{c f}(v) beats nearby points on c*f(z) + 0.5 (z-v)^2."""
    loss = get_loss(loss_name)
    z = float(prox_loss(loss_name, jnp.float32(v), jnp.float32(y),
                        jnp.float32(c)))
    def obj(t):
        return c * float(loss.value(jnp.float32(t), jnp.float32(y))) \
            + 0.5 * (t - v) ** 2
    base = obj(z)
    for dz in (-1e-2, 1e-2, -0.3, 0.3):
        assert base <= obj(z + dz) + 1e-5


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "logistic"])
@settings(max_examples=40, deadline=None)
@given(y=labels, a=st.floats(0.05, 0.95),
       zloc=st.floats(-2.0, 2.0),
       xsq=st.floats(0.1, 10.0))
def test_sdca_delta_improves_local_objective(loss_name, y, a, zloc, xsq):
    """The closed-form/Newton delta does not decrease the local dual obj."""
    loss = get_loss(loss_name)
    lam, n, Q = 0.5, 50, 2
    alpha = jnp.float32(a * y)
    d = loss.sdca_delta(alpha, jnp.float32(xsq), jnp.float32(zloc),
                        jnp.float32(y), lam, n, Q)

    # evaluate the true local objective used in Algorithm 2 step 3
    def obj(delta):
        conj = loss.conj(alpha + delta, jnp.float32(y))
        return float(-(1.0 / Q) * conj - zloc * delta
                     - delta ** 2 * xsq / (2 * lam * n))

    assert obj(float(d)) >= obj(0.0) - 1e-4


def test_gradients_match_autodiff():
    for name in ("squared", "logistic"):
        loss = get_loss(name)
        zs = jnp.linspace(-3, 3, 25)
        for y in (-1.0, 1.0):
            g = loss.grad(zs, y)
            g_ad = jax.vmap(jax.grad(lambda z: loss.value(z, y)))(zs)
            np.testing.assert_allclose(np.asarray(g), np.asarray(g_ad),
                                       rtol=1e-5, atol=1e-6)
