"""Convergence/fidelity tests for the paper's algorithms."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, admm_simulated,
                        d3ca_simulated, duality_gap, objective, partition,
                        radisa_simulated, rel_opt, serial_sdca)
from repro.data import make_svm_data

LAM = 1.0


@pytest.fixture(scope="module")
def problem():
    X, y = make_svm_data(300, 90, seed=3)
    w_ref, a_ref = serial_sdca("hinge", X, y, lam=LAM, epochs=400)
    f_star = float(objective("hinge", X, y, w_ref, LAM))
    gap = float(duality_gap("hinge", X, y, w_ref, a_ref, LAM))
    assert gap < 1e-3
    return X, y, f_star


def test_serial_sdca_matches_ridge_exactly():
    X, y = make_svm_data(200, 50, seed=4)
    lam, n = 0.05, 200
    w, _ = serial_sdca("squared", X, y, lam=lam, epochs=800)
    w_exact = np.linalg.solve(np.asarray(X.T @ X) + 0.5 * lam * n * np.eye(50),
                              np.asarray(X.T @ y))
    np.testing.assert_allclose(np.asarray(w), w_exact, atol=1e-4)


def test_d3ca_converges(problem):
    X, y, f_star = problem
    data = partition(X, y, 3, 2)
    w, alpha = d3ca_simulated("hinge", data,
                              D3CAConfig(lam=LAM, outer_iters=25))
    assert float(rel_opt(objective("hinge", X, y, w, LAM), f_star)) < 0.03
    # dual feasibility: alpha * y in [0, 1]
    ay = np.asarray(alpha) * np.asarray(y)
    assert ay.min() > -1e-6 and ay.max() < 1 + 1e-6


def test_d3ca_reduces_to_cocoa_when_Q1(problem):
    """Q=1 must reproduce the CoCoA geometry: dual avg only over P."""
    X, y, f_star = problem
    data = partition(X, y, 4, 1)
    w, _ = d3ca_simulated("hinge", data, D3CAConfig(lam=LAM, outer_iters=25))
    assert float(rel_opt(objective("hinge", X, y, w, LAM), f_star)) < 0.03


@pytest.mark.parametrize("variant", ["block", "avg"])
def test_radisa_converges(problem, variant):
    X, y, f_star = problem
    data = partition(X, y, 3, 2)
    w = radisa_simulated("hinge", data,
                         RADiSAConfig(lam=LAM, gamma=0.05, outer_iters=30,
                                      variant=variant))
    assert float(rel_opt(objective("hinge", X, y, w, LAM), f_star)) < 0.05


def test_admm_converges(problem):
    X, y, f_star = problem
    data = partition(X, y, 3, 2)
    w = admm_simulated("hinge", data,
                       ADMMConfig(lam=LAM, rho=LAM, outer_iters=300))
    # ADMM needs a much larger number of iterations (paper §IV, Fig. 4)
    assert float(rel_opt(objective("hinge", X, y, w, LAM), f_star)) < 0.04


def test_all_three_agree(problem):
    """All three optimizers find (roughly) the same objective value."""
    X, y, f_star = problem
    data = partition(X, y, 3, 2)
    def f(w):
        return float(objective("hinge", X, y, w, LAM))
    w1, _ = d3ca_simulated("hinge", data, D3CAConfig(lam=LAM, outer_iters=30))
    w2 = radisa_simulated("hinge", data, RADiSAConfig(
        lam=LAM, gamma=0.05, outer_iters=40))
    w3 = admm_simulated("hinge", data, ADMMConfig(lam=LAM, rho=LAM,
                                                  outer_iters=200))
    # D3CA plateaus ~1%, ADMM oscillates around ~5% at this budget --
    # the paper reports the same ordering (Fig. 3/4)
    for w in (w1, w2, w3):
        assert abs(f(w) - f_star) / f_star < 0.09


def test_logistic_and_squared_d3ca():
    X, y = make_svm_data(160, 40, seed=5)
    for loss in ("logistic", "squared"):
        w_ref, _ = serial_sdca(loss, X, y, lam=LAM, epochs=300)
        f_star = float(objective(loss, X, y, w_ref, LAM))
        data = partition(X, y, 2, 2)
        w, _ = d3ca_simulated(loss, data, D3CAConfig(lam=LAM, outer_iters=25))
        assert float(rel_opt(objective(loss, X, y, w, LAM), f_star)) < 0.05


def test_paper_qualitative_radisa_avg_best_small_lam():
    """Paper Fig. 3: RADiSA(-avg) outperform D3CA at small lambda."""
    X, y = make_svm_data(400, 120, seed=1)
    lam = 1e-2
    w_ref, _ = serial_sdca("hinge", X, y, lam=lam, epochs=400)
    f_star = float(objective("hinge", X, y, w_ref, lam))
    data = partition(X, y, 4, 2)
    def ro(w):
        return float(rel_opt(objective("hinge", X, y, w, lam), f_star))
    w_d, _ = d3ca_simulated("hinge", data, D3CAConfig(lam=lam, outer_iters=15))
    w_r = radisa_simulated("hinge", data, RADiSAConfig(
        lam=lam, gamma=0.02, outer_iters=15))
    assert ro(w_r) < ro(w_d)
