"""Online learning service: admission, the grid ring store, the gated
incremental solver path, atomic snapshot hand-off under concurrent
scoring, staleness accounting, and the end-to-end service loop.

The pure queue/store/snapshot unit tests run in the simulated CI split;
the tests that drive real warm-started solves carry the ``online``
marker (their own matrix leg)."""
import threading

import numpy as np
import pytest

from repro.core import D3CAConfig, get_solver, objective
from repro.data import make_svm_data
from repro.online import (AdmissionQueue, GridStore, OnlineConfig,
                          OnlineSolverService, QueueFullError, SnapshotBook)

LAM = 1e-2
RNG = np.random.default_rng(3)


def _stream(b, m, rng=RNG):
    X = rng.normal(size=(b, m)).astype(np.float32)
    w_star = np.linspace(-1.0, 1.0, m)
    y = np.where(X @ w_star >= 0, 1.0, -1.0).astype(np.float32)
    return X, y


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def test_queue_admits_and_coalesces_fifo():
    q = AdmissionQueue(capacity=100)
    X1, y1 = _stream(4, 3)
    X2, y2 = _stream(6, 3)
    assert q.submit(X1, y1) == 4
    assert q.submit(X2, y2) == 10
    assert q.pending_rows == 10
    X, y, seq = q.drain()
    assert X.shape == (10, 3) and seq == 10 and q.pending_rows == 0
    np.testing.assert_array_equal(X[:4], X1)      # FIFO order preserved
    np.testing.assert_array_equal(X[4:], X2)
    assert q.drain() is None


def test_queue_sheds_on_overflow_without_partial_admission():
    q = AdmissionQueue(capacity=10)
    q.submit(*_stream(8, 2))
    with pytest.raises(QueueFullError):
        q.submit(*_stream(4, 2))                  # 8 + 4 > 10: shed whole
    assert q.pending_rows == 8 and q.rejected == 4 and q.admitted == 8
    q.submit(*_stream(2, 2))                      # exactly to the brim is ok
    assert q.pending_rows == 10


def test_queue_drain_respects_max_rows():
    q = AdmissionQueue(capacity=0)                # unbounded
    for _ in range(5):
        q.submit(*_stream(4, 2))
    X, _, seq = q.drain(max_rows=7)               # whole batches: 4 + 4
    assert X.shape[0] == 8 and seq == 8 and q.pending_rows == 12


def test_queue_rejects_mismatched_shapes():
    q = AdmissionQueue()
    with pytest.raises(ValueError):
        q.submit(np.zeros((4, 3)), np.zeros((5,)))


# ---------------------------------------------------------------------------
# grid ring store
# ---------------------------------------------------------------------------

def test_store_rounds_capacity_and_tracks_touched_rows():
    st = GridStore(m=4, capacity=10, P=4, Q=2)
    assert st.capacity == 12 and st.n_p == 3      # rounded so P divides
    touched = st.insert(*_stream(5, 4))
    np.testing.assert_array_equal(touched, np.arange(5))
    assert set(st.touched_partitions(touched)) == {0, 1}
    assert st.filled == 5


def test_store_ring_wraps_and_overwrites_oldest():
    st = GridStore(m=2, capacity=8, P=2, Q=1)
    st.insert(*_stream(6, 2))
    touched = st.insert(*_stream(4, 2))           # wraps: rows 6,7,0,1
    np.testing.assert_array_equal(touched, [0, 1, 6, 7])
    assert st.filled == 8 and st.written == 10
    Xg, _ = _stream(20, 2, np.random.default_rng(7))
    touched = st.insert(Xg, np.ones(20, np.float32))
    assert len(touched) == 8                      # giant batch: tail only
    assert st.filled == 8 and st.written == 18
    # the buffer now holds exactly the last `capacity` rows of the batch
    order = np.argsort((np.arange(8) - st._cursor) % 8)
    np.testing.assert_array_equal(st.X[order], Xg[-8:])


def test_store_rejects_wrong_width():
    st = GridStore(m=3, capacity=4, P=2, Q=2)
    with pytest.raises(ValueError):
        st.insert(np.zeros((2, 5)), np.zeros(2))


# ---------------------------------------------------------------------------
# snapshot book: atomic hand-off + persistence (checkpoint crash cases
# live in test_checkpoint.py)
# ---------------------------------------------------------------------------

def test_snapshot_publish_is_atomic_under_concurrent_reads():
    book = SnapshotBook(np.zeros(4), np.zeros(6))
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            s = book.current()
            if not (np.all(s.w == s.version) and s.trained_seq == s.version):
                torn.append(s.version)

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for th in threads:
        th.start()
    for v in range(1, 200):
        book.publish(np.full(4, float(v)), np.zeros(6), v)
    stop.set()
    for th in threads:
        th.join()
    assert torn == []
    assert book.current().version == 199


def test_snapshot_recover_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    book = SnapshotBook(np.zeros(3), np.zeros(4), manager=mgr,
                        async_persist=False)
    book.publish(np.full(3, 1.0), np.full(4, 0.5), trained_seq=7)
    book.publish(np.full(3, 2.0), np.full(4, 1.5), trained_seq=11)
    fresh = SnapshotBook(np.zeros(3), np.zeros(4), manager=mgr)
    snap = fresh.recover(np.zeros(3), np.zeros(4))
    assert snap.version == 2 and snap.trained_seq == 11
    np.testing.assert_array_equal(snap.w, np.full(3, 2.0))
    np.testing.assert_array_equal(snap.alpha, np.full(4, 1.5))
    # without a manager there is nothing to recover
    assert SnapshotBook(np.zeros(3)).recover(np.zeros(3)) is None


# ---------------------------------------------------------------------------
# the gated incremental solver path (real solves: own CI leg)
# ---------------------------------------------------------------------------

@pytest.mark.online
def test_gate_all_ones_matches_ungated_bit_for_bit():
    X, y = make_svm_data(48, 12, seed=2)
    cfg = D3CAConfig(lam=LAM, outer_iters=3, local_steps=8)
    s = get_solver("d3ca")()
    plain = s.solve("hinge", X, y, P=2, Q=2, cfg=cfg, record_history=False)
    gated = s.solve("hinge", X, y, P=2, Q=2, cfg=cfg, record_history=False,
                    row_gate=np.ones(48, np.float32))
    np.testing.assert_array_equal(np.asarray(plain.w), np.asarray(gated.w))
    np.testing.assert_array_equal(np.asarray(plain.alpha),
                                  np.asarray(gated.alpha))


@pytest.mark.online
def test_gate_freezes_untouched_duals_exactly():
    X, y = make_svm_data(48, 12, seed=2)
    cfg = D3CAConfig(lam=LAM, outer_iters=2, local_steps=8)
    s = get_solver("d3ca")()
    base = s.solve("hinge", X, y, P=2, Q=2, cfg=cfg, record_history=False)
    touched = np.arange(36, 48)                   # last partition only
    res = s.update("hinge", X, y, touched=touched,
                   warm_start=(base.w, base.alpha), P=2, Q=2, cfg=cfg,
                   passes=2, record_history=False)
    a0 = np.asarray(base.alpha)
    a1 = np.asarray(res.alpha)
    untouched = np.setdiff1d(np.arange(48), touched)
    np.testing.assert_array_equal(a1[untouched], a0[untouched])
    assert np.any(a1[touched] != a0[touched])     # gated-on rows moved


@pytest.mark.online
def test_row_gate_rejected_by_primal_only_solvers():
    X, y = make_svm_data(24, 8, seed=0)
    for name in ("radisa", "sfk", "admm"):
        with pytest.raises(ValueError, match="row-gate"):
            get_solver(name)().solve("hinge", X, y, P=2, Q=2,
                                     row_gate=np.ones(24, np.float32))
    with pytest.raises(ValueError, match="warm_start"):
        get_solver("d3ca")().update("hinge", X, y, touched=[0],
                                    warm_start=None, P=2, Q=2)


# ---------------------------------------------------------------------------
# the service loop (real solves: own CI leg)
# ---------------------------------------------------------------------------

def _service(**kw):
    from repro.obs import Registry
    reg = Registry()
    cfg = OnlineConfig(m=10, capacity=32, P=2, Q=2,
                       solver_cfg=D3CAConfig(lam=LAM, local_steps=8),
                       passes=2, **kw)
    return OnlineSolverService(cfg, registry=reg), reg


@pytest.mark.online
def test_service_end_to_end_improves_and_tracks_lag():
    svc, reg = _service()
    assert svc.run_pending() is None              # nothing pending
    for _ in range(4):
        svc.submit(*_stream(8, 10))
        assert svc.version_lag > 0                # admitted, not trained
        svc.run_pending()
        assert svc.version_lag == 0
    assert svc.book.current().version == 4
    mask = svc.store.filled_mask > 0
    w = svc.book.current().w
    f_w = objective("hinge", svc.store.X[mask], svc.store.y[mask], w, LAM)
    f_0 = objective("hinge", svc.store.X[mask], svc.store.y[mask],
                    np.zeros(10), LAM)
    assert f_w < f_0                              # the model learned
    # the scorer serves the published version
    assert svc.scorer.w_version == 4
    Xs, ys = _stream(64, 10)
    assert np.mean(svc.predict(Xs) * ys > 0) > 0.6
    snap = reg.snapshot()
    c = {k.split("{")[0]: v for k, v in snap["counters"].items()}
    assert c["online/ingested"] == 32 and c["online/updates"] == 4
    assert c["online/scored"] == 64
    g = {k.split("{")[0]: v for k, v in snap["gauges"].items()}
    assert g["online/version_lag"] == 0
    assert g["online/staleness_s"] >= 0
    h = {k.split("{")[0]: v for k, v in snap["histograms"].items()}
    assert h["online/update_s"]["count"] == 4
    assert h["online/swap_s"]["count"] == 4


@pytest.mark.online
def test_service_sheds_load_and_counts_rejections():
    svc, reg = _service(queue_capacity=8)
    svc.submit(*_stream(8, 10))
    with pytest.raises(QueueFullError):
        svc.submit(*_stream(4, 10))
    assert svc.stats()["rejected"] == 4
    snap = reg.snapshot()
    c = {k.split("{")[0]: v for k, v in snap["counters"].items()}
    assert c["online/rejected"] == 4


@pytest.mark.online
def test_service_rejects_solvers_without_row_gate():
    with pytest.raises(ValueError, match="row-gate"):
        OnlineSolverService(OnlineConfig(m=4, solver="radisa"))


@pytest.mark.online
def test_scorer_swap_is_atomic_under_concurrent_scoring():
    """update_weights while score() runs in other threads: every margin
    batch must be consistent with ONE published version, never a mix."""
    from repro.serve.scoring import LinearScorer
    m = 6
    scorer = LinearScorer(np.full(m, 1.0), None)
    X = np.eye(m, dtype=np.float32)               # margins == w exactly
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            margins = scorer.score(X)
            if len(set(np.round(margins, 6))) != 1:
                torn.append(margins.copy())

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    for v in range(2, 200):
        scorer.update_weights(np.full(m, float(v)), version=v)
    stop.set()
    for th in threads:
        th.join()
    assert torn == [], f"mixed-version batches: {torn[:3]}"
    assert scorer.w_version == 199


@pytest.mark.online
def test_service_recover_after_restart(tmp_path):
    from repro.checkpoint import CheckpointManager
    cfg = OnlineConfig(m=10, capacity=32, P=2, Q=2,
                       solver_cfg=D3CAConfig(lam=LAM, local_steps=8))
    svc = OnlineSolverService(cfg, manager=CheckpointManager(str(tmp_path)))
    svc.submit(*_stream(8, 10))
    svc.run_pending()
    svc.book.flush()
    w = np.asarray(svc.book.current().w)

    svc2 = OnlineSolverService(cfg, manager=CheckpointManager(str(tmp_path)))
    assert svc2.recover() == 1
    np.testing.assert_array_equal(np.asarray(svc2.book.current().w), w)
    assert svc2.scorer.w_version == 1
    # and the recovered alpha warm-starts the next update
    svc2.submit(*_stream(8, 10))
    assert svc2.run_pending() == 2
