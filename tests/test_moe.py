"""MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Transformer, reduced
from repro.models.moe import init_moe, moe_ffn

CFG = dataclasses.replace(reduced(get_config("mixtral_8x7b")),
                          compute_dtype="float32")


def test_moe_shapes_and_finiteness():
    params, _ = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, CFG.d_model))
    y = moe_ffn(params, x, CFG)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= E/topk (full capacity) nothing is dropped:
    doubling capacity must not change the output."""
    big = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=4.0))
    huge = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=8.0))
    params, _ = init_moe(jax.random.PRNGKey(0), big)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, CFG.d_model))
    y1 = moe_ffn(params, x, big)
    y2 = moe_ffn(params, x, huge)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_permutation_equivariance_across_batch():
    """Dispatch is per-(row, chunk): permuting batch rows permutes output."""
    params, _ = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, CFG.d_model))
    y = moe_ffn(params, x, CFG)
    perm = jnp.asarray([2, 0, 3, 1])
    y_perm = moe_ffn(params, x[perm], CFG)
    np.testing.assert_allclose(np.asarray(y_perm), np.asarray(y[perm]),
                               atol=1e-5)


def test_moe_gradients_flow_to_router():
    params, _ = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, CFG.d_model))

    def loss(p):
        return jnp.sum(moe_ffn(p, x, CFG) ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_down"]).max()) > 0
