"""Sparse block format: CSR containers, the padded-ELL partition, the
gather-based local solvers/kernels, and sparse == dense equivalence of
the full solver matrix (the shard_map side runs in a subprocess with a
forced device grid)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, get_solver,
                        partition, partition_sparse)
from repro.core.local import (local_sdca, local_sdca_sparse, local_svrg,
                              local_svrg_sparse)
from repro.core.losses import get_loss
from repro.data import (CSRMatrix, csr_from_dense, load_libsvm,
                        load_libsvm_csr, make_sparse_svm_csr,
                        make_sparse_svm_data, save_libsvm)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

LAM = 1.0
RNG = np.random.default_rng(23)


def _instance():
    """120 x 41 at 15% density: P*Q = 8 does not divide m = 41 (pads to
    m_q = 24), and zeroing columns 24+ leaves feature block q=1 entirely
    zero -- the two padding edge cases the format must survive."""
    X, y = make_sparse_svm_data(120, 41, density=0.15, seed=7)
    X[:, 24:] = 0.0
    return X, y


# ---------------------------------------------------------------------------
# host-side containers
# ---------------------------------------------------------------------------

def test_csr_roundtrip_and_products():
    X, y = _instance()
    csr = csr_from_dense(X)
    assert csr.shape == X.shape
    assert csr.nnz == int((X != 0).sum())
    np.testing.assert_array_equal(csr.toarray(), X)
    w = RNG.normal(size=X.shape[1]).astype(np.float32)
    a = RNG.normal(size=X.shape[0]).astype(np.float32)
    np.testing.assert_allclose(np.asarray(csr @ w), X @ w,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(csr.T @ a), X.T @ a,
                               rtol=1e-4, atol=1e-4)


def test_libsvm_csr_streams_without_densifying(tmp_path):
    X, y = _instance()
    path = str(tmp_path / "inst.svm")
    save_libsvm(path, X, y)
    Xd, yd = load_libsvm(path)
    csr, yc = load_libsvm_csr(path)
    assert isinstance(csr, CSRMatrix)
    np.testing.assert_array_equal(csr.toarray(), Xd)
    np.testing.assert_array_equal(yc, yd)


def test_make_sparse_svm_csr_properties():
    csr, y = make_sparse_svm_csr(300, 80, density=0.05, seed=3)
    assert csr.shape == (300, 80)
    assert set(np.unique(y)) <= {-1.0, 1.0}
    assert 0.02 < csr.density < 0.10
    # standardized: unit variance on columns that have entries
    Xd = csr.toarray()
    std = Xd.std(axis=0)
    np.testing.assert_allclose(std[std > 0], 1.0, atol=1e-4)
    # every row has at least one entry (labels carry signal)
    assert csr.row_nnz().min() >= 1


# ---------------------------------------------------------------------------
# padded-ELL partition
# ---------------------------------------------------------------------------

def test_partition_sparse_matches_dense_blocks():
    X, y = _instance()
    sp = partition_sparse(X, y, 4, 2, m_multiple=8)
    dn = partition(X, y, 4, 2, m_multiple=8)
    assert sp.m_q == dn.m_q and sp.n_p == dn.n_p
    Xs, ys = sp.dense()
    Xd, yd = dn.dense()
    np.testing.assert_allclose(Xs, np.asarray(Xd), atol=1e-6)
    np.testing.assert_array_equal(ys, np.asarray(yd))
    np.testing.assert_array_equal(np.asarray(sp.mask), np.asarray(dn.mask))
    # CSR input produces the identical partition
    sp2 = partition_sparse(csr_from_dense(X), y, 4, 2, m_multiple=8)
    np.testing.assert_array_equal(np.asarray(sp2.cols), np.asarray(sp.cols))
    np.testing.assert_array_equal(np.asarray(sp2.vals), np.asarray(sp.vals))


def test_cell_buffers_scale_with_nnz():
    """The acceptance-criterion assert: peak block memory is O(nnz)
    (via the cell buffer shapes), not O(n_p * m_q)."""
    n, m = 256, 400
    csr, y = make_sparse_svm_csr(n, m, density=0.02, seed=1)
    sp = partition_sparse(csr, y, 4, 2, m_multiple=8)
    # ELL width tracks the max per-cell-row nonzero count (lane-rounded),
    # far below the dense block width
    assert sp.cols.shape == (4, 2, sp.n_p, sp.k)
    assert sp.k < sp.m_q // 4
    # total cell elements beat the dense grid by a wide margin
    dense_elems = 4 * 2 * sp.n_p * sp.m_q
    assert sp.vals.size < dense_elems / 4
    # k is exactly the lane-rounded max cell-row count, i.e. nnz-driven
    q_of = np.minimum(csr.indices // sp.m_q, 1)
    counts = np.zeros((n, 2), dtype=int)
    np.add.at(counts, (csr.row_ids(), q_of), 1)
    k_exact = int(counts.max())
    assert sp.k == -(-max(k_exact, 1) // 8) * 8
    # denser instance -> wider ELL, same m_q
    csr2, y2 = make_sparse_svm_csr(n, m, density=0.08, seed=1)
    sp2 = partition_sparse(csr2, y2, 4, 2, m_multiple=8)
    assert sp2.k > sp.k and sp2.m_q == sp.m_q


# ---------------------------------------------------------------------------
# sparse local solvers: dense parity and ref <-> pallas parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss_name", ["hinge", "squared"])
@pytest.mark.parametrize("step_mode", ["exact", "beta"])
def test_local_sdca_sparse_parity(loss_name, step_mode):
    loss = get_loss(loss_name)
    X, y = _instance()
    # P = Q = 1: the single ELL cell covers the whole (unpadded) matrix,
    # so the dense local solver is directly comparable
    sp = partition_sparse(X, y, 1, 1, k_multiple=8)
    assert sp.m_q == X.shape[1]
    x = jnp.asarray(X)
    cols, vals = sp.cols[0, 0], sp.vals[0, 0]
    mask = jnp.ones((sp.n_p,)).at[-3:].set(0.0)
    a0 = jnp.zeros((sp.n_p,))
    w0 = jnp.asarray(RNG.normal(size=sp.m_q) * 0.1, jnp.float32)
    kw = dict(lam=0.2, n=200, Q=3, steps=48, key=jax.random.PRNGKey(5),
              step_mode=step_mode, beta=float(sp.m_q))
    d_dense = local_sdca(loss, x, sp.y_blocks[0], mask, a0, w0,
                         backend="ref", **kw)
    d_ref = local_sdca_sparse(loss, cols, vals, sp.y_blocks[0], mask, a0,
                              w0, backend="ref", **kw)
    d_pal = local_sdca_sparse(loss, cols, vals, sp.y_blocks[0], mask, a0,
                              w0, backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(d_pal), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(d_pal[-3:]), 0.0)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("loss_name", ["hinge", "squared"])
@pytest.mark.parametrize("lo", [None, 8])
def test_local_svrg_sparse_parity(loss_name, lo):
    loss = get_loss(loss_name)
    X, y = _instance()
    sp = partition_sparse(X, y, 1, 1, k_multiple=8)
    assert sp.m_q == X.shape[1]
    x = jnp.asarray(X)
    cols, vals = sp.cols[0, 0], sp.vals[0, 0]
    mask = jnp.ones((sp.n_p,))
    m_sub = sp.m_q if lo is None else 8
    wa = jnp.asarray(RNG.normal(size=m_sub) * 0.2, jnp.float32)
    za = jnp.asarray(RNG.normal(size=sp.n_p) * 0.3, jnp.float32)
    mu = jnp.asarray(RNG.normal(size=m_sub) * 0.05, jnp.float32)
    kw = dict(lam=0.1, L=32, eta=0.03, key=jax.random.PRNGKey(9), lo=lo)
    w_dense = local_svrg(loss, x, sp.y_blocks[0], mask, za, wa, mu,
                         backend="ref", **kw)
    w_ref = local_svrg_sparse(loss, cols, vals, sp.y_blocks[0], mask, za,
                              wa, mu, backend="ref", **kw)
    w_pal = local_svrg_sparse(loss, cols, vals, sp.y_blocks[0], mask, za,
                              wa, mu, backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(w_ref), np.asarray(w_dense),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(w_pal), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# full-solver equivalence, simulated engine (the shard_map side of the
# matrix runs in the subprocess below)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,cfg", [
    ("d3ca", D3CAConfig(lam=LAM, outer_iters=3, local_steps=12)),
    ("radisa", RADiSAConfig(lam=LAM, gamma=0.03, outer_iters=3, L=12)),
    ("radisa", RADiSAConfig(lam=LAM, gamma=0.03, outer_iters=2, L=12,
                            variant="avg")),
    ("admm", ADMMConfig(lam=LAM, rho=LAM, outer_iters=4)),
])
def test_sparse_matches_dense_simulated(name, cfg):
    X, y = _instance()
    base = get_solver(name)(engine="simulated", local_backend="ref").solve(
        "hinge", X, y, P=4, Q=2, cfg=cfg, record_history=False)
    backends = ("ref",) if name == "admm" else ("ref", "pallas")
    for backend in backends:
        rs = get_solver(name)(engine="simulated", local_backend=backend,
                              block_format="sparse").solve(
            "hinge", csr_from_dense(X), y, P=4, Q=2, cfg=cfg,
            record_history=False)
        assert rs.block_format == "sparse"
        np.testing.assert_allclose(np.asarray(rs.w), np.asarray(base.w),
                                   rtol=2e-4, atol=2e-4)
        if base.alpha is not None:
            np.testing.assert_allclose(
                np.asarray(rs.alpha), np.asarray(base.alpha),
                rtol=2e-4, atol=2e-4)


def test_block_format_knob_validation():
    with pytest.raises(ValueError, match="block_format"):
        get_solver("d3ca")(block_format="csc")


def test_optimize_cli_sparse_all_solvers(capsys):
    from repro.launch.optimize import main as optimize_main
    for solver in ("d3ca", "radisa", "admm"):
        summary = optimize_main([
            "--solver", solver, "--dataset", "sparse", "--density", "0.05",
            "--n", "96", "--m", "40", "--block-format", "sparse",
            "--iters", "2", "--ref-epochs", "5"])
        assert summary["block_format"] == "sparse"
        assert summary["objective"] is not None
    capsys.readouterr()


# ---------------------------------------------------------------------------
# shard_map side of the matrix (subprocess: forced device count)
# ---------------------------------------------------------------------------

@pytest.mark.shard_map
def test_shard_map_sparse_matches_simulated_dense():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "sparse_equiv.py")],
        env=ENV, timeout=600, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
