"""Data pipeline: determinism, sharding invariance, libsvm roundtrip."""
import numpy as np

from repro.data import (TokenPipeline, load_libsvm, make_sparse_svm_data,
                        make_svm_data, save_libsvm, synthetic_token_batch)


def test_token_batch_deterministic():
    a = synthetic_token_batch(3, batch=8, seq=16, vocab=100)
    b = synthetic_token_batch(3, batch=8, seq=16, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_token_batch(4, batch=8, seq=16, vocab=100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_token_batch_shard_invariance():
    """Re-sharding (elastic scaling) replays identical global data."""
    full = synthetic_token_batch(5, batch=8, seq=12, vocab=50, shard=(0, 1))
    half0 = synthetic_token_batch(5, batch=8, seq=12, vocab=50, shard=(0, 2))
    half1 = synthetic_token_batch(5, batch=8, seq=12, vocab=50, shard=(1, 2))
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([half0["tokens"], half1["tokens"]]))
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])


def test_pipeline_prefetch_order():
    pipe = TokenPipeline(lambda s: {"s": np.array([s])}, depth=2)
    try:
        got = [next(pipe) for _ in range(5)]
        assert [g[0] for g in got] == [0, 1, 2, 3, 4]
        assert all(int(g[1]["s"][0]) == g[0] for g in got)
    finally:
        pipe.close()


def test_libsvm_roundtrip(tmp_path):
    X, y = make_sparse_svm_data(20, 15, density=0.3, seed=0)
    p = str(tmp_path / "data.svm")
    save_libsvm(p, X, y)
    X2, y2 = load_libsvm(p, n_features=15)
    np.testing.assert_allclose(X2, X, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(y2, y)


def test_svm_generator_matches_paper_spec():
    X, y = make_svm_data(500, 40, seed=0, standardize=True)
    assert set(np.unique(y)) == {-1.0, 1.0}
    np.testing.assert_allclose(X.std(axis=0), 1.0, atol=1e-6)
    # ~10% label noise: a linear model can't be perfect but beats chance
    assert 0.05 < (y == 1).mean() < 0.95
