"""Distributed engines == simulated engines, and dry-run smoke, on 8
forced host devices (subprocesses: the device count must be fixed before
jax initializes)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def _run(script, timeout=600):
    return subprocess.run([sys.executable, script], env=ENV, timeout=timeout,
                          capture_output=True, text=True, cwd=ROOT)


@pytest.mark.shard_map
def test_shard_map_engines_match_simulated():
    r = _run(os.path.join(ROOT, "tests", "helpers", "dist_equiv.py"))
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.shard_map
def test_dryrun_small_mesh():
    r = _run(os.path.join(ROOT, "tests", "helpers", "dryrun_small.py"))
    assert r.returncode == 0, r.stdout + r.stderr
