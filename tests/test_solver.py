"""Unified solver framework: registry, the engine x local_backend matrix,
the shared driver (history / early stopping / warm starts), and the
ref<->pallas parity of the cell-local solvers."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, SFKConfig,
                        available_solvers, get_solver, objective,
                        serial_sdca)
from repro.core.local import local_sdca, local_svrg
from repro.core.losses import get_loss
from repro.data import make_svm_data

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

LAM = 1.0
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def problem():
    X, y = make_svm_data(120, 36, seed=1)
    w_ref, _ = serial_sdca("hinge", X, y, lam=LAM, epochs=200)
    f_star = float(objective("hinge", X, y, w_ref, LAM))
    return X, y, f_star


# ---------------------------------------------------------------------------
# registry + knob validation
# ---------------------------------------------------------------------------

def test_registry():
    assert available_solvers() == ["admm", "d3ca", "radisa", "sfk"]
    for name in available_solvers():
        cls = get_solver(name)
        assert cls.name == name
        assert cls.config_cls is not None
    with pytest.raises(KeyError, match="unknown solver"):
        get_solver("sgd")
    with pytest.raises(ValueError, match="engine"):
        get_solver("d3ca")(engine="mpi")
    with pytest.raises(ValueError, match="local_backend"):
        get_solver("d3ca")(local_backend="triton")
    # the async engine is a first-class registry knob
    s = get_solver("d3ca")(engine="async", staleness=2)
    assert (s.engine, s.staleness) == ("async", 2)
    with pytest.raises(ValueError, match="needs engine='async'"):
        get_solver("d3ca")(staleness=1)


def test_simulated_needs_grid(problem):
    X, y, _ = problem
    with pytest.raises(ValueError, match="needs P and Q"):
        get_solver("d3ca")().solve("hinge", X, y)


def test_pallas_logistic_raises(problem):
    X, y, _ = problem
    s = get_solver("d3ca")(engine="simulated", local_backend="pallas")
    with pytest.raises(NotImplementedError, match="pallas"):
        s.solve("logistic", X, y, P=2, Q=2,
                cfg=D3CAConfig(lam=LAM, outer_iters=1, local_steps=4))


# ---------------------------------------------------------------------------
# simulated engine: ref == pallas for every solver (the shard_map side of
# the matrix runs in the subprocess test below)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,cfg", [
    ("d3ca", D3CAConfig(lam=LAM, outer_iters=3, local_steps=12)),
    ("d3ca", D3CAConfig(lam=LAM, outer_iters=2, local_steps=12,
                        step_mode="beta")),
    ("radisa", RADiSAConfig(lam=LAM, gamma=0.03, outer_iters=3, L=12)),
    ("radisa", RADiSAConfig(lam=LAM, gamma=0.03, outer_iters=3, L=12,
                            variant="avg")),
    ("sfk", SFKConfig(lam=LAM, gamma=0.03, outer_iters=3, L=12)),
    ("admm", ADMMConfig(lam=LAM, rho=LAM, outer_iters=4)),
])
@pytest.mark.parametrize("loss", ["hinge", "squared"])
def test_simulated_ref_matches_pallas(problem, name, cfg, loss):
    X, y, _ = problem
    ws = {}
    for backend in ("ref", "pallas"):
        s = get_solver(name)(engine="simulated", local_backend=backend)
        ws[backend] = s.solve(loss, X, y, P=3, Q=2, cfg=cfg,
                              record_history=False).w
    np.testing.assert_allclose(np.asarray(ws["pallas"]),
                               np.asarray(ws["ref"]), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# shared driver: history, early stopping, warm starts
# ---------------------------------------------------------------------------

def test_history_and_duality_gap(problem):
    X, y, f_star = problem
    s = get_solver("d3ca")()
    res = s.solve("hinge", X, y, P=3, Q=2,
                  cfg=D3CAConfig(lam=LAM, outer_iters=6), f_star=f_star)
    assert len(res.history) == 6 and res.iters == 6 and not res.converged
    for h in res.history:
        assert set(h) >= {"iter", "time_s", "objective", "duality_gap",
                          "rel_opt"}
        assert h["duality_gap"] > -1e-6      # gap certifies optimality
    # objective decreases overall
    assert res.history[-1]["objective"] < res.history[0]["objective"]
    # radisa/admm are primal-only: no gap, no alpha
    res2 = get_solver("radisa")().solve(
        "hinge", X, y, P=3, Q=2,
        cfg=RADiSAConfig(lam=LAM, gamma=0.05, outer_iters=2))
    assert res2.alpha is None
    assert "duality_gap" not in res2.history[0]


def test_early_stopping_rel_opt(problem):
    X, y, f_star = problem
    s = get_solver("d3ca")()
    res = s.solve("hinge", X, y, P=3, Q=2,
                  cfg=D3CAConfig(lam=LAM, outer_iters=50),
                  f_star=f_star, tol=0.05)
    assert res.converged and res.iters < 50
    assert res.history[-1]["rel_opt"] < 0.05


def test_early_stopping_duality_gap(problem):
    X, y, _ = problem
    res = get_solver("d3ca")().solve(
        "hinge", X, y, P=3, Q=2, cfg=D3CAConfig(lam=LAM, outer_iters=60),
        tol=0.1)       # no f_star -> stops on the duality gap
    assert res.converged and res.iters < 60
    assert res.history[-1]["duality_gap"] < 0.1


def test_warm_start(problem):
    X, y, _ = problem
    s = get_solver("d3ca")()
    cfg = D3CAConfig(lam=LAM, outer_iters=4)
    r1 = s.solve("hinge", X, y, P=3, Q=2, cfg=cfg)
    r2 = s.solve("hinge", X, y, P=3, Q=2, cfg=cfg, warm_start=r1)
    # warm-started run continues to improve on the cold objective
    assert r2.history[-1]["objective"] < r1.history[-1]["objective"] + 1e-6
    # bare-w warm starts work for primal-only solvers
    r3 = get_solver("radisa")().solve(
        "hinge", X, y, P=3, Q=2,
        cfg=RADiSAConfig(lam=LAM, gamma=0.05, outer_iters=2),
        warm_start=r1.w)
    assert r3.history[-1]["objective"] < float(
        objective("hinge", X, y, jnp.zeros(X.shape[1]), LAM))


def test_callback_fires(problem):
    X, y, _ = problem
    seen = []
    get_solver("admm")().solve(
        "hinge", X, y, P=3, Q=2, cfg=ADMMConfig(lam=LAM, outer_iters=3),
        callback=lambda t, w, a: seen.append((t, w.shape, a)))
    assert [t for t, _, _ in seen] == [1, 2, 3]
    assert all(shape == (X.shape[1],) for _, shape, _ in seen)
    assert all(a is None for _, _, a in seen)


# ---------------------------------------------------------------------------
# cell-local solvers: ref <-> pallas parity across losses and step modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss_name", ["hinge", "squared"])
@pytest.mark.parametrize("step_mode", ["exact", "beta"])
def test_local_sdca_backend_parity(loss_name, step_mode):
    loss = get_loss(loss_name)
    n_p, m_q, steps = 24, 16, 48
    x = jnp.asarray(RNG.normal(size=(n_p, m_q)), jnp.float32)
    y = jnp.asarray(np.sign(RNG.normal(size=n_p)) + 0.0, jnp.float32)
    y = jnp.where(y == 0, 1.0, y)
    mask = jnp.ones((n_p,)).at[-3:].set(0.0)
    a0 = jnp.zeros((n_p,))
    w0 = jnp.asarray(RNG.normal(size=m_q) * 0.1, jnp.float32)
    key = jax.random.PRNGKey(5)
    # beta of the order of ||x||^2 keeps the squared-loss recursion
    # contractive (tiny beta amplifies f32 reduction-order noise)
    kw = dict(lam=0.2, n=200, Q=3, steps=steps, key=key,
              step_mode=step_mode, beta=float(m_q))
    d_ref = local_sdca(loss, x, y, mask, a0, w0, backend="ref", **kw)
    d_pal = local_sdca(loss, x, y, mask, a0, w0, backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(d_pal), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-5)
    # padded rows never move
    np.testing.assert_array_equal(np.asarray(d_pal[-3:]), 0.0)


@pytest.mark.parametrize("loss_name", ["hinge", "squared"])
@pytest.mark.parametrize("lo", [None, 8])
def test_local_svrg_backend_parity(loss_name, lo):
    loss = get_loss(loss_name)
    n_p, m_q, m_sub, L = 20, 16, 8, 32
    x = jnp.asarray(RNG.normal(size=(n_p, m_q)), jnp.float32)
    y = jnp.asarray(np.sign(RNG.normal(size=n_p)), jnp.float32)
    y = jnp.where(y == 0, 1.0, y)
    mask = jnp.ones((n_p,))
    m_eff = m_q if lo is None else m_sub
    wa = jnp.asarray(RNG.normal(size=m_eff) * 0.2, jnp.float32)
    za = jnp.asarray(RNG.normal(size=n_p) * 0.3, jnp.float32)
    mu = jnp.asarray(RNG.normal(size=m_eff) * 0.05, jnp.float32)
    key = jax.random.PRNGKey(9)
    kw = dict(lam=0.1, L=L, eta=0.03, key=key, lo=lo)
    w_ref = local_svrg(loss, x, y, mask, za, wa, mu, backend="ref", **kw)
    w_pal = local_svrg(loss, x, y, mask, za, wa, mu, backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(w_pal), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)


def test_local_pallas_rejects_logistic():
    loss = get_loss("logistic")
    x = jnp.ones((4, 3))
    with pytest.raises(NotImplementedError):
        local_sdca(loss, x, jnp.ones(4), jnp.ones(4), jnp.zeros(4),
                   jnp.zeros(3), lam=0.1, n=4, Q=1, steps=2,
                   key=jax.random.PRNGKey(0), backend="pallas")


# ---------------------------------------------------------------------------
# shard_map side of the matrix (subprocess: forced device count)
# ---------------------------------------------------------------------------

@pytest.mark.shard_map
def test_shard_map_pallas_matches_simulated_ref():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "solver_equiv.py"), "sync"],
        env=ENV, timeout=600, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.async_engine
def test_async_tau0_matches_shard_map_and_tau2_converges():
    """Engine API v2 staleness contract: async(staleness=0) == shard_map
    to 1e-8 for all solvers x block formats; staleness=2 still
    converges (see helpers/solver_equiv.py, mode 'async')."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "solver_equiv.py"), "async"],
        env=ENV, timeout=600, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.overlap
def test_overlap_tau0_bitidentical_and_tau2_matches_async():
    """Communication-overlap contract: overlap(staleness=0) is
    BIT-identical (diff 0.0) to shard_map for all solvers x block
    formats x backends; at staleness=2 the trajectory equals the async
    engine's; int8 composition and hierarchical topology runs hold (see
    helpers/solver_equiv.py, mode 'overlap')."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "solver_equiv.py"), "overlap"],
        env=ENV, timeout=600, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
