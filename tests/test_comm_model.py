"""Alpha-beta wire-time model (core/comm_model.py) and the adaptive
compression schedule: closed-form collective times, topology spec
parsing, hierarchical byte accounting, link fitting, and the
hidden-vs-exposed overlap split.  Pure host-side math -- no devices."""
import math

import pytest

from repro.core.comm_model import (INTER_POD_LINK, INTRA_POD_LINK, LinkModel,
                                   Topology, as_topology, collective_time,
                                   fit_link, hierarchical_accounting,
                                   overlap_split, predict_comm_s)
from repro.core.compress import (CompressionPolicy, CompressionSchedule,
                                 as_compression)


# ---------------------------------------------------------------------------
# link + closed-form collective times
# ---------------------------------------------------------------------------

def test_link_model_validation_and_bandwidth():
    link = LinkModel(1e-6, 1.0 / 100e9)
    assert link.bandwidth_gbps == pytest.approx(100.0)
    assert LinkModel(0.0, 0.0).bandwidth_gbps == math.inf
    with pytest.raises(ValueError, match=">= 0"):
        LinkModel(-1e-6, 0.0)


def test_allreduce_ring_formula():
    # ring: 2(k-1) alpha + 2(k-1)/k n beta -- the classic factor
    a, b, n, k = 2e-6, 1e-9, 4096.0, 8
    link = LinkModel(a, b)
    expect = 2 * (k - 1) * a + 2 * (k - 1) / k * n * b
    assert collective_time("psum", n, k, link, "ring") == pytest.approx(
        expect)
    # pmean costs the same wire (division is local)
    assert collective_time("pmean", n, k, link, "ring") == pytest.approx(
        expect)


def test_allreduce_tree_formula():
    a, b, n, k = 2e-6, 1e-9, 4096.0, 6          # non-power-of-2: ceil(log2)
    link = LinkModel(a, b)
    h = math.ceil(math.log2(k))
    assert collective_time("psum", n, k, link, "tree") == pytest.approx(
        2 * h * (a + n * b))


def test_allgather_formulas():
    a, b, n, k = 2e-6, 1e-9, 1024.0, 4
    link = LinkModel(a, b)
    assert collective_time("allgather", n, k, link, "ring") == pytest.approx(
        (k - 1) * (a + n * b))
    assert collective_time("allgather", n, k, link, "tree") == pytest.approx(
        math.ceil(math.log2(k)) * a + (k - 1) * n * b)


def test_collective_time_degenerate_and_errors():
    link = LinkModel(1e-6, 1e-9)
    assert collective_time("psum", 1024.0, 1, link) == 0.0    # k=1: no wire
    assert collective_time("psum", 0.0, 8, link) == 0.0
    with pytest.raises(ValueError, match="algorithm"):
        collective_time("psum", 64.0, 4, link, "butterfly")
    with pytest.raises(ValueError, match="op"):
        collective_time("reduce", 64.0, 4, link)


def test_ring_beats_tree_on_bandwidth_tree_on_latency():
    # the reason both algos exist: ring is bandwidth-optimal (big n),
    # tree is latency-optimal (large k, small n)
    fat = LinkModel(1e-6, 1e-9)
    big, small, k = 1e8, 8.0, 64
    assert (collective_time("psum", big, k, fat, "ring")
            < collective_time("psum", big, k, fat, "tree"))
    assert (collective_time("psum", small, k, fat, "tree")
            < collective_time("psum", small, k, fat, "ring"))


# ---------------------------------------------------------------------------
# topology specs
# ---------------------------------------------------------------------------

def test_topology_spec_roundtrip():
    t = Topology.from_spec("pods=4:int8:tree")
    assert (t.pods, t.codec, t.algo) == (4, "int8", "tree")
    assert Topology.from_spec(t.spec) == t
    # defaults fill in
    t2 = Topology.from_spec("pods=2")
    assert (t2.codec, t2.algo) == ("identity", "ring")
    assert t2.hierarchical() and not Topology(pods=1).hierarchical()


def test_topology_spec_errors():
    for bad in ("", "2", "pods=x", "pods=2:int8:tree:extra"):
        with pytest.raises(ValueError, match="spec|pod count"):
            Topology.from_spec(bad)
    with pytest.raises(ValueError, match="pods"):
        Topology(pods=0)
    with pytest.raises(ValueError, match="algo"):
        Topology(pods=2, algo="butterfly")


def test_as_topology():
    assert as_topology(None) is None
    assert as_topology("pods=2").pods == 2
    t = Topology(pods=3)
    assert as_topology(t) is t


# ---------------------------------------------------------------------------
# accounting -> predicted seconds
# ---------------------------------------------------------------------------

def _acct(per_cell=4096, cells=8, op="psum", axis="data", name="g"):
    """Minimal wire_accounting dict with one collective."""
    return {"collectives": {
        name: {"payload_bytes_per_cell": per_cell, "cells": cells,
               "bytes_per_step": per_cell * cells, "op": op, "axis": axis}},
        "bytes_per_step": per_cell * cells,
        "uncompressed_bytes_per_step": per_cell * cells}


def test_predict_comm_s_flat():
    acct = _acct(per_cell=4096, op="psum", axis="data")
    link = LinkModel(1e-6, 1e-9)
    pred = predict_comm_s(acct, {"data": 4, "model": 2}, link=link)
    assert pred["total_s"] == pytest.approx(
        collective_time("psum", 4096, 4, link, "ring"))
    assert pred["collectives"]["g"]["k"] == 4


def test_predict_comm_s_hierarchical_sums_stages():
    topo = Topology(pods=2, codec="identity")
    acct = _acct(per_cell=4096, axis="data")
    pred = predict_comm_s(acct, {"data": 8, "model": 1}, topology=topo)
    c = pred["collectives"]["g"]
    intra = collective_time("psum", 4096, 4, topo.intra, "ring")
    inter = collective_time("psum", 4096, 2, topo.inter, "ring")
    assert c["intra_s"] == pytest.approx(intra)
    assert c["inter_s"] == pytest.approx(inter)
    assert pred["total_s"] == pytest.approx(intra + inter)


def test_hierarchical_accounting_tiers():
    # 8 data cells, 2 pods: intra carries the full per-cell payload per
    # cell; inter carries one codec payload per pod
    acct = _acct(per_cell=4096, cells=8, axis="data")
    topo = Topology(pods=2, codec="identity")
    out = hierarchical_accounting(acct, topo, {"data": 8, "model": 1})
    c = out["collectives"]["g"]
    assert c["intra_bytes_per_step"] == 4096 * 8
    assert c["inter_bytes_per_step"] == 4096 * 2
    assert out["bytes_per_step"] == 4096 * 10
    assert out["topology"] == topo.spec
    # int8 shrinks ONLY the inter-pod tier
    out8 = hierarchical_accounting(acct, Topology(pods=2, codec="int8"),
                                   {"data": 8, "model": 1})
    c8 = out8["collectives"]["g"]
    assert c8["intra_bytes_per_step"] == c["intra_bytes_per_step"]
    assert c8["inter_bytes_per_step"] < c["inter_bytes_per_step"] / 3
    # flat topology (or None) is a no-op passthrough
    assert hierarchical_accounting(acct, None, {}) is acct
    assert hierarchical_accounting(acct, Topology(pods=1), {}) is acct
    # collectives over OTHER axes are untouched
    other = _acct(per_cell=512, cells=8, axis="model")
    o = hierarchical_accounting(other, topo, {"data": 8, "model": 1})
    assert o["collectives"]["g"]["inter_bytes_per_step"] == 0.0
    assert o["collectives"]["g"]["bytes_per_step"] == 512 * 8


# ---------------------------------------------------------------------------
# link fitting
# ---------------------------------------------------------------------------

def test_fit_link_recovers_known_parameters():
    true = LinkModel(3e-6, 2e-9)
    samples = []
    for per_cell, k in ((1024, 4), (8192, 4), (65536, 8), (256, 8)):
        acct = _acct(per_cell=per_cell, cells=k, axis="data")
        sizes = {"data": k, "model": 1}
        t = predict_comm_s(acct, sizes, link=true)["total_s"]
        samples.append((acct, sizes, t))
    fit = fit_link(samples)
    assert fit.alpha_s == pytest.approx(true.alpha_s, rel=1e-6)
    assert fit.beta_s_per_byte == pytest.approx(true.beta_s_per_byte,
                                                rel=1e-6)


def test_fit_link_clamps_and_degenerates():
    acct = _acct(per_cell=4096, cells=4, axis="data")
    sizes = {"data": 4, "model": 1}
    # a single sample: falls back to a 1-parameter fit, still >= 0
    one = fit_link([(acct, sizes, 1e-3)])
    assert one.alpha_s >= 0 and one.beta_s_per_byte >= 0
    assert predict_comm_s(acct, sizes, link=one)["total_s"] > 0
    # no usable samples -> the zero link, not an exception
    empty = fit_link([])
    assert (empty.alpha_s, empty.beta_s_per_byte) == (0.0, 0.0)
    solo = fit_link([(_acct(per_cell=64, cells=1, axis="data"),
                      {"data": 1, "model": 1}, 1e-3)])   # k=1: no wire
    assert (solo.alpha_s, solo.beta_s_per_byte) == (0.0, 0.0)


# ---------------------------------------------------------------------------
# overlap split
# ---------------------------------------------------------------------------

def test_overlap_split():
    # tau steps of local work hide up to tau * local_s of wire
    s = overlap_split(comm_s=3.0, local_s=1.0, tau=2)
    assert s == {"comm_hidden_s": 2.0, "comm_exposed_s": 1.0}
    # everything hidden when the wire fits in the window
    s = overlap_split(comm_s=1.5, local_s=1.0, tau=2)
    assert s["comm_exposed_s"] == 0.0 and s["comm_hidden_s"] == 1.5
    # tau = 0 exposes everything (the sync/async engines)
    s = overlap_split(comm_s=3.0, local_s=1.0, tau=0)
    assert s["comm_hidden_s"] == 0.0 and s["comm_exposed_s"] == 3.0
    # negative inputs clamp instead of going nonsensical
    s = overlap_split(comm_s=-1.0, local_s=1.0, tau=2)
    assert s == {"comm_hidden_s": 0.0, "comm_exposed_s": 0.0}


# ---------------------------------------------------------------------------
# adaptive compression schedule
# ---------------------------------------------------------------------------

def test_schedule_spec_parsing_and_roundtrip():
    s = CompressionSchedule.from_spec("adaptive")
    assert [p.spec for p in s.stages] == ["topk:0.25", "int8"]
    s2 = CompressionSchedule.from_spec(
        "adaptive:topk:0.1->int8->identity@slope=0.02@window=4")
    assert [p.spec for p in s2.stages] == ["topk:0.1", "int8", "identity"]
    assert (s2.slope_tol, s2.window) == (0.02, 4)
    # canonical spec round-trips
    assert CompressionSchedule.from_spec(s2.spec).spec == s2.spec


def test_schedule_spec_errors():
    with pytest.raises(ValueError, match="adaptive"):
        CompressionSchedule.from_spec("int8->identity")
    with pytest.raises(ValueError, match="unknown adaptive option"):
        CompressionSchedule.from_spec("adaptive@rate=2")
    with pytest.raises(ValueError, match="window"):
        CompressionSchedule(window=0)


def test_schedule_should_advance():
    s = CompressionSchedule(slope_tol=0.05, window=3)
    # too little history: never advance
    assert not s.should_advance([1.0, 0.9])
    # steep progress (a decade per iteration): keep the aggressive codec
    assert not s.should_advance([1.0, 0.1, 0.01, 1e-3])
    # flat progress: advance
    assert s.should_advance([0.5, 0.5, 0.5, 0.5])


def test_as_compression_dispatch():
    assert as_compression(None) is None
    assert isinstance(as_compression("int8"), CompressionPolicy)
    assert isinstance(as_compression("adaptive"), CompressionSchedule)
    sched = CompressionSchedule()
    assert as_compression(sched) is sched
