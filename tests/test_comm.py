"""Engine API v2: CommSchedule declaration contract, collective
execution under named-vmap grids, the StaleComm FIFO semantics
(value applied at t is the reduction computed at max(1, t - tau)),
the OverlapComm executor (identical consumption contract, overlapped
wire), and the hierarchical two-level reduction (set_topology).

Everything here runs on ONE device: the grid engine uses named vmap
axes, and the mesh/staleness tests use a 1x1 mesh (collectives become
identities there, which isolates the delay semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import (Collective, CommSchedule, OverlapComm,
                             StaleComm, SyncComm, hier_ef_names)
from repro.core.comm_model import Topology
from repro.core.compress import get_codec
from repro.core.engines import CellProgram, grid_program, mesh_program


# ---------------------------------------------------------------------------
# schedule declaration contract
# ---------------------------------------------------------------------------

def test_schedule_declaration():
    sched = (CommSchedule()
             .psum("rhs", axis="data")
             .pmean("dalpha", axis="model")
             .allgather("alpha", axis="data"))
    assert sched.names == ("rhs", "dalpha", "alpha")
    assert "rhs" in sched and "nope" not in sched
    assert sched["rhs"].op == "psum"
    assert sched["dalpha"].result_axis == "data"
    assert sched["rhs"].result_axis == "model"


def test_schedule_rejects_duplicates_and_bad_axes():
    with pytest.raises(ValueError, match="declared twice"):
        CommSchedule().psum("x", axis="data").pmean("x", axis="model")
    with pytest.raises(ValueError, match="axis"):
        CommSchedule().psum("x", axis="rows")
    with pytest.raises(ValueError, match="op"):
        Collective("x", "allreduce", "data")


def test_schedule_unknown_lookup_message():
    sched = CommSchedule().psum("declared", axis="data")
    with pytest.raises(KeyError, match="not declared in this CommSchedule"):
        sched["other"]


def test_comm_contract_checks():
    sched = CommSchedule().psum("a", axis="data").psum("b", axis="model")
    axis_map = {"data": ("d",), "model": ("m",)}

    def cell_twice(x):
        comm = SyncComm(sched, axis_map, {"data": 2, "model": 1})
        comm("a", x)
        return comm("a", x)                 # same point twice -> error

    with pytest.raises(ValueError, match="executed twice"):
        jax.vmap(jax.vmap(cell_twice, axis_name="m"), axis_name="d")(
            jnp.ones((2, 1)))

    def cell_partial(x):
        comm = SyncComm(sched, axis_map, {"data": 2, "model": 1})
        out = comm("a", x)
        comm.finalize()                     # "b" never executed -> error
        return out

    with pytest.raises(ValueError, match="never executed"):
        jax.vmap(jax.vmap(cell_partial, axis_name="m"), axis_name="d")(
            jnp.ones((2, 1)))


# ---------------------------------------------------------------------------
# collective execution under named vmap (the grid engine's substrate)
# ---------------------------------------------------------------------------

def test_sync_comm_under_named_vmap():
    sched = (CommSchedule()
             .psum("s", axis="data")
             .pmean("m", axis="model")
             .allgather("g", axis="data"))
    axis_map = {"data": ("d",), "model": ("m",)}
    vals = jnp.arange(6.0).reshape(3, 2)        # grid P=3, Q=2

    def cell(x):
        comm = SyncComm(sched, axis_map, {"data": 3, "model": 2})
        out = (comm("s", x), comm("m", x), comm("g", x),
               comm.axis_index("data"), comm.axis_index("model"))
        comm.finalize()
        assert comm.axis_size("data") == 3
        return out

    s, m, g, p, q = jax.vmap(jax.vmap(cell, axis_name="m"),
                             axis_name="d")(vals)
    np.testing.assert_allclose(np.asarray(s), np.asarray(
        vals.sum(axis=0, keepdims=True).repeat(3, 0)))
    np.testing.assert_allclose(np.asarray(m), np.asarray(
        vals.mean(axis=1, keepdims=True).repeat(2, 1)))
    assert g.shape == (3, 2, 3)                  # per-cell gather over data
    np.testing.assert_allclose(np.asarray(g[0, 1]), np.asarray(vals[:, 1]))
    np.testing.assert_array_equal(np.asarray(p), [[0, 0], [1, 1], [2, 2]])
    np.testing.assert_array_equal(np.asarray(q), [[0, 1], [0, 1], [0, 1]])


# ---------------------------------------------------------------------------
# StaleComm FIFO semantics via the mesh executor on a 1x1 mesh
# ---------------------------------------------------------------------------

def _delay_program():
    """A cell whose single collective carries f(t) = t as payload; the
    state records what the comm handed back, so the returned sequence
    exposes the delay directly."""
    sched = CommSchedule().psum("probe", axis="data")

    def cell(comm, t, data, state):
        seen = comm("probe", jnp.float32(t) * data)
        return seen
    # data: a scalar-per-cell array; state: the last value seen
    return CellProgram(sched, cell, data_specs=(None,), state_specs=(None,))


@pytest.mark.parametrize("tau", [1, 2, 3])
def test_stale_comm_bounded_delay(tau):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cellprog = _delay_program()
    data = jnp.ones((1,))
    state0 = jnp.zeros((1,))
    step, comm0, acct = mesh_program(cellprog, mesh, data, state0,
                                     staleness=tau)
    assert set(comm0) == {"stale"}
    assert comm0["stale"]["probe"].shape == (1, 1, tau, 1)
    # wire accounting comes back from every engine binding: the probe
    # payload is one f32 per cell per step
    assert acct["collectives"]["probe"]["bytes_per_step"] == 4
    assert acct["bytes_per_step"] == acct["uncompressed_bytes_per_step"]
    state = (state0, comm0)
    seen = []
    for t in range(1, 9):
        state = step(t, data, state)
        seen.append(float(state[0][0]))
    # contract: value applied at t is the reduction computed at
    # max(1, t - tau)
    expect = [float(max(1, t - tau)) for t in range(1, 9)]
    assert seen == expect, (tau, seen, expect)


def test_stale_tau0_is_sync():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cellprog = _delay_program()
    data = jnp.ones((1,))
    state0 = jnp.zeros((1,))
    step, comm0, _ = mesh_program(cellprog, mesh, data, state0, staleness=0)
    assert comm0 == {}
    state = (state0, comm0)
    for t in range(1, 5):
        state = step(t, data, state)
        assert float(state[0][0]) == float(t)    # no delay at tau = 0


def test_stale_comm_rejects_negative_tau():
    with pytest.raises(ValueError, match="must be >= 0"):
        StaleComm(CommSchedule(), {"data": ("d",), "model": ("m",)},
                  {"data": 1, "model": 1}, tau=-1, t=1)


def test_stale_warmup_pins_first_reduction():
    """Warm-up contract (see the StaleComm docstring): at t = 1 every
    ring slot is seeded with the FIRST reduction, so steps 1..tau+1 all
    consume step 1's value -- never zeros from initialization, never a
    partially-filled ring."""
    tau = 3
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = jnp.ones((1,))
    step, comm0, _ = mesh_program(_delay_program(), mesh, data,
                                  jnp.zeros((1,)), staleness=tau)
    state = (jnp.zeros((1,)), comm0)
    seen = []
    for t in range(1, tau + 3):
        state = step(t, data, state)
        seen.append(float(state[0][0]))
    # steps 1..tau+1 consume step 1's value; tau+2 consumes step 2's
    assert seen[:tau + 1] == [1.0] * (tau + 1)
    assert seen[tau + 1] == 2.0


# ---------------------------------------------------------------------------
# OverlapComm: same consumption contract, overlapped wire
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [0, 2])
def test_overlap_comm_matches_stale_delay(tau):
    """The overlap engine changes wall-clock, never numerics: at every
    tau its per-step outputs equal StaleComm's bit for bit (tau = 0 is
    the sync engine)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = jnp.ones((1,))
    state0 = jnp.zeros((1,))
    step_s, comm_s, _ = mesh_program(_delay_program(), mesh, data, state0,
                                     staleness=tau)
    step_o, comm_o, _ = mesh_program(_delay_program(), mesh, data, state0,
                                     staleness=tau, overlap=True)
    assert jax.tree_util.tree_structure(comm_s) \
        == jax.tree_util.tree_structure(comm_o)
    ss, so = (state0, comm_s), (state0, comm_o)
    for t in range(1, 8):
        ss, so = step_s(t, data, ss), step_o(t, data, so)
        assert float(ss[0][0]) == float(so[0][0]), t


def test_overlap_comm_class_contract():
    kw = dict(tau=2, t=1)
    oc = OverlapComm(CommSchedule(), {"data": ("d",), "model": ("m",)},
                     {"data": 1, "model": 1}, **kw)
    assert oc.overlap and isinstance(oc, StaleComm)
    stale = StaleComm(CommSchedule(), {"data": ("d",), "model": ("m",)},
                      {"data": 1, "model": 1}, **kw)
    assert not getattr(stale, "overlap", False)


def test_wire_bytes_additive_across_executors():
    """Byte accounting is additive, not policy-dependent: the staleness
    ring only re-times consumption, so sync / stale / overlap report
    identical totals for the identity wire."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = jnp.ones((1,))
    state0 = jnp.zeros((1,))
    accts = {}
    for label, kw in (("sync", dict(staleness=0)),
                      ("stale", dict(staleness=2)),
                      ("overlap", dict(staleness=2, overlap=True))):
        _, _, acct = mesh_program(_delay_program(), mesh, data, state0, **kw)
        accts[label] = acct
    base = accts["sync"]
    for label, acct in accts.items():
        assert acct["bytes_per_step"] == base["bytes_per_step"], label
        assert acct["bytes_per_step"] == acct["uncompressed_bytes_per_step"]
        assert {n: c["bytes_per_step"]
                for n, c in acct["collectives"].items()} \
            == {n: c["bytes_per_step"]
                for n, c in base["collectives"].items()}, label


# ---------------------------------------------------------------------------
# hierarchical two-level reduction (set_topology)
# ---------------------------------------------------------------------------

def _hier_run(cell, pods, per_pod, payload):
    """Run `cell(x)` under a (pod, d) two-level named-vmap split."""
    return jax.vmap(jax.vmap(cell, axis_name="d"),
                    axis_name="pod")(payload.reshape(pods, per_pod))


def test_hierarchical_psum_matches_flat():
    """identity topology codec: intra-pod psum + cross-pod psum == the
    flat psum over all cells (up to f32 reassociation)."""
    sched = CommSchedule().psum("s", axis="data").pmean("m", axis="data")
    axis_map = {"data": ("pod", "d"), "model": ()}
    sizes = {"data": 8, "model": 1}
    vals = jnp.arange(8.0) + 0.25

    def cell(x):
        comm = SyncComm(sched, axis_map, sizes)
        comm.set_topology(Topology(pods=2), get_codec("identity"))
        out = comm("s", x), comm("m", x)
        comm.finalize()
        return out

    s, m = _hier_run(cell, 2, 4, vals)
    np.testing.assert_allclose(np.asarray(s).ravel(),
                               float(vals.sum()), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m).ravel(),
                               float(vals.mean()), rtol=1e-6)


def test_hierarchical_stateful_codec_threads_ef():
    """A stateful cross-pod codec consumes hier_ef_in and emits
    hier_ef_out; a missing residual is a loud KeyError."""
    sched = CommSchedule().psum("s", axis="data")
    axis_map = {"data": ("pod", "d"), "model": ()}
    sizes = {"data": 4, "model": 1}
    codec = get_codec("int8")
    assert codec.stateful
    assert hier_ef_names(sched, Topology(pods=2, codec="int8")) == ("s",)
    assert hier_ef_names(sched, Topology(pods=2)) == ()        # stateless
    assert hier_ef_names(sched, None) == ()

    def cell(x, ef):
        comm = SyncComm(sched, axis_map, sizes)
        comm.set_topology(Topology(pods=2, codec="int8"), codec,
                          ef={"s": ef})
        out = comm("s", x)
        comm.finalize()
        return out, comm.hier_ef_out["s"]

    vals = jnp.arange(4.0)
    out, ef_out = jax.vmap(jax.vmap(cell, axis_name="d"),
                           axis_name="pod")(
        vals.reshape(2, 2), jnp.zeros((2, 2)))
    assert jnp.isfinite(out).all() and ef_out.shape == (2, 2)

    def cell_no_ef(x):
        comm = SyncComm(sched, axis_map, sizes)
        comm.set_topology(Topology(pods=2, codec="int8"), codec)
        return comm("s", x)

    with pytest.raises(KeyError, match="error-feedback residual"):
        jax.vmap(jax.vmap(cell_no_ef, axis_name="d"),
                 axis_name="pod")(vals.reshape(2, 2))


def test_hierarchical_needs_two_level_axis_split():
    sched = CommSchedule().psum("s", axis="data")

    def cell(x):
        comm = SyncComm(sched, {"data": ("d",), "model": ()},
                        {"data": 2, "model": 1})
        comm.set_topology(Topology(pods=2), get_codec("identity"))
        return comm("s", x)

    with pytest.raises(ValueError, match="two-level axis split"):
        jax.vmap(cell, axis_name="d")(jnp.ones((2,)))


# ---------------------------------------------------------------------------
# grid executor: dim-specs drive replication/unreplication
# ---------------------------------------------------------------------------

def test_grid_program_specs_roundtrip():
    sched = CommSchedule().psum("col", axis="data").pmean("row", axis="model")

    def cell(comm, t, data, state):
        x_b, = data                      # (n_p, m_q) cell of the grid
        a_b, w_b = state
        a_new = a_b + comm("row", x_b.sum(axis=1))    # varies over data
        w_new = comm("col", x_b.sum(axis=0)) + w_b    # varies over model
        return a_new, w_new

    cellprog = CellProgram(sched, cell,
                           data_specs=((("data", "model"),)),
                           state_specs=((("data",), ("model",))))
    Pn, Qn, n_p, m_q = 3, 2, 4, 5
    x = jnp.arange(float(Pn * Qn * n_p * m_q)).reshape(Pn, Qn, n_p, m_q)
    step = grid_program(cellprog, Pn, Qn)
    a, w = step(1, (x,), (jnp.zeros((Pn, n_p)), jnp.zeros((Qn, m_q))))
    assert a.shape == (Pn, n_p) and w.shape == (Qn, m_q)
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(x.sum(axis=3).mean(axis=1)))
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(x.sum(axis=2).sum(axis=0)))


# ---------------------------------------------------------------------------
# solver-level knob validation (single device; no solve is run)
# ---------------------------------------------------------------------------

def test_solver_staleness_validation():
    from repro.core import get_solver
    cls = get_solver("d3ca")
    assert cls(engine="async", staleness=3).staleness == 3
    assert cls(engine="overlap", staleness=3).staleness == 3
    assert cls(engine="sync").engine == "shard_map"     # alias
    with pytest.raises(ValueError, match="must be >= 0"):
        cls(engine="async", staleness=-1)
    with pytest.raises(ValueError, match="needs engine='async'"):
        cls(engine="shard_map", staleness=2)
    with pytest.raises(ValueError, match="needs engine='async'"):
        cls(engine="simulated", staleness=1)


def test_solver_topology_validation():
    from repro.core import get_solver
    from repro.data import make_svm_data
    cls = get_solver("d3ca")
    s = cls(engine="overlap", staleness=2, topology="pods=2:int8")
    assert s.topology.pods == 2 and s.topology_spec == "pods=2:int8:ring"
    assert cls().topology is None and cls().topology_spec is None
    with pytest.raises(ValueError, match="spec"):
        cls(topology="2pods")
    # pod count must divide P at program-build time
    X, y = make_svm_data(24, 8, seed=0)
    bad = cls(engine="simulated", topology="pods=2")
    with pytest.raises(ValueError, match="divide"):
        bad.program("hinge", X, y, P=3, Q=1)
