"""Trainer fault-tolerance: NaN rollback, straggler detection, resume."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import Trainer, TrainerConfig


def quad_step_factory(poison_steps=(), slow_steps=(), delay=0.08):
    """Toy quadratic 'training': params -> params - 0.1*grad."""
    def step_fn(params, opt_state, batch):
        if int(batch["step"]) in slow_steps:
            time.sleep(delay)
        g = params["w"] - batch["target"]
        loss = jnp.sum(g * g)
        if int(batch["step"]) in poison_steps:
            loss = jnp.asarray(float("nan"))
        return ({"w": params["w"] - 0.1 * g}, opt_state,
                {"loss": loss})
    return step_fn


def make_batch(step):
    return {"step": step, "target": jnp.ones((4,))}


def test_loss_decreases_and_ckpt_resume(tmp_path):
    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                               async_ckpt=False),
                 quad_step_factory(), make_batch,
                 {"w": jnp.zeros((4,))}, {})
    hist = tr.run(20)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # fresh trainer resumes from the synced final checkpoint
    tr2 = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
                  quad_step_factory(), make_batch,
                  {"w": jnp.zeros((4,))}, {})
    assert tr2.restore() == 20
    np.testing.assert_allclose(np.asarray(tr2.params["w"]),
                               np.asarray(tr.params["w"]))


def test_nan_rollback_and_skip(tmp_path):
    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                               async_ckpt=False),
                 quad_step_factory(poison_steps={7}), make_batch,
                 {"w": jnp.zeros((4,))}, {})
    hist = tr.run(15)
    steps_seen = [h["step"] for h in hist]
    assert 7 not in steps_seen          # poisoned batch skipped
    assert tr.step == 15
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_nan_storm_aborts(tmp_path):
    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                               async_ckpt=False, max_rollbacks=2),
                 quad_step_factory(poison_steps=set(range(3, 30))),
                 make_batch, {"w": jnp.zeros((4,))}, {})
    import pytest
    with pytest.raises(RuntimeError, match="rollbacks"):
        tr.run(20)


def test_straggler_detection(tmp_path):
    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                               async_ckpt=False, straggler_factor=3.0),
                 quad_step_factory(slow_steps={10}, delay=0.15), make_batch,
                 {"w": jnp.zeros((4,))}, {})
    tr.run(15)
    assert 10 in tr.stragglers
