"""Telemetry subsystem tests: tracer, metrics registry, instrumentation.

Unit tests (fake clock, no jax compute) run in the default tier-1
split; the solve-under-telemetry integration tests are marked ``obs``
and get their own CI matrix leg.
"""
import json

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, NullTracer, Registry, Tracer, as_tracer,
                       percentiles)
from repro.obs.metrics import DEFAULT_PERCENTILES
from repro.obs.serve import RequestMetrics
from repro.obs.trace import _NULL_SPAN


class FakeClock:
    """Deterministic clock: every call advances one second."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------- tracer ----

def test_span_nesting_and_ordering_with_fake_clock():
    tr = Tracer(clock=FakeClock())          # epoch = 1
    with tr.span("outer", which="o"):       # t0 = 2
        with tr.span("inner"):              # t0 = 3
            pass                            # t1 = 4
    # outer closes at t1 = 5

    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    inner, outer = tr.events
    assert inner == {"name": "inner", "ts": 2.0, "dur": 1.0, "depth": 1,
                     "tid": inner["tid"]}
    assert outer["ts"] == 1.0 and outer["dur"] == 3.0 and outer["depth"] == 0
    assert outer["args"] == {"which": "o"}
    # the child interval nests inside the parent interval
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_record_and_instant_and_queries():
    tr = Tracer(clock=FakeClock())          # epoch = 1
    tr.record("comm/dalpha", t0=10.0, dur=0.5, iter=3)
    tr.record("comm/dalpha", t0=10.5, dur=0.25)
    tr.instant("marker", reason="x")        # clock -> 2

    assert tr.total("comm/dalpha") == pytest.approx(0.75)
    assert len(tr.spans("comm/dalpha")) == 2
    assert tr.spans("comm/dalpha")[0]["ts"] == 9.0   # t0 - epoch
    inst = [e for e in tr.events if e["dur"] is None]
    assert len(inst) == 1 and inst[0]["name"] == "marker"
    assert inst[0]["args"] == {"reason": "x"}


def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = Tracer(clock=FakeClock())
    with tr.span("solve", solver="d3ca"):
        with tr.span("step"):
            pass
    tr.instant("finish")

    path = tmp_path / "trace.json"
    tr.write_chrome_trace(str(path))
    payload = json.loads(path.read_text())

    assert set(payload) == {"traceEvents", "displayTimeUnit"}
    evs = payload["traceEvents"]
    assert len(evs) == 3
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(complete) == 2 and len(instants) == 1
    for e in complete:
        # microsecond complete events with the required keys
        assert {"name", "cat", "pid", "tid", "ts", "dur", "ph"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] > 0
    assert instants[0]["s"] == "t" and "dur" not in instants[0]
    solve = next(e for e in complete if e["name"] == "solve")
    assert solve["args"] == {"solver": "d3ca"}
    # seconds -> microseconds
    assert solve["dur"] == pytest.approx(tr.spans("solve")[0]["dur"] * 1e6)


def test_jsonl_export_roundtrip(tmp_path):
    tr = Tracer(clock=FakeClock())
    with tr.span("a"):
        pass
    tr.instant("b")
    path = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines == tr.events


def test_disabled_tracer_fast_path():
    # every disabled span() call hands back the ONE shared no-op object:
    # no per-span allocation, no event growth
    for tr in (NULL_TRACER, Tracer(enabled=False), NullTracer()):
        s1 = tr.span("a")
        s2 = tr.span("b", x=1)
        assert s1 is _NULL_SPAN and s2 is _NULL_SPAN
        with tr.span("c"):
            tr.record("d", 0.0, 1.0)
            tr.instant("e")
        assert tr.events == []
        assert not tr.enabled


def test_as_tracer_normalization():
    assert as_tracer(None) is NULL_TRACER
    tr = Tracer()
    assert as_tracer(tr) is tr


def test_tracer_is_thread_safe():
    import threading

    tr = Tracer()
    barrier = threading.Barrier(4)   # all threads alive at once, so their
                                     # idents are guaranteed distinct

    def work():
        barrier.wait()
        for i in range(50):
            with tr.span("w", i=i):
                pass

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events) == 200
    assert len({e["tid"] for e in tr.events}) == 4
    # per-thread stacks: every span closed at depth 0
    assert all(e["depth"] == 0 for e in tr.events)


# -------------------------------------------------------------- registry ----

def test_percentiles_default_set_includes_p90():
    assert 90 in DEFAULT_PERCENTILES
    assert percentiles([]) == {"p50": 0.0, "p90": 0.0, "p99": 0.0}
    p = percentiles([1.0, 2.0, 3.0])
    assert p["p50"] == 2.0 and p["p90"] == pytest.approx(2.8)


def test_registry_get_or_create_and_snapshot():
    reg = Registry()
    c = reg.counter("serve/prefills")
    c.inc()
    c.inc(2.0)
    assert reg.counter("serve/prefills") is c        # same triple, same obj
    reg.gauge("solver/objective", solver="d3ca", engine="simulated").set(0.5)
    h = reg.histogram("solver/step_s", solver="d3ca")
    h.observe(1.0)
    h.observe(3.0)

    snap = reg.snapshot()
    assert snap["counters"] == {"serve/prefills": 3.0}
    # labels render sorted into the key
    assert snap["gauges"] == {
        "solver/objective{engine=simulated,solver=d3ca}": 0.5}
    hs = snap["histograms"]["solver/step_s{solver=d3ca}"]
    assert hs["count"] == 2 and hs["sum"] == 4.0 and hs["mean"] == 2.0
    assert hs["min"] == 1.0 and hs["max"] == 3.0
    assert {"p50", "p90", "p99"} <= set(hs)
    json.dumps(snap)                                 # plain JSON-able


def test_gauge_and_histogram_dont_collide():
    reg = Registry()
    reg.gauge("x").set(1.0)
    reg.histogram("x").observe(2.0)
    snap = reg.snapshot()
    assert snap["gauges"]["x"] == 1.0
    assert snap["histograms"]["x"]["count"] == 1


def test_registry_snapshot_matches_request_metrics_summary():
    """The serving summary and the registry snapshot are the same numbers
    bit for bit -- the legacy ServeMetrics.summary() contract, now fed
    through the registry."""
    clock = FakeClock()
    reg = Registry()
    m = RequestMetrics(clock=clock, registry=reg)
    m.prefills += 2
    m.decode_steps += 5
    m.start_request("a", n_prompt=4)     # arrival 1
    m.start_request("b", n_prompt=4)     # arrival 2
    m.start_request("c", n_prompt=4)     # arrival 3: never finishes
    m.first_token("a")                   # 4
    m.first_token("b")                   # 5
    m.finish("a", n_generated=8)         # 6
    m.finish("b", n_generated=4)         # 7

    s = m.summary()
    snap = reg.snapshot()
    assert s["requests_finished"] == 2
    assert s["requests_unfinished"] == 1     # skipped, not raised on
    assert snap["counters"]["serve/requests_finished"] == 2.0
    assert snap["counters"]["serve/generated_tokens"] == 12.0
    assert snap["counters"]["serve/prefills"] == s["prefills"] == 2
    assert snap["counters"]["serve/decode_steps"] == s["decode_steps"] == 5
    for q in ("p50", "p90", "p99"):
        assert snap["histograms"]["serve/ttft_s"][q] == s["ttft_s"][q]
        assert snap["histograms"]["serve/latency_s"][q] == s["latency_s"][q]
    assert snap["gauges"]["serve/tokens_per_sec"] == s["tokens_per_sec"]
    assert snap["gauges"]["serve/elapsed_s"] == s["elapsed_s"]


# ----------------------------------------------- solve-level integration ----

def _small_problem():
    from repro.core import D3CAConfig, get_solver
    from repro.data import make_svm_data

    X, y = make_svm_data(120, 40, seed=0)
    cfg = D3CAConfig(lam=1e-1, outer_iters=3, local_steps=8)
    return get_solver("d3ca")(engine="simulated"), X, y, cfg


@pytest.mark.obs
def test_traced_solve_bit_identical_to_untraced():
    solver, X, y, cfg = _small_problem()
    plain = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg)
    traced = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg,
                          tracer=Tracer(), registry=Registry())
    assert np.array_equal(np.asarray(plain.w), np.asarray(traced.w))
    assert plain.history[-1]["objective"] == traced.history[-1]["objective"]


@pytest.mark.obs
def test_registry_snapshot_matches_solver_history():
    solver, X, y, cfg = _small_problem()
    reg = Registry()
    res = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg, registry=reg)
    snap = reg.snapshot()
    labels = "{engine=simulated,solver=d3ca}"

    # history gained the per-phase fields
    for h in res.history:
        assert {"step_s", "local_s", "comm_s", "host_s"} <= set(h)
        assert h["local_s"] + h["comm_s"] <= h["step_s"] + 1e-12

    # and the registry carries the same series bit for bit
    assert snap["counters"][f"solver/iters{labels}"] == len(res.history)
    assert (snap["gauges"][f"solver/objective{labels}"]
            == res.history[-1]["objective"])
    assert (snap["gauges"][f"solver/duality_gap{labels}"]
            == res.history[-1]["duality_gap"])
    step_h = snap["histograms"][f"solver/step_s{labels}"]
    assert step_h["count"] == len(res.history)
    assert step_h["sum"] == sum(h["step_s"] for h in res.history)
    host_h = snap["histograms"][f"solver/host_s{labels}"]
    assert host_h["sum"] == sum(h["host_s"] for h in res.history)
    local_h = snap["histograms"][f"solver/local_s{labels}"]
    assert local_h["sum"] == sum(h["local_s"] for h in res.history)
    assert (snap["counters"][f"solver/comm_bytes{labels}"]
            == res.comm_bytes["bytes_per_step"] * len(res.history))


@pytest.mark.obs
def test_trace_spans_cover_solve_wall_clock():
    """Acceptance: the emitted spans cover >= 95% of measured wall-clock
    and the per-collective spans carry the CommSchedule names."""
    solver, X, y, cfg = _small_problem()
    tr = Tracer()
    solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg, tracer=tr)

    solve_s = tr.total("solve")
    covered = (tr.total("data_prep") + tr.total("calibrate")
               + tr.total("outer_iter"))
    assert covered >= 0.95 * solve_s

    # d3ca declares dalpha (pmean@model) and w_contrib (psum@data):
    # both appear as synthesized comm spans, nested inside each step
    for name in ("comm/dalpha", "comm/w_contrib"):
        spans = tr.spans(name)
        assert len(spans) == cfg.outer_iters
    for it in range(1, cfg.outer_iters + 1):
        step = next(s for s in tr.spans("step")
                    if s.get("args", {}).get("iter") == it)
        local = next(s for s in tr.spans("local_solve")
                     if s.get("args", {}).get("iter") == it)
        assert local["ts"] >= step["ts"] - 1e-9
        assert (local["ts"] + local["dur"]
                <= step["ts"] + step["dur"] + 1e-9)


@pytest.mark.obs
def test_untimed_solve_history_has_no_phase_fields():
    """Tracing off (the default) leaves history entries exactly as the
    legacy schema: no step_s / local_s / comm_s / host_s keys."""
    solver, X, y, cfg = _small_problem()
    res = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg)
    for h in res.history:
        assert not {"step_s", "local_s", "comm_s", "host_s"} & set(h)
