"""repro.serve: paged-cache invariants, sampling, scheduler equivalence.

The headline test: continuous batching under greedy decoding is
token-for-token identical to the seed-era static-batch loop
(``repro.launch.serve.static_batch_generate``), including when the pool
is small enough to force preemption and replay.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import static_batch_generate
from repro.models import Transformer, reduced
from repro.serve import (EngineConfig, InferenceEngine, LinearScorer,
                         PagePool, PagedCacheConfig, Request, RequestMetrics,
                         SamplingParams)
from repro.serve.sampling import params_arrays, sample_tokens


# ---------------------------------------------------------------------------
# PagePool / block-table invariants
# ---------------------------------------------------------------------------

def test_pages_for():
    pc = PagedCacheConfig(page_size=16, num_pages=8)
    assert pc.pages_for(1) == 1
    assert pc.pages_for(16) == 1
    assert pc.pages_for(17) == 2
    assert pc.pages_for(0) == 1          # every sequence holds >= 1 page
    assert pc.trash_page == 8


def test_pool_alloc_free_roundtrip():
    pool = PagePool(PagedCacheConfig(page_size=4, num_pages=6))
    a = pool.alloc("a", 2)
    b = pool.alloc("b", 3)
    assert len(a) == 2 and len(b) == 3 and pool.n_free == 1
    assert not set(a) & set(b)
    pool.check()
    assert pool.free("a") == 2
    assert pool.n_free == 3
    pool.check()
    assert pool.free("b") == 3
    assert pool.n_free == 6
    pool.check()


def test_pool_double_free_raises():
    pool = PagePool(PagedCacheConfig(page_size=4, num_pages=4))
    pool.alloc("a", 1)
    pool.free("a")
    with pytest.raises(KeyError):
        pool.free("a")
    with pytest.raises(KeyError):
        pool.free("never-allocated")


def test_pool_alloc_is_atomic():
    pool = PagePool(PagedCacheConfig(page_size=4, num_pages=4))
    assert pool.alloc("a", 3) is not None
    # all-or-nothing: a 2-page ask against 1 free page changes NOTHING
    assert pool.alloc("b", 2) is None
    assert pool.n_free == 1
    assert pool.pages("b") == []
    pool.check()
    assert pool.alloc("b", 1) is not None
    assert pool.n_free == 0
    pool.check()


def test_pool_eviction_releases_every_page():
    pool = PagePool(PagedCacheConfig(page_size=4, num_pages=8))
    for owner, n in [("a", 3), ("b", 2), ("c", 3)]:
        pool.alloc(owner, n)
    assert pool.n_free == 0
    assert pool.free("b") == 2           # evict b: its pages come back whole
    assert pool.n_free == 2
    assert sorted(pool.owners()) == ["a", "c"]
    pool.check()


def test_pool_check_catches_corruption():
    pool = PagePool(PagedCacheConfig(page_size=4, num_pages=4))
    pool.alloc("a", 2)
    pool._free.append(pool.pages("a")[0])    # simulate a double-book
    with pytest.raises(AssertionError):
        pool.check()


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _rows(n, v, seed=0):
    return np.random.default_rng(seed).normal(size=(n, v)).astype(np.float32)


def test_sampling_greedy_is_argmax():
    logits = _rows(5, 32)
    sp = params_arrays([SamplingParams()] * 5, [0] * 5)
    out = np.asarray(sample_tokens(logits, *sp))
    np.testing.assert_array_equal(out, logits.argmax(-1))


def test_sampling_top_k1_is_argmax():
    logits = _rows(4, 32, seed=1)
    sp = params_arrays(
        [SamplingParams(temperature=1.0, top_k=1, seed=i) for i in range(4)],
        [3] * 4)
    out = np.asarray(sample_tokens(logits, *sp))
    np.testing.assert_array_equal(out, logits.argmax(-1))


def test_sampling_stream_is_slot_independent():
    """A request's draw depends on (seed, step), not its batch position."""
    logits = _rows(1, 64, seed=2)
    p = SamplingParams(temperature=0.9, top_k=8, top_p=0.95, seed=123)
    alone = np.asarray(sample_tokens(
        logits, *params_arrays([p], [7])))[0]
    batched = np.asarray(sample_tokens(
        np.repeat(logits, 3, axis=0),
        *params_arrays([SamplingParams(temperature=1.3, seed=5), p,
                        SamplingParams(seed=9)], [0, 7, 2])))[1]
    assert alone == batched


def test_sampling_top_p_keeps_argmax():
    logits = _rows(6, 32, seed=3)
    sp = params_arrays(
        [SamplingParams(temperature=1.0, top_p=1e-6, seed=i)
         for i in range(6)], [0] * 6)
    out = np.asarray(sample_tokens(logits, *sp))
    np.testing.assert_array_equal(out, logits.argmax(-1))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_with_fake_clock():
    t = [0.0]
    m = RequestMetrics(clock=lambda: t[0])
    m.start_request("a", 8)
    t[0] = 0.5
    m.first_token("a")
    t[0] = 2.0
    m.finish("a", 10)
    s = m.summary()
    assert s["requests_finished"] == 1
    assert s["generated_tokens"] == 10
    assert s["tokens_per_sec"] == pytest.approx(10 / 2.0)
    assert s["ttft_s"]["p50"] == pytest.approx(0.5)
    assert s["ttft_s"]["p90"] == pytest.approx(0.5)   # p90 joined the set
    assert s["latency_s"]["p99"] == pytest.approx(2.0)


def test_legacy_servemetrics_shim_warns():
    import importlib
    import sys
    sys.modules.pop("repro.serve.metrics", None)
    with pytest.warns(DeprecationWarning, match="repro.obs.serve"):
        mod = importlib.import_module("repro.serve.metrics")
    m = mod.ServeMetrics(clock=lambda: 0.0)
    assert isinstance(m, RequestMetrics)
    assert m.summary()["requests_finished"] == 0


# ---------------------------------------------------------------------------
# doubly-distributed scoring
# ---------------------------------------------------------------------------

def test_linear_scorer_matches_dense():
    rng = np.random.default_rng(0)
    w = rng.normal(size=37).astype(np.float32)
    X = rng.normal(size=(23, 37)).astype(np.float32)
    sc = LinearScorer(w, loss="hinge", bucket=8)
    np.testing.assert_allclose(sc.score(X), X @ w, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(sc.predict(X),
                                  np.where(X @ w >= 0, 1.0, -1.0))
    assert sc.rows_scored == 2 * 23


def test_linear_scorer_on_grid_mesh():
    from repro.launch.mesh import make_grid_mesh
    rng = np.random.default_rng(1)
    w = rng.normal(size=10).astype(np.float32)
    X = rng.normal(size=(5, 10)).astype(np.float32)
    sc = LinearScorer(w, mesh=make_grid_mesh(1, 1), loss="logistic")
    np.testing.assert_allclose(sc.score(X), X @ w, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sc.predict(X), 1 / (1 + np.exp(-(X @ w))),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# continuous batching == static batching (greedy)
# ---------------------------------------------------------------------------

_MODEL_CACHE = {}


def _tiny_model():
    if "m" not in _MODEL_CACHE:
        import jax
        cfg = reduced(get_config("qwen3-1.7b"))
        model = Transformer(cfg)
        params = jax.jit(lambda k: model.init(k)[0])(jax.random.PRNGKey(0))
        _MODEL_CACHE["m"] = (cfg, model, params)
    return _MODEL_CACHE["m"]


def _trace(cfg, plens, gens):
    rng = np.random.default_rng(7)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=p),
                    max_new_tokens=g)
            for i, (p, g) in enumerate(zip(plens, gens))]


def test_continuous_equals_static_greedy():
    cfg, model, params = _tiny_model()
    # uniform prompt length per static chunk of 2 (the static loop's
    # right-padding is only exact for equal-length prompts); the engine
    # sees the requests as one mixed stream across 2 slots
    plens = [6, 6, 11, 11, 3, 3]
    gens = [5, 8, 4, 7, 6, 3]
    reqs = _trace(cfg, plens, gens)
    ref = static_batch_generate(model, params, reqs, batch_size=2)

    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=32, max_seq_len=32))
    out = engine.run(reqs)

    assert sorted(out) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid],
                                      err_msg=f"request {rid}")
    s = engine.metrics.summary()
    assert s["requests_finished"] == len(reqs)
    assert s["generated_tokens"] == sum(gens)
    engine.pool.check()
    assert engine.pool.n_free == engine.pc.num_pages   # all pages returned


def test_engine_preemption_is_transparent():
    """A pool too small for all slots forces eviction + replay; greedy
    outputs still match the static reference token-for-token."""
    cfg, model, params = _tiny_model()
    plens = [9, 9, 9, 9]
    gens = [10, 10, 10, 10]
    reqs = _trace(cfg, plens, gens)
    ref = static_batch_generate(model, params, reqs, batch_size=4)

    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=4, page_size=4, num_pages=13, max_seq_len=20,
        reserve_pages=False))
    out = engine.run(reqs)
    assert engine.metrics.preemptions > 0
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid],
                                      err_msg=f"request {rid}")
    engine.pool.check()


def test_engine_admission_control():
    cfg, model, params = _tiny_model()
    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=16, max_seq_len=24,
        max_queue=2))
    too_long = Request(rid="x", prompt=np.zeros(20, np.int32),
                      max_new_tokens=8)
    assert not engine.submit(too_long)
    assert engine.submit(Request(rid=0, prompt=np.zeros(4, np.int32),
                                 max_new_tokens=2))
    assert engine.submit(Request(rid=1, prompt=np.zeros(4, np.int32),
                                 max_new_tokens=2))
    assert not engine.submit(Request(rid=2, prompt=np.zeros(4, np.int32),
                                     max_new_tokens=2))   # queue full
    assert engine.metrics.rejections == 2
    out = engine.run([])
    assert sorted(out) == [0, 1]


def test_engine_rejects_duplicate_rid():
    """A rid keys the page pool and the output dict: duplicates would
    merge two requests' pages under one owner."""
    cfg, model, params = _tiny_model()
    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=2, page_size=8, num_pages=16, max_seq_len=32))
    r = _trace(cfg, [4, 4], [3, 3])
    dup = Request(rid=r[0].rid, prompt=r[1].prompt, max_new_tokens=3)
    assert engine.submit(r[0])
    assert not engine.submit(dup)           # duplicate of a queued rid
    out = engine.run([])
    assert sorted(out) == [0]
    assert not engine.submit(dup)           # duplicate of a finished rid
    assert engine.metrics.rejections == 2
    engine.pool.check()


def test_engine_stop_token():
    cfg, model, params = _tiny_model()
    reqs = _trace(cfg, [5], [12])
    ref = InferenceEngine(model, params, EngineConfig(
        max_slots=1, page_size=8, num_pages=16, max_seq_len=32)).run(reqs)
    # stop at the first token value that hasn't occurred before it
    k = next(i for i in range(1, len(ref[0]))
             if ref[0][i] not in ref[0][:i])
    req = Request(rid=0, prompt=reqs[0].prompt, max_new_tokens=12,
                  stop_token=int(ref[0][k]))
    out = InferenceEngine(model, params, EngineConfig(
        max_slots=1, page_size=8, num_pages=16, max_seq_len=32)).run([req])
    np.testing.assert_array_equal(out[0], ref[0][: k + 1])
