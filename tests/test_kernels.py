"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_attention
from repro.kernels.linattn import rwkv_linattn_pallas, rwkv_linattn_ref
from repro.kernels.sdca import sdca_epoch_pallas, sdca_epoch_ref
from repro.kernels.svrg import svrg_inner_pallas, svrg_inner_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("n_p,m_q,steps", [(8, 8, 8), (24, 16, 50),
                                           (64, 128, 64), (17, 9, 33)])
@pytest.mark.parametrize("loss", ["hinge", "squared"])
@pytest.mark.parametrize("beta", [None, "m_q"])
def test_sdca_kernel(n_p, m_q, steps, loss, beta):
    x = jnp.asarray(RNG.normal(size=(n_p, m_q)), jnp.float32)
    y = jnp.asarray(np.sign(RNG.normal(size=n_p)) + 0.0, jnp.float32)
    y = jnp.where(y == 0, 1.0, y)
    mask = jnp.ones((n_p,)).at[-2:].set(0.0)
    a0 = jnp.asarray(RNG.uniform(0, 0.5, n_p), jnp.float32) * (y > 0)
    w0 = jnp.asarray(RNG.normal(size=m_q) * 0.1, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, n_p, steps), jnp.int32)
    # beta ~ ||x_i||^2 keeps the step-size-variant recursion contractive
    kw = dict(lam=0.2, n=200, Q=3, loss=loss,
              beta=float(m_q) if beta else None)
    da_r, w_r = sdca_epoch_ref(x, y, mask, a0, w0, idx, **kw)
    da_p, w_p = sdca_epoch_pallas(x, y, mask, a0, w0, idx, **kw)
    np.testing.assert_allclose(np.asarray(da_p), np.asarray(da_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_p,m_sub,L", [(16, 8, 20), (40, 32, 64),
                                         (13, 5, 11)])
@pytest.mark.parametrize("loss", ["hinge", "squared"])
def test_svrg_kernel(n_p, m_sub, L, loss):
    x = jnp.asarray(RNG.normal(size=(n_p, m_sub)), jnp.float32)
    y = jnp.asarray(np.sign(RNG.normal(size=n_p)), jnp.float32)
    y = jnp.where(y == 0, 1.0, y)
    mask = jnp.ones((n_p,))
    wa = jnp.asarray(RNG.normal(size=m_sub) * 0.2, jnp.float32)
    za = x @ wa + jnp.asarray(RNG.normal(size=n_p) * 0.1, jnp.float32)
    mu = jnp.asarray(RNG.normal(size=m_sub) * 0.05, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, n_p, L), jnp.int32)
    kw = dict(lam=0.1, eta=0.03, loss=loss)
    w_r = svrg_inner_ref(x, y, mask, za, wa, mu, idx, **kw)
    w_p = svrg_inner_pallas(x, y, mask, za, wa, mu, idx, **kw)
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,H,KV,D", [(2, 128, 4, 2, 32), (1, 256, 2, 2, 64),
                                        (2, 64, 8, 1, 16)])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel(B, S, H, KV, D, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, D)), dtype)
    o_ref = flash_attention(q, k, v, causal=True, window=window,
                            backend="ref")
    o_pal = flash_attention(q, k, v, causal=True, window=window,
                            backend="pallas", block_q=64, block_k=64)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("BH,S,D,chunk", [(2, 64, 16, 16), (3, 128, 32, 32),
                                          (1, 256, 64, 64), (2, 96, 16, 32)])
def test_linattn_kernel(BH, S, D, chunk):
    r = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32))
    u = jnp.asarray(RNG.normal(size=(D,)), jnp.float32)
    o_r, s_r = rwkv_linattn_ref(r, k, v, logw, u)
    o_p, s_p = rwkv_linattn_pallas(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


def test_linattn_extreme_decay_no_overflow():
    """All-negative exponent formulation: no NaN/Inf even at w -> 0."""
    BH, S, D = 1, 64, 16
    r = jnp.ones((BH, S, D)) * 0.5
    k = jnp.ones((BH, S, D)) * 0.5
    v = jnp.ones((BH, S, D))
    logw = jnp.full((BH, S, D), -50.0)   # decay ~ e^-50 per step
    u = jnp.ones((D,))
    o_p, s_p = rwkv_linattn_pallas(r, k, v, logw, u, chunk=16)
    assert bool(jnp.all(jnp.isfinite(o_p))) and bool(
        jnp.all(jnp.isfinite(s_p)))
