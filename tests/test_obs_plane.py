"""The live observability plane: flight recorder, health rules/monitor,
Prometheus exposition, the HTTP endpoint, and their threading through
the real services.

Unit tests (recorder ring, bundle round-trip, rule verdicts, text
format, endpoint handlers) run in the simulated leg; the tests that
drive real solves / the real ``OnlineSolverService`` carry the ``obs``
marker (the telemetry CI leg).
"""
from __future__ import annotations

import json
import math
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import (CRIT, OK, WARN, FlightRecorder, HealthMonitor,
                       HealthRule, ObsServer, Registry, as_tracer,
                       load_bundle, online_rules, parse_prometheus_text,
                       render_prometheus, rule_comm_exposed,
                       rule_divergence, rule_fleet_starvation,
                       rule_gap_stall, rule_queue_shed, rule_staleness,
                       rule_version_lag)
from repro.obs.recorder import BUNDLE_SCHEMA


class FakeClock:
    """Deterministic clock: every call advances one second."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_recorder_is_bounded_under_heavy_span_load():
    rec = FlightRecorder(capacity=64, clock=FakeClock())
    for i in range(10_000):
        with rec.span("work", i=i):
            pass
    assert len(rec.events) == 64            # ring never exceeds capacity
    assert rec.dropped == 10_000 - 64
    # drop-oldest: the tail holds the *last* spans
    kept = [e["args"]["i"] for e in rec.events]
    assert kept == list(range(10_000 - 64, 10_000))


def test_recorder_speaks_the_tracer_api():
    rec = FlightRecorder(capacity=16, clock=FakeClock())
    assert as_tracer(rec) is rec            # drop-in wherever tracer= goes
    assert rec.enabled
    with rec.span("outer", k=1):
        with rec.span("inner"):
            pass
    rec.instant("marker")
    names = [e["name"] for e in rec.events]
    assert names == ["inner", "outer", "marker"]
    assert rec.spans("outer")[0]["depth"] == 0
    assert rec.spans("inner")[0]["depth"] == 1
    # chrome-trace export works off the ring like the base class
    evs = rec.to_chrome_trace()["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i"}


def test_recorder_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_bundle_dump_roundtrips_through_loader(tmp_path):
    reg = Registry()
    reg.counter("x").inc(3)
    reg.histogram("h").observe(1.0)
    rec = FlightRecorder(capacity=8, clock=FakeClock(), registry=reg,
                         meta={"svc": "test"})
    for i in range(20):
        with rec.span("step", i=i):
            pass
    path = str(tmp_path / "bundle.json")
    rec.dump(path, reason="trigger")
    assert rec.dumps == [path]

    b = load_bundle(path)                   # validates schema + trace
    assert b["schema"] == BUNDLE_SCHEMA
    assert b["reason"] == "trigger"
    assert b["meta"]["svc"] == "test"
    assert b["capacity"] == 8
    assert b["retained_events"] == 8
    assert b["dropped_events"] == 12
    assert len(b["trace"]["traceEvents"]) == 8
    assert b["metrics"]["counters"]["x"] == 3
    assert b["metrics"]["histograms"]["h"]["count"] == 1


def test_load_bundle_rejects_foreign_and_malformed(tmp_path):
    p = tmp_path / "notabundle.json"
    p.write_text(json.dumps({"schema": "something/else"}))
    with pytest.raises(ValueError, match="schema"):
        load_bundle(str(p))
    p.write_text(json.dumps({"schema": BUNDLE_SCHEMA, "trace": {}}))
    with pytest.raises(ValueError, match="traceEvents"):
        load_bundle(str(p))
    p.write_text(json.dumps({
        "schema": BUNDLE_SCHEMA,
        "trace": {"traceEvents": [{"ph": "B", "name": "x"}]}}))
    with pytest.raises(ValueError, match="phase"):
        load_bundle(str(p))


def test_crash_guard_dumps_and_reraises(tmp_path):
    rec = FlightRecorder(capacity=8, clock=FakeClock())
    path = str(tmp_path / "crash.json")
    with pytest.raises(RuntimeError, match="boom"):
        with rec.crash_guard(path):
            with rec.span("doomed"):
                pass
            raise RuntimeError("boom")
    b = load_bundle(path)
    assert b["reason"] == "crash:RuntimeError"
    assert [e["name"] for e in b["trace"]["traceEvents"]] == ["doomed"]


# ---------------------------------------------------------------------------
# histogram reservoir (bounded memory)
# ---------------------------------------------------------------------------

def test_histogram_reservoir_exact_below_cap():
    from repro.obs.metrics import Histogram, percentiles
    h = Histogram(cap=100)
    xs = [float(i) for i in range(100)]
    for v in xs:
        h.observe(v)
    s = h.summary()
    # below the cap the reservoir IS the series: summaries bit-identical
    assert s["count"] == 100 and s["sum"] == sum(xs)
    assert s["min"] == 0.0 and s["max"] == 99.0
    assert {k: v for k, v in s.items() if k.startswith("p")} \
        == percentiles(xs)
    assert h.observations == xs             # arrival order preserved


def test_histogram_reservoir_bounded_above_cap():
    from repro.obs.metrics import Histogram
    h = Histogram(cap=64)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert len(h.observations) == 64        # memory capped
    assert h.count == n                     # aggregates stay exact
    assert h.sum == sum(range(n))
    s = h.summary()
    assert s["min"] == 0.0 and s["max"] == float(n - 1)
    assert s["mean"] == sum(range(n)) / n
    # the reservoir is a uniform sample: p50 lands near the true median
    assert abs(s["p50"] - (n - 1) / 2) < 0.25 * n
    assert all(0.0 <= v <= n - 1 for v in h.observations)


def test_histogram_reservoir_deterministic():
    from repro.obs.metrics import Histogram
    a, b = Histogram(cap=32), Histogram(cap=32)
    for i in range(1000):
        a.observe(float(i))
        b.observe(float(i))
    assert a.observations == b.observations   # seeded PRNG, no flake


def test_registry_histogram_cap_kwarg():
    reg = Registry()
    h = reg.histogram("svc/lat_s", cap=16)
    for i in range(100):
        h.observe(float(i))
    assert len(h.observations) == 16
    assert reg.snapshot()["histograms"]["svc/lat_s"]["count"] == 100


def test_histogram_cap_validation():
    from repro.obs.metrics import Histogram
    with pytest.raises(ValueError, match="cap"):
        Histogram(cap=0)


# ---------------------------------------------------------------------------
# registry under concurrency
# ---------------------------------------------------------------------------

def test_registry_concurrent_writers_lose_no_updates():
    """The online service scores and publishes from different threads
    while the endpoint snapshots from a third: counters must not lose
    increments, histogram count/sum must stay exact, and every snapshot
    taken mid-flight must be self-consistent."""
    reg = Registry()
    n_threads, n_ops = 8, 2_000
    snap_errors = []
    stop = threading.Event()

    def writer(tid):
        c = reg.counter("c")                # all threads share one counter
        g = reg.gauge("g", t=str(tid))
        h = reg.histogram("h")
        for i in range(n_ops):
            c.inc()
            g.set(float(i))
            h.observe(1.0)

    def snapshotter():
        while not stop.is_set():
            s = reg.snapshot()
            h = s["histograms"].get("h")
            if h is None:
                continue
            # self-consistency: aggregates move together under the
            # histogram lock -- sum must equal count for unit observes
            if h["sum"] != float(h["count"]):
                snap_errors.append((h["count"], h["sum"]))

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    snapper = threading.Thread(target=snapshotter)
    snapper.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    snapper.join()

    total = n_threads * n_ops
    assert reg.counter("c").value == total  # no lost increments
    h = reg.histogram("h")
    assert h.count == total and h.sum == float(total)
    assert snap_errors == []                # every snapshot consistent


# ---------------------------------------------------------------------------
# health rules
# ---------------------------------------------------------------------------

def _reg_with(gauges=(), counters=(), hists=()):
    reg = Registry()
    for name, labels, v in gauges:
        reg.gauge(name, **labels).set(v)
    for name, labels, v in counters:
        reg.counter(name, **labels).inc(v)
    for name, labels, vs in hists:
        h = reg.histogram(name, **labels)
        for v in vs:
            h.observe(v)
    return reg


def test_rule_divergence_nan_is_crit():
    rule = rule_divergence()
    reg = _reg_with(gauges=[("solver/objective", {"solver": "d3ca"},
                             float("nan"))])
    status, msg, _ = rule.check(reg.snapshot())
    assert status == CRIT and "non-finite" in msg


def test_rule_divergence_stall_is_warn():
    rule = rule_divergence(window=3)
    reg = Registry()
    g = reg.gauge("solver/rel_opt")
    # improving: stays OK
    for v in (1.0, 0.5, 0.25, 0.12, 0.06):
        g.set(v)
        status, _, _ = rule.check(reg.snapshot())
        assert status == OK
    # frozen: WARN once the window fills with non-improvement
    statuses = []
    for _ in range(4):
        statuses.append(rule.check(reg.snapshot())[0])
    assert statuses[-1] == WARN


def test_rule_gap_stall_and_growth():
    rule = rule_gap_stall(window=3)
    reg = Registry()
    g = reg.gauge("solver/duality_gap")
    for v in (1.0, 0.5, 0.2, 0.1):          # shrinking: OK
        g.set(v)
        assert rule.check(reg.snapshot())[0] == OK
    for v in (0.1, 0.1, 0.1):               # stalled: WARN
        g.set(v)
        last = rule.check(reg.snapshot())[0]
    assert last == WARN
    for v in (0.2, 0.5, 1.0, 2.0):          # growing: CRIT
        g.set(v)
        last = rule.check(reg.snapshot())[0]
    assert last == CRIT


def test_rule_staleness_thresholds():
    rule = rule_staleness(10.0)
    snap = lambda v: _reg_with(                      # noqa: E731
        gauges=[("online/staleness_s", {}, v)]).snapshot()
    assert rule.check(snap(1.0))[0] == OK
    assert rule.check(snap(6.0))[0] == WARN
    assert rule.check(snap(11.0))[0] == CRIT
    assert rule.check(Registry().snapshot())[0] == OK   # no series yet


def test_rule_version_lag_thresholds():
    rule = rule_version_lag(100)
    snap = lambda v: _reg_with(                      # noqa: E731
        gauges=[("online/version_lag", {}, v)]).snapshot()
    assert rule.check(snap(10))[0] == OK
    assert rule.check(snap(60))[0] == WARN
    assert rule.check(snap(101))[0] == CRIT


def test_rule_queue_shed_uses_deltas_between_evaluations():
    rule = rule_queue_shed(max_rate=0.2)
    reg = Registry()
    adm = reg.counter("online/ingested")
    rej = reg.counter("online/rejected")
    adm.inc(100)
    assert rule.check(reg.snapshot())[0] == OK
    # next interval: 50 offered, 30 shed -> 60% > 20% -> CRIT
    adm.inc(20)
    rej.inc(30)
    assert rule.check(reg.snapshot())[0] == CRIT
    # following interval: healthy again (deltas, not cumulative rate)
    adm.inc(100)
    status, _, rate = rule.check(reg.snapshot())
    assert status == OK and rate == 0.0
    # idle interval: no traffic is OK, not a division by zero
    assert rule.check(reg.snapshot())[0] == OK


def test_rule_fleet_starvation():
    rule = rule_fleet_starvation(min_tenants=2)
    reg = _reg_with(gauges=[("fleet/bucket_tenants", {"bucket": "a"}, 4),
                            ("fleet/bucket_tenants", {"bucket": "b"}, 1)])
    status, msg, v = rule.check(reg.snapshot())
    assert status == WARN and v == 1
    reg2 = _reg_with(gauges=[("fleet/bucket_tenants", {"bucket": "a"}, 4)])
    assert rule.check(reg2.snapshot())[0] == OK


def test_rule_comm_exposed_share():
    rule = rule_comm_exposed(max_share=0.5)
    reg = _reg_with(hists=[("solver/step_s", {}, [1.0, 1.0]),
                           ("solver/comm_exposed_s", {}, [0.8, 0.9])])
    status, _, share = rule.check(reg.snapshot())
    assert status == WARN and share == pytest.approx(0.85)
    reg2 = _reg_with(hists=[("solver/step_s", {}, [1.0]),
                            ("solver/comm_exposed_s", {}, [0.1])])
    assert rule.check(reg2.snapshot())[0] == OK


def test_broken_rule_degrades_to_warn_not_crash():
    def boom(snap):
        raise KeyError("broken rule")
    mon = HealthMonitor(Registry(), [HealthRule("bad", boom)],
                        clock=FakeClock())
    [ev] = mon.evaluate()
    assert ev.status == WARN and "rule error" in ev.message


# ---------------------------------------------------------------------------
# health monitor: verdict recording + edge-triggered dumps
# ---------------------------------------------------------------------------

def test_monitor_records_verdicts_into_registry():
    reg = Registry()
    reg.gauge("online/staleness_s").set(1.0)
    mon = HealthMonitor(reg, [rule_staleness(10.0)], clock=FakeClock())
    mon.evaluate()
    snap = reg.snapshot()
    assert snap["gauges"]["health/status{rule=staleness}"] == 0
    assert snap["gauges"]["health/overall"] == 0
    reg.gauge("online/staleness_s").set(99.0)
    mon.evaluate()
    snap = reg.snapshot()
    assert snap["gauges"]["health/status{rule=staleness}"] == 2
    assert snap["gauges"]["health/overall"] == 2
    assert snap["counters"][
        "health/transitions{rule=staleness,status=crit}"] == 1
    assert mon.status == CRIT


def test_monitor_fires_exactly_one_dump_per_crit_edge(tmp_path):
    reg = Registry()
    reg.gauge("online/staleness_s").set(1.0)
    rec = FlightRecorder(capacity=8, clock=FakeClock(), registry=reg)
    mon = HealthMonitor(reg, [rule_staleness(10.0)], recorder=rec,
                        dump_dir=str(tmp_path), clock=FakeClock())
    mon.evaluate()
    assert rec.dumps == []                  # healthy: no dump
    reg.gauge("online/staleness_s").set(99.0)
    for _ in range(5):                      # stays CRIT across evals
        mon.evaluate()
    assert len(rec.dumps) == 1              # edge-triggered, not level
    b = load_bundle(rec.dumps[0])
    assert b["reason"].startswith("health:staleness")
    # recovery re-arms the edge: a second breach dumps again
    reg.gauge("online/staleness_s").set(1.0)
    mon.evaluate()
    reg.gauge("online/staleness_s").set(99.0)
    mon.evaluate()
    assert len(rec.dumps) == 2


def test_monitor_poll_rate_limit():
    reg = Registry()
    calls = []

    def probe(snap):
        calls.append(1)
        return OK, "ok", None

    clock = FakeClock()                     # +1s per reading
    mon = HealthMonitor(reg, [HealthRule("probe", probe)],
                        min_interval_s=10.0, clock=clock)
    for _ in range(8):
        mon.poll()
    # 8 polls over ~16 fake seconds with a 10 s interval -> ~2 evals
    assert 1 <= len(calls) < 8


def test_monitor_healthz_payload():
    reg = Registry()
    reg.gauge("online/staleness_s").set(99.0)
    mon = HealthMonitor(reg, [rule_staleness(10.0)], clock=FakeClock())
    hz = mon.healthz()
    assert hz["status"] == CRIT
    assert hz["rules"]["staleness"]["status"] == CRIT
    assert "99.000s" in hz["rules"]["staleness"]["message"]


# ---------------------------------------------------------------------------
# prometheus exposition
# ---------------------------------------------------------------------------

def test_render_prometheus_counters_gauges_histograms():
    reg = Registry()
    reg.counter("solver/iters", solver="d3ca", engine="simulated").inc(5)
    reg.gauge("solver/objective", solver="d3ca").set(0.25)
    h = reg.histogram("solver/step_s", solver="d3ca")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = render_prometheus(reg.snapshot())
    assert "# TYPE solver_iters counter" in text
    assert 'solver_iters{engine="simulated",solver="d3ca"} 5.0' in text
    assert "# TYPE solver_step_s summary" in text
    assert 'quantile="0.5"' in text
    assert 'solver_step_s_count{solver="d3ca"} 3.0' in text
    parsed = parse_prometheus_text(text)    # the format self-validates
    assert parsed["solver_objective"][
        frozenset({("solver", "d3ca")})] == 0.25
    assert parsed["solver_step_s_sum"][
        frozenset({("solver", "d3ca")})] == pytest.approx(0.6)


def test_render_prometheus_nonfinite_values():
    reg = Registry()
    reg.gauge("w_norm").set(float("nan"))
    reg.gauge("peak").set(float("inf"))
    text = render_prometheus(reg.snapshot())
    parsed = parse_prometheus_text(text)
    assert math.isnan(parsed["w_norm"][frozenset()])
    assert math.isinf(parsed["peak"][frozenset()])


def test_render_prometheus_sanitizes_names_and_escapes_labels():
    reg = Registry()
    reg.counter("compress/ef_norm/w-contrib", codec='top"k').inc()
    text = render_prometheus(reg.snapshot(), prefix="repro_")
    assert "repro_compress_ef_norm_w_contrib" in text
    parsed = parse_prometheus_text(text)
    [(labels, v)] = list(
        parsed["repro_compress_ef_norm_w_contrib"].items())
    assert ("codec", 'top"k') in labels and v == 1.0


def test_parse_prometheus_rejects_garbage():
    with pytest.raises(ValueError, match="not a valid sample"):
        parse_prometheus_text("this is { not metrics")
    with pytest.raises(ValueError, match="bad value"):
        parse_prometheus_text("ok_name twelve")


def test_render_empty_registry_is_valid():
    text = render_prometheus(Registry().snapshot())
    assert parse_prometheus_text(text) == {}


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------

def _get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, resp.read().decode()


def test_obs_server_serves_metrics_healthz_varz():
    reg = Registry()
    reg.counter("online/ingested").inc(7)
    reg.gauge("online/staleness_s").set(1.0)
    mon = HealthMonitor(reg, [rule_staleness(10.0)], clock=FakeClock())
    rec = FlightRecorder(capacity=8, clock=FakeClock())
    with ObsServer(reg, monitor=mon, recorder=rec, port=0) as srv:
        assert srv.port != 0                # ephemeral port resolved

        code, body = _get(srv.url + "/metrics")
        assert code == 200
        parsed = parse_prometheus_text(body)     # valid text format
        assert parsed["online_ingested"][frozenset()] == 7.0
        # the monitor's own verdicts are scrapeable too (after healthz)
        code, body = _get(srv.url + "/healthz")
        assert code == 200
        assert json.loads(body)["status"] == "ok"

        code, body = _get(srv.url + "/varz")
        varz = json.loads(body)
        assert varz["metrics"]["counters"]["online/ingested"] == 7.0
        assert varz["recorder"]["capacity"] == 8
        assert varz["uptime_s"] >= 0

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404


def test_obs_server_healthz_503_on_crit():
    reg = Registry()
    reg.gauge("online/staleness_s").set(999.0)
    mon = HealthMonitor(reg, [rule_staleness(10.0)], clock=FakeClock())
    with ObsServer(reg, monitor=mon, port=0) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/healthz")
        assert ei.value.code == 503         # probes need no body parsing
        assert json.loads(ei.value.read().decode())["status"] == "crit"
        # /metrics keeps serving while unhealthy
        code, body = _get(srv.url + "/metrics")
        assert code == 200
        parse_prometheus_text(body)


def test_obs_server_without_monitor_reports_ok():
    with ObsServer(Registry(), port=0) as srv:
        code, body = _get(srv.url + "/healthz")
        assert code == 200 and json.loads(body)["status"] == "ok"


# ---------------------------------------------------------------------------
# launch helper
# ---------------------------------------------------------------------------

def test_parse_listen_forms():
    from repro.launch.obs import parse_listen
    assert parse_listen("0.0.0.0:9100") == ("0.0.0.0", 9100)
    assert parse_listen(":0") == ("127.0.0.1", 0)
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_listen("nope")


def test_build_plane_wires_recorder_monitor_server(tmp_path):
    import argparse

    from repro.launch.obs import build_plane
    args = argparse.Namespace(
        listen="127.0.0.1:0", health=True,
        flight_recorder=str(tmp_path / "b.json"), flight_capacity=32)
    plane = build_plane(args, rules=online_rules(), start_server=False)
    assert plane.active
    assert plane.recorder.capacity == 32
    assert plane.monitor.recorder is plane.recorder
    assert plane.monitor.dump_dir == str(tmp_path)
    assert plane.server is not None and plane.server.port == 0
    assert plane.tracer_or(None) is plane.recorder
    sentinel = object()
    assert plane.tracer_or(sentinel) is sentinel

    out = plane.finalize()
    assert out["flight_recorder"]["bundle"] == str(tmp_path / "b.json")
    assert load_bundle(str(tmp_path / "b.json"))["reason"] == "exit"


def test_build_plane_inactive_without_flags():
    import argparse

    from repro.launch.obs import build_plane
    args = argparse.Namespace(listen=None, health=False,
                              flight_recorder=None, flight_capacity=None)
    plane = build_plane(args)
    assert not plane.active
    assert plane.crash_guard() is not None  # still a usable no-op guard
    with plane.crash_guard():
        pass
    assert plane.finalize() == {}


# ---------------------------------------------------------------------------
# threading through the real stack (obs CI leg)
# ---------------------------------------------------------------------------

def _small_problem():
    from repro.core import D3CAConfig, get_solver
    from repro.data import make_svm_data
    X, y = make_svm_data(120, 40, seed=0)
    cfg = D3CAConfig(lam=1e-1, outer_iters=4, local_steps=8)
    return get_solver("d3ca")(engine="simulated"), X, y, cfg


@pytest.mark.obs
def test_live_endpoint_does_not_perturb_solve():
    """/metrics scraped concurrently while a solve runs: the text stays
    valid Prometheus throughout, and the solve's iterates/objective
    series are bit-identical to the same solve without the endpoint."""
    solver, X, y, cfg = _small_problem()
    reg_off = Registry()
    plain = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg,
                         registry=reg_off)

    reg_on = Registry()
    stop = threading.Event()
    scrapes, parse_errors = [], []
    with ObsServer(reg_on, port=0) as srv:
        def scraper():
            while not stop.is_set():
                try:
                    _, body = _get(srv.url + "/metrics")
                    parse_prometheus_text(body)
                    scrapes.append(len(body))
                except Exception as e:      # pragma: no cover - fail below
                    parse_errors.append(repr(e))
        t = threading.Thread(target=scraper)
        t.start()
        try:
            live = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg,
                                registry=reg_on)
        finally:
            stop.set()
            t.join()

    assert parse_errors == []
    assert len(scrapes) > 0                 # the endpoint really ran
    assert np.array_equal(np.asarray(plain.w), np.asarray(live.w))
    assert ([h["objective"] for h in plain.history]
            == [h["objective"] for h in live.history])
    assert ([h["duality_gap"] for h in plain.history]
            == [h["duality_gap"] for h in live.history])


@pytest.mark.obs
def test_solve_with_recorder_and_monitor_stays_ok():
    """A healthy solve under the full plane: recorder ring bounded, all
    rules OK end-to-end, no dumps fired."""
    from repro.obs import solver_rules
    solver, X, y, cfg = _small_problem()
    reg = Registry()
    rec = FlightRecorder(capacity=32, registry=reg)
    mon = HealthMonitor(reg, solver_rules(max_comm_share=1.0),
                        recorder=rec, dump_dir="/tmp")
    res = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg, tracer=rec,
                       registry=reg, monitor=mon)
    assert res.iters == cfg.outer_iters
    assert mon.status == OK
    assert mon.evaluations >= cfg.outer_iters   # polled every iteration
    assert rec.dumps == []
    assert len(rec.events) <= 32
    snap = reg.snapshot()
    assert snap["gauges"]["health/overall"] == 0


def _online_service(monitor_rules, queue_capacity=4096, clock=None,
                    dump_dir=None):
    from repro.core import D3CAConfig
    from repro.online import OnlineConfig, OnlineSolverService
    reg = Registry()
    rec = FlightRecorder(capacity=64, registry=reg)
    mon = HealthMonitor(reg, monitor_rules, recorder=rec,
                        dump_dir=dump_dir)
    cfg = OnlineConfig(m=10, capacity=32, P=2, Q=2,
                       solver_cfg=D3CAConfig(lam=1e-2, local_steps=8),
                       passes=2, queue_capacity=queue_capacity)
    kw = {} if clock is None else {"clock": clock}
    svc = OnlineSolverService(cfg, registry=reg, monitor=mon, **kw)
    return svc, reg, rec, mon


def _stream(b, m, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(b, m)).astype(np.float32)
    y = np.sign(X @ np.linspace(-1, 1, m) + 0.1).astype(np.float32)
    return X, np.where(y == 0, 1.0, y)


@pytest.mark.obs
def test_online_service_healthy_run_stays_ok(tmp_path):
    svc, reg, rec, mon = _online_service(
        online_rules(max_staleness_s=1e6, max_shed_rate=0.5),
        dump_dir=str(tmp_path))
    for i in range(3):
        svc.submit(*_stream(8, 10, seed=i))
        svc.run_pending()
        svc.score(_stream(16, 10, seed=100 + i)[0])
    assert mon.status == OK
    assert mon.evaluations > 0
    assert rec.dumps == []
    # the service published its w_norm sentinel for the divergence rule
    g = {k.split("{")[0]: v for k, v in reg.snapshot()["gauges"].items()}
    assert math.isfinite(g["online/w_norm"]) and g["online/w_norm"] > 0


@pytest.mark.obs
def test_online_divergence_flips_crit_and_dumps_once(tmp_path):
    """Injected NaN model (a diverged update) through the real publish
    path: the divergence rule flips /healthz to CRIT and fires exactly
    one postmortem dump."""
    svc, reg, rec, mon = _online_service(
        online_rules(max_staleness_s=1e6), dump_dir=str(tmp_path))
    svc.submit(*_stream(8, 10))
    svc.run_pending()
    assert mon.status == OK

    # corrupt the next update's result: real solver, poisoned output
    real_update = svc.solver.update

    def poisoned(*a, **kw):
        res = real_update(*a, **kw)
        import dataclasses as dc
        return dc.replace(res, w=np.full_like(np.asarray(res.w),
                                              np.nan))
    svc.solver.update = poisoned
    svc.submit(*_stream(8, 10, seed=1))
    svc.run_pending()                       # publishes NaN w -> NaN norm

    assert mon.status == CRIT
    hz = mon.healthz(evaluate=False)
    assert hz["rules"]["online_divergence"]["status"] == CRIT
    assert len(rec.dumps) == 1              # exactly one bundle
    # staying diverged across further activity does not re-dump
    svc.score(_stream(8, 10)[0])
    mon.evaluate()
    assert len(rec.dumps) == 1
    b = load_bundle(rec.dumps[0])
    assert b["reason"].startswith("health:online_divergence")
    assert not math.isfinite(
        {k.split("{")[0]: v
         for k, v in b["metrics"]["gauges"].items()}["online/w_norm"])


@pytest.mark.obs
def test_online_staleness_breach_flips_crit_and_dumps_once(tmp_path):
    clock = FakeClock()
    svc, reg, rec, mon = _online_service(
        online_rules(max_staleness_s=30.0), clock=clock,
        dump_dir=str(tmp_path))
    svc.submit(*_stream(8, 10))
    svc.run_pending()
    assert mon.status == OK
    # the fake clock advances 1 s per reading: keep scoring without an
    # update until the served snapshot ages past the breach
    for i in range(60):
        svc.score(_stream(4, 10, seed=i)[0])
    assert mon.status == CRIT
    assert mon.healthz(evaluate=False)["rules"]["staleness"]["status"] \
        == CRIT
    assert len(rec.dumps) == 1
    assert load_bundle(rec.dumps[0])["reason"] \
        .startswith("health:staleness")


@pytest.mark.obs
def test_online_queue_saturation_flips_crit_and_dumps_once(tmp_path):
    from repro.online import QueueFullError
    svc, reg, rec, mon = _online_service(
        online_rules(max_staleness_s=1e6, max_shed_rate=0.2),
        queue_capacity=8, dump_dir=str(tmp_path))
    svc.submit(*_stream(8, 10))             # fills the queue
    with pytest.raises(QueueFullError):
        svc.submit(*_stream(8, 10, seed=1))  # 8/16 offered shed -> 50%
    assert mon.status == CRIT
    assert mon.healthz(evaluate=False)["rules"]["queue_shed"]["status"] \
        == CRIT
    assert len(rec.dumps) == 1
    assert load_bundle(rec.dumps[0])["reason"] \
        .startswith("health:queue_shed")
    # draining recovers: the shed-rate delta window sees clean traffic
    svc.run_pending()
    svc.submit(*_stream(4, 10, seed=2))
    assert mon.status == OK
    assert len(rec.dumps) == 1
