"""Compressed-communication subsystem (repro.core.compress): codecs,
error feedback, per-collective policies, the CompressedComm executor,
exact wire accounting, and the solver-level ``compression=`` knob.

Everything here runs on ONE device (the grid engine uses named vmap
axes); the mesh-engine equivalence + EF convergence checks run in a
subprocess with a forced device grid (pytest marker ``compression``,
its own CI matrix leg -- see helpers/solver_equiv.py mode "compress").
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import D3CAConfig, get_solver
from repro.core.comm import CommSchedule, SyncComm
from repro.core.compress import (CompressedComm, CompressionPolicy,
                                 IdentityCodec, Int8Codec, TopKCodec,
                                 as_policy, compress, decompress, get_codec,
                                 init_error, wire_accounting)
from repro.core.d3ca import d3ca_schedule
from repro.data import make_svm_data

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def test_identity_codec_is_exact_and_stateless():
    c = get_codec("identity")
    v = jnp.asarray(RNG.normal(size=(33,)), jnp.float32)
    deq, err = c.apply(v)
    assert deq is v                    # same array object: bit-identical
    assert err is None and not c.stateful
    # "none" is an accepted spelling
    assert isinstance(get_codec("none"), IdentityCodec)


def test_identity_payload_bytes_exactly_uncompressed():
    c = get_codec("identity")
    for shape, dtype in [((17,), jnp.float32), ((4, 5), jnp.float32),
                         ((128,), jnp.int8)]:
        arr = jnp.zeros(shape, dtype)
        assert c.payload_nbytes(shape, dtype) == arr.size * arr.dtype.itemsize


def test_int8_codec_bounded_error():
    c = get_codec("int8")
    v = jnp.asarray(RNG.normal(size=(64,)) * 10, jnp.float32)
    deq, err = c.apply(v, jnp.zeros_like(v))
    scale = float(jnp.max(jnp.abs(v))) / 127.0 + 1e-12
    assert float(jnp.abs(deq - v).max()) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(err), np.asarray(v - deq),
                               atol=1e-7)
    assert c.payload_nbytes((64,), jnp.float32) == 64 + 4   # int8 + scale


def test_fp8_codec_bounded_relative_error():
    try:
        c = get_codec("fp8")
    except NotImplementedError:
        pytest.skip("no float8_e4m3fn in this jax build")
    v = jnp.asarray(RNG.normal(size=(64,)) * 3, jnp.float32)
    deq, err = c.apply(v, jnp.zeros_like(v))
    # e4m3 has ~2 decimal digits; scaled into range the error is small
    assert float(jnp.abs(deq - v).max()) <= 0.1 * float(jnp.abs(v).max())
    assert c.payload_nbytes((64,), jnp.float32) == 64 + 4


def test_topk_codec_keeps_largest_and_feeds_back_rest():
    c = get_codec("topk:0.25")
    v = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.3, 0.05, 7.0, -0.01],
                    jnp.float32)
    deq, err = c.apply(v, jnp.zeros_like(v))
    assert c.k_of(8) == 2               # ceil(0.25 * 8)
    kept = np.flatnonzero(np.asarray(deq))
    assert set(kept) == {1, 6}          # the two largest-|.| entries
    # everything dropped is in the residual, exactly
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(v),
                               atol=1e-7)
    # payload: k (value, index) pairs
    assert c.payload_nbytes((8,), jnp.float32) == c.k_of(8) * 8
    with pytest.raises(ValueError, match="fraction"):
        TopKCodec(0.0)


def test_codec_registry():
    assert isinstance(get_codec("int8"), Int8Codec)
    assert get_codec("topk:0.5").frac == 0.5
    assert get_codec("topk").frac == 0.1          # default fraction
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("int4")


def test_error_feedback_accumulation_tracks_true_sum():
    """Ported from the legacy repro.optim.compression suite: with EF the
    accumulated dequantized signal tracks the true accumulated signal."""
    g = {"a": jnp.asarray(RNG.normal(size=(32,)), jnp.float32)}
    e = init_error(g)
    total_true = np.zeros(32)
    total_deq = np.zeros(32)
    for _ in range(50):
        q, s, e = compress(g, e)
        deq = decompress(q, s)
        total_true += np.asarray(g["a"])
        total_deq += np.asarray(deq["a"])
    assert np.abs(total_true - total_deq).max() / 50 < 1e-2


def test_ef_sgd_converges_quadratic():
    """Ported: EF-int8 compressed 'all-reduce' keeps SGD convergence."""
    target = jnp.asarray(RNG.normal(size=(16,)), jnp.float32)
    w = jnp.zeros((16,))
    e = init_error({"w": w})
    for _ in range(200):
        g = {"w": w - target}
        q, s, e = compress(g, e)
        w = w - 0.1 * decompress(q, s)["w"]
    assert float(jnp.abs(w - target).max()) < 1e-2


def test_int8_bounded_per_step_error_property():
    """Ported (hypothesis): per-step quantization error <= scale/2."""
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.lists(st.floats(-100, 100), min_size=2,
                               max_size=40))
    def check(vals):
        g = {"a": jnp.asarray(np.array(vals, np.float32))}
        e = init_error(g)
        q, s, _ = compress(g, e)
        deq = decompress(q, s)
        scale = float(np.abs(np.array(vals)).max()) / 127.0 + 1e-12
        assert float(jnp.abs(deq["a"] - g["a"]).max()) <= scale * 0.5 + 1e-6

    check()


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

def test_policy_from_spec_and_lookup():
    p = CompressionPolicy.from_spec("int8,rhs=identity")
    assert p.codec_for("anything").name == "int8"
    assert p.codec_for("rhs").name == "identity"
    assert p.spec == "int8,rhs=identity"
    p2 = as_policy("dalpha=fp8,w_contrib=topk:0.2")
    assert p2.default.name == "identity"
    assert p2.codec_for("w_contrib").frac == 0.2
    assert as_policy(None) is None
    assert as_policy(p) is p
    assert as_policy({"default": "int8", "rhs": "identity"}).spec == \
        "int8,rhs=identity"


def test_policy_spec_errors():
    with pytest.raises(ValueError, match="assigned twice"):
        CompressionPolicy.from_spec("a=int8,a=fp8")
    with pytest.raises(ValueError, match="two default"):
        CompressionPolicy.from_spec("int8,fp8")
    with pytest.raises(ValueError, match="malformed"):
        CompressionPolicy.from_spec("a=")


def test_policy_validates_against_schedule():
    sched = d3ca_schedule()
    as_policy("dalpha=int8").validate(sched)      # declared name: fine
    with pytest.raises(ValueError, match="never declares"):
        as_policy("dw=int8").validate(sched)      # radisa's name, not d3ca's
    assert as_policy("int8").stateful_names(sched) == ("dalpha", "w_contrib")
    assert as_policy("identity").stateful_names(sched) == ()


# ---------------------------------------------------------------------------
# CompressedComm under named vmap (the grid engine's substrate)
# ---------------------------------------------------------------------------

def _run_cells(policy, vals, ef=None):
    sched = CommSchedule().psum("s", axis="data")
    axis_map = {"data": ("d",), "model": ("m",)}

    def cell(x, e):
        comm = CompressedComm(SyncComm(sched, axis_map,
                                       {"data": 3, "model": 1}),
                              policy, ef=e)
        out = comm("s", x)
        comm.finalize()
        return out, comm.ef_out, comm.wire_bytes["s"]

    ef = ef if ef is not None else {"s": jnp.zeros(vals.shape)}
    return jax.vmap(jax.vmap(cell, axis_name="m"), axis_name="d")(
        vals, ef)


def test_compressed_comm_identity_is_exact_psum():
    vals = jnp.asarray(RNG.normal(size=(3, 1, 8)), jnp.float32)
    out, ef_out, _ = _run_cells(as_policy("identity"), vals, ef={})
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(vals.sum(axis=0, keepdims=True)
                                    .repeat(3, 0)))
    assert ef_out == {}


def test_compressed_comm_int8_reduces_dequantized_and_updates_ef():
    vals = jnp.asarray(RNG.normal(size=(3, 1, 8)) * 5, jnp.float32)
    policy = as_policy("int8")
    out, ef_out, wire = _run_cells(policy, vals)
    # psum of per-cell dequantized payloads: within 3 * (scale/2)
    true = np.asarray(vals.sum(axis=0))
    tol = 3 * (np.abs(np.asarray(vals)).max(axis=(0, 1)).max() / 127) + 1e-5
    assert np.abs(np.asarray(out[0]) - true).max() <= tol
    # the EF residual is the per-cell quantization error
    assert ef_out["s"].shape == (3, 1, 8)
    assert float(jnp.abs(ef_out["s"]).max()) > 0
    # wire bytes: compressed payload, per cell
    assert int(wire[0, 0]) == 8 + 4


def test_comm_wire_bytes_uncompressed_default():
    """Every Comm executor records exact payload bytes -- the base
    records the uncompressed size."""
    sched = CommSchedule().psum("s", axis="data")
    axis_map = {"data": ("d",), "model": ("m",)}

    def cell(x):
        comm = SyncComm(sched, axis_map, {"data": 2, "model": 1})
        out = comm("s", x)
        comm.finalize()
        return out, comm.wire_bytes["s"]

    _, wire = jax.vmap(jax.vmap(cell, axis_name="m"), axis_name="d")(
        jnp.ones((2, 1, 5), jnp.float32))
    assert int(wire[0, 0]) == 5 * 4


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

def test_wire_accounting_identity_equals_uncompressed():
    sched = d3ca_schedule()
    payloads = {"dalpha": jax.ShapeDtypeStruct((40,), jnp.float32),
                "w_contrib": jax.ShapeDtypeStruct((18,), jnp.float32)}
    sizes = {"data": 4, "model": 2}
    none = wire_accounting(sched, payloads, sizes, None)
    ident = wire_accounting(sched, payloads, sizes, as_policy("identity"))
    assert none["bytes_per_step"] == ident["bytes_per_step"] \
        == (40 + 18) * 4 * 8
    assert none["bytes_per_step"] == none["uncompressed_bytes_per_step"]
    assert none["collectives"]["dalpha"]["op"] == "pmean"
    assert none["collectives"]["dalpha"]["cells"] == 8


def test_wire_accounting_int8_cuts_bytes_3x():
    sched = d3ca_schedule()
    payloads = {"dalpha": jax.ShapeDtypeStruct((400,), jnp.float32),
                "w_contrib": jax.ShapeDtypeStruct((180,), jnp.float32)}
    sizes = {"data": 4, "model": 2}
    none = wire_accounting(sched, payloads, sizes, None)
    int8 = wire_accounting(sched, payloads, sizes, as_policy("int8"))
    assert int8["bytes_per_step"] * 3 <= none["bytes_per_step"]
    assert int8["uncompressed_bytes_per_step"] == none["bytes_per_step"]
    assert int8["compression"] == "int8"


# ---------------------------------------------------------------------------
# solver-level knob (simulated engine: single device)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    return make_svm_data(96, 30, seed=3)


def test_solver_compression_none_equals_identity_bitwise(problem):
    X, y = problem
    cfg = D3CAConfig(lam=1.0, outer_iters=3, local_steps=10)
    ws = {}
    for comp in (None, "identity"):
        s = get_solver("d3ca")(engine="simulated", compression=comp)
        ws[comp] = s.solve("hinge", X, y, P=3, Q=2, cfg=cfg,
                           record_history=False).w
    assert float(jnp.abs(ws[None] - ws["identity"]).max()) == 0.0


def test_solver_history_carries_comm_bytes(problem):
    X, y = problem
    cfg = D3CAConfig(lam=1.0, outer_iters=3, local_steps=10)
    res = get_solver("d3ca")(engine="simulated").solve(
        "hinge", X, y, P=3, Q=2, cfg=cfg)
    per_step = res.comm_bytes["bytes_per_step"]
    assert per_step > 0 and res.compression is None
    assert [h["comm_bytes"] for h in res.history] == \
        [per_step, 2 * per_step, 3 * per_step]
    # identity accounting invariant end-to-end
    assert res.comm_bytes["bytes_per_step"] == \
        res.comm_bytes["uncompressed_bytes_per_step"]


def test_solver_int8_converges_and_reports_fewer_bytes(problem):
    X, y = problem
    cfg = D3CAConfig(lam=1.0, outer_iters=8)
    r8 = get_solver("d3ca")(engine="simulated", compression="int8").solve(
        "hinge", X, y, P=3, Q=2, cfg=cfg)
    rn = get_solver("d3ca")(engine="simulated").solve(
        "hinge", X, y, P=3, Q=2, cfg=cfg)
    assert r8.comm_bytes["bytes_per_step"] * 3 <= \
        rn.comm_bytes["bytes_per_step"]
    assert r8.compression == "int8"
    # EF keeps the dual ascent on track (loose: same ballpark gap)
    assert r8.history[-1]["duality_gap"] <= \
        2 * rn.history[-1]["duality_gap"] + 1e-3


def test_solver_rejects_unknown_collective(problem):
    X, y = problem
    s = get_solver("d3ca")(engine="simulated", compression="dw=int8")
    with pytest.raises(ValueError, match="never declares"):
        s.solve("hinge", X, y, P=3, Q=2,
                cfg=D3CAConfig(lam=1.0, outer_iters=1))


# ---------------------------------------------------------------------------
# mesh engines (subprocess: forced device grid; own CI matrix leg)
# ---------------------------------------------------------------------------

@pytest.mark.compression
def test_mesh_identity_bit_identical_and_int8_ef_converges():
    """The tentpole contract on the mesh engines: identity/None
    bit-identical to the uncompressed engines for all 3 solvers x
    dense/sparse x ref/pallas, compression composes with staleness, and
    EF-int8 D3CA reaches the uncompressed duality gap within 2x
    iterations (helpers/solver_equiv.py, mode 'compress')."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "solver_equiv.py"), "compress"],
        env=ENV, timeout=900, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
