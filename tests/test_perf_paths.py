"""Equivalence tests for the performance-motivated code paths:

  * chunked cross entropy == full-logits cross entropy (value and grad)
  * gradient accumulation (lax.scan microbatches) == single-batch step
  * local_svrg row-then-column slicing (lo=) == pre-sliced sub-block
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.local import local_svrg
from repro.core.losses import get_loss
from repro.models import Transformer, reduced
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig


def _model_and_batch(loss_chunk, seed=0, batch=8, seq=32):
    cfg = reduced(get_config("qwen3_1_7b"), loss_chunk=loss_chunk)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab)
    labels = jax.random.randint(k2, (batch, seq), 0, cfg.vocab)
    return model, params, {"tokens": tokens, "labels": labels}


def test_chunked_loss_matches_full():
    model_c, params, batch = _model_and_batch(loss_chunk=8)
    model_f = Transformer(dataclasses.replace(model_c.cfg, loss_chunk=None))

    lc, gc = jax.value_and_grad(model_c.train_loss)(params, batch)
    lf, gf = jax.value_and_grad(model_f.train_loss)(params, batch)
    np.testing.assert_allclose(lc, lf, rtol=1e-4)
    flat_c, flat_f = jax.tree.leaves(gc), jax.tree.leaves(gf)
    for a, b in zip(flat_c, flat_f):
        # the chunked backward recomputes logits from bf16 activations
        # instead of reusing saved fp32 ones -> small recompute noise
        np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-4)


@pytest.mark.parametrize("accum", [2, 4])
def test_grad_accumulation_matches_single_batch(accum):
    model, params, batch = _model_and_batch(loss_chunk=None)
    opt_cfg = AdamWConfig(lr=1e-3)
    from repro.optim import adamw_init

    step1 = make_train_step(model, opt_cfg, accum_steps=1)
    stepN = make_train_step(model, opt_cfg, accum_steps=accum)
    o1 = adamw_init(params)
    oN = adamw_init(params)
    p1, o1, m1 = jax.jit(step1)(params, o1, batch)
    pN, oN, mN = jax.jit(stepN)(params, oN, batch)
    np.testing.assert_allclose(m1["loss"], mN["loss"], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)):
        # accumulation changes the fp summation order of the grads; after
        # AdamW's sqrt(nu) normalization, elements with ~zero gradient can
        # flip the sign of their (lr-sized) step, so tolerate a few
        # lr-scale outliers but require negligible mean drift
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=2.5e-3)
        assert float(jnp.mean(jnp.abs(a - b))) < 5e-5


def test_local_svrg_lo_matches_presliced():
    loss = get_loss("hinge")
    key = jax.random.PRNGKey(3)
    n_p, m_q, m_sub, lo = 64, 24, 8, 16
    kx, ky, kr = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_p, m_q))
    y = jnp.sign(jax.random.normal(ky, (n_p,)))
    mask = jnp.ones((n_p,))
    w_tilde = jnp.zeros((m_q,))
    z = x @ w_tilde
    w_anchor = w_tilde[lo:lo + m_sub]
    gz = loss.grad(z, y) * mask
    mu = gz @ x / n_p + 1e-3 * w_tilde

    kwargs = dict(lam=1e-3, L=32, eta=0.05, key=kr)
    w_a = local_svrg(loss, x[:, lo:lo + m_sub], y, mask, z, w_anchor,
                     mu[lo:lo + m_sub], **kwargs)
    w_b = local_svrg(loss, x, y, mask, z, w_anchor, mu[lo:lo + m_sub],
                     lo=lo, **kwargs)
    np.testing.assert_allclose(w_a, w_b, rtol=1e-6, atol=1e-7)


def test_int8_kv_cache_close_to_bf16():
    cfg = reduced(get_config("mistral_nemo_12b"))
    model_b = Transformer(cfg)
    model_q = Transformer(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    params, _ = model_b.init(jax.random.PRNGKey(0))
    B, S, gen = 2, 16, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    outs = {}
    for name, model in (("bf16", model_b), ("int8", model_q)):
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, S + gen))(
                params, {"tokens": tokens})
        seq = [logits]
        step = jax.jit(model.decode_step)
        for _ in range(gen):
            nxt = jnp.argmax(seq[-1][:, -1:], axis=-1).astype(jnp.int32)
            logits, cache = step(params, cache, {"tokens": nxt})
            seq.append(logits)
        outs[name] = jnp.concatenate(seq, axis=1)

    # int8 cache adds quantization noise; logits must stay close and the
    # greedy decode path identical for this toy problem
    np.testing.assert_allclose(outs["int8"], outs["bf16"],
                               rtol=0.1, atol=0.15)
