"""Checkpoint manager: atomicity, async, keep-N, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 6)),
            "nested": {"b": jnp.arange(12).reshape(3, 4).astype(jnp.float32)},
            "lst": [jnp.ones((2,)), jnp.zeros((3,))]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t)
    r = restore_tree(str(tmp_path / "ck"), jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_manager_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), float(s))})
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]
    step, t = mgr.restore({"x": jnp.zeros((2,))})
    assert step == 4 and float(t["x"][0]) == 4.0


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    t = _tree(1)
    mgr.save_async(7, t)
    mgr.wait()
    step, r = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=5)
    mgr.save(1, _tree())
    for d in os.listdir(tmp_path):
        assert not d.endswith(".tmp")


def test_elastic_reshard(tmp_path):
    """Save under one sharding, restore under another (mesh change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = jax.make_mesh((1,), ("data",))
    x = jax.device_put(jnp.arange(16.0).reshape(4, 4),
                       NamedSharding(mesh1, P("data")))
    save_tree(str(tmp_path / "ck"), {"x": x})
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    tgt = NamedSharding(mesh2, P(None, "model"))
    r = restore_tree(str(tmp_path / "ck"), {"x": jnp.zeros((4, 4))},
                     shardings={"x": tgt})
    assert r["x"].sharding == tgt
    np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(x))


def test_restore_rejects_shape_mismatch(tmp_path):
    save_tree(str(tmp_path / "ck"), {"x": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore_tree(str(tmp_path / "ck"), {"x": jnp.zeros((4,))})
