"""Checkpoint manager: atomicity, async, keep-N, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 6)),
            "nested": {"b": jnp.arange(12).reshape(3, 4).astype(jnp.float32)},
            "lst": [jnp.ones((2,)), jnp.zeros((3,))]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t)
    r = restore_tree(str(tmp_path / "ck"), jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_manager_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), float(s))})
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]
    step, t = mgr.restore({"x": jnp.zeros((2,))})
    assert step == 4 and float(t["x"][0]) == 4.0


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    t = _tree(1)
    mgr.save_async(7, t)
    mgr.wait()
    step, r = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=5)
    mgr.save(1, _tree())
    for d in os.listdir(tmp_path):
        assert not d.endswith(".tmp")


def test_elastic_reshard(tmp_path):
    """Save under one sharding, restore under another (mesh change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = jax.make_mesh((1,), ("data",))
    x = jax.device_put(jnp.arange(16.0).reshape(4, 4),
                       NamedSharding(mesh1, P("data")))
    save_tree(str(tmp_path / "ck"), {"x": x})
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    tgt = NamedSharding(mesh2, P(None, "model"))
    r = restore_tree(str(tmp_path / "ck"), {"x": jnp.zeros((4, 4))},
                     shardings={"x": tgt})
    assert r["x"].sharding == tgt
    np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(x))


def test_restore_rejects_shape_mismatch(tmp_path):
    save_tree(str(tmp_path / "ck"), {"x": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore_tree(str(tmp_path / "ck"), {"x": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# the online service's contract: versioned snapshot swap under concurrent
# readers, and recovery from a crash mid-swap
# ---------------------------------------------------------------------------

def test_concurrent_readers_see_complete_snapshots(tmp_path):
    """Readers restoring the latest step while a writer publishes new
    ones must always get an internally consistent tree: every leaf from
    the SAME version (the write-to-tmp + atomic-rename protocol makes a
    step directory visible only when complete)."""
    import threading

    mgr = CheckpointManager(str(tmp_path), keep_n=0)   # no gc: isolate swap
    mgr.save(1, {"x": jnp.full((4,), 1.0), "y": jnp.full((3,), 1.0)})
    like = {"x": jnp.zeros((4,)), "y": jnp.zeros((3,))}
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            step, t = mgr.restore(like)
            x, y = float(np.asarray(t["x"])[0]), float(np.asarray(t["y"])[0])
            if not (x == y == float(step)):
                torn.append((step, x, y))

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for th in threads:
        th.start()
    for s in range(2, 30):
        mgr.save(s, {"x": jnp.full((4,), float(s)),
                     "y": jnp.full((3,), float(s))})
    stop.set()
    for th in threads:
        th.join()
    assert torn == [], f"torn snapshot reads: {torn[:5]}"
    assert mgr.latest_step() == 29


def test_crash_mid_swap_recovers_previous_version(tmp_path):
    """A crash that leaves a partial ``.tmp`` directory (died before the
    atomic rename) must be invisible: latest_step stays on the last
    complete version, restore works, and re-saving the same step
    clobbers the debris."""
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(1, {"x": jnp.full((2,), 1.0)})
    # simulate the crash window: step 2's write began (tmp dir, partial
    # leaves, no index) but the rename never happened
    debris = tmp_path / "step_00000002.tmp"
    debris.mkdir()
    (debris / "leaf_00000.npy").write_bytes(b"partial")
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    step, t = mgr.restore({"x": jnp.zeros((2,))})
    assert step == 1 and float(t["x"][0]) == 1.0
    # the interrupted save can simply be retried
    mgr.save(2, {"x": jnp.full((2,), 2.0)})
    assert mgr.latest_step() == 2
    step, t = mgr.restore({"x": jnp.zeros((2,))})
    assert step == 2 and float(t["x"][0]) == 2.0
    assert not os.path.exists(debris)
