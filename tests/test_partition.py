"""Hypothesis property tests for the P x Q partitioner."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 40), m=st.integers(2, 30),
       P=st.integers(1, 5), Q=st.integers(1, 4))
def test_roundtrip(n, m, P, Q):
    rng = np.random.default_rng(n * 100 + m)
    X = rng.normal(size=(n, m)).astype(np.float32)
    y = np.sign(rng.normal(size=n)).astype(np.float32)
    y[y == 0] = 1
    data = partition(X, y, P, Q)
    Xd, yd = data.dense()
    np.testing.assert_array_equal(np.asarray(Xd), X)
    np.testing.assert_array_equal(np.asarray(yd), y)
    assert int(data.mask.sum()) == n
    assert data.x_blocks.shape[0] == P and data.x_blocks.shape[1] == Q


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 30), m=st.integers(2, 20),
       P=st.integers(1, 4), Q=st.integers(1, 3))
def test_vector_block_maps(n, m, P, Q):
    rng = np.random.default_rng(n + m)
    X = rng.normal(size=(n, m)).astype(np.float32)
    y = np.ones(n, np.float32)
    data = partition(X, y, P, Q)
    w = jnp.asarray(rng.normal(size=m).astype(np.float32))
    a = jnp.asarray(rng.normal(size=n).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(data.w_from_blocks(data.w_to_blocks(w))), np.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(data.alpha_from_blocks(data.alpha_to_blocks(a))),
        np.asarray(a))


def test_padding_is_inert():
    """Padded rows never contribute to objective or primal-dual map."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(10, 7)).astype(np.float32)
    y = np.sign(rng.normal(size=10)).astype(np.float32); y[y == 0] = 1
    from repro.core import D3CAConfig, d3ca_simulated, objective
    for P, Q in [(3, 2), (4, 3)]:
        data = partition(X, y, P, Q)
        w, alpha = d3ca_simulated("hinge", data,
                                  D3CAConfig(lam=1.0, outer_iters=5))
        assert w.shape == (7,) and alpha.shape == (10,)
        assert np.isfinite(float(objective("hinge", X, y, w, 1.0)))
