"""Fleet-vs-solo equivalence on the shard_map mesh (subprocess).

Runs under a forced 8-device host grid (4 x 2).  For each solver x
block_format case, a 3-tenant fleet batch is solved ONCE and every
tenant's result is compared against a solo
``Solver(engine="shard_map").solve`` of the same problem.

Tolerance contract (docs/consistency.md):

  * grid engine, mesh sparse:  BIT-identical (the solo grid path is
    already vmap-batched, and ELL gather/scatter arithmetic does not
    depend on the batch size);
  * mesh DENSE with smooth-loss matvecs (d3ca, admm, radisa/squared):
    float tolerance.  Inside shard_map, XLA lowers the batched
    (T, n_p, m_q) @ (T, m_q) matvec differently for T > 1 than the
    solo T-free matvec, which reassociates the contraction (~1e-8 end
    to end).  Piecewise-linear paths (radisa/sfk hinge) and every
    sparse gather are lowering-stable, so those stay bit-identical.

All tenant lambdas keep ``lam * n`` (and ``n * sample_frac``,
``rho * n``) a power of two so the traced-scalar division of the fleet
path equals the solo path's constant-folded reciprocal exactly.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np                                   # noqa: E402

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig,  # noqa: E402
                        SFKConfig, get_solver)
from repro.data import make_svm_data                 # noqa: E402
from repro.fleet import FleetProblem, FleetSolver, solo_config  # noqa: E402

Pn, Qn = 4, 2
N, M = 64, 24
LAMS = (1.0, 0.5, 0.25)     # lam * n = 64 / 32 / 16: powers of two


def make_problems(loss):
    probs = []
    for i, lam in enumerate(LAMS):
        X, y = make_svm_data(N, M, seed=10 + i)
        probs.append(FleetProblem(tenant_id=f"t{i}", loss_name=loss,
                                  X=X, y=y, lam=lam, seed=i))
    return probs


def check(name, cfg, loss, block_format, atol):
    """One fleet batch vs three solo mesh solves; returns #failures."""
    probs = make_problems(loss)
    fleet = FleetSolver(solver=name, engine="shard_map",
                        block_format=block_format)
    batch = fleet.solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                              record_history=False)
    fails = 0
    for p, res in zip(probs, batch):
        solo = get_solver(name)(
            engine="shard_map", block_format=block_format).solve(
            loss, p.X, p.y, P=Pn, Q=Qn, cfg=solo_config(cfg, p),
            record_history=False)
        diff = float(np.max(np.abs(np.asarray(res.w, np.float32)
                                   - np.asarray(solo.w, np.float32))))
        ok = (diff == 0.0) if atol == 0.0 else (diff <= atol)
        tag = "BIT" if diff == 0.0 else f"max|dw|={diff:.3e}"
        print(f"[fleet-mesh] {name}/{loss}/{block_format}: lam={p.lam} "
              f"{tag} {'ok' if ok else 'FAIL'}")
        fails += 0 if ok else 1
        if res.alpha is not None and atol == 0.0:
            da = float(np.max(np.abs(np.asarray(res.alpha)
                                     - np.asarray(solo.alpha))))
            if da != 0.0:
                print(f"[fleet-mesh]   alpha diff {da:.3e} FAIL")
                fails += 1
    return fails


def main():
    fails = 0
    # dense d3ca/admm: batched-matvec lowering -> float tolerance
    fails += check("d3ca", D3CAConfig(local_steps=8, outer_iters=4),
                   "hinge", "dense", 1e-6)
    fails += check("admm", ADMMConfig(rho=0.5, outer_iters=4),
                   "hinge", "dense", 1e-6)
    # sparse and gemv-direction dense: bit-identical
    fails += check("d3ca", D3CAConfig(local_steps=8, outer_iters=4),
                   "hinge", "sparse", 0.0)
    fails += check("radisa", RADiSAConfig(gamma=0.125, L=8, outer_iters=4),
                   "squared", "dense", 1e-6)
    fails += check("radisa", RADiSAConfig(gamma=0.125, L=8, outer_iters=4),
                   "hinge", "dense", 0.0)
    fails += check("sfk", SFKConfig(gamma=0.125, L=8, sample_frac=0.5,
                                    outer_iters=4),
                   "hinge", "dense", 0.0)
    print(f"[fleet-mesh] total failures: {fails}")
    return fails


if __name__ == "__main__":
    raise SystemExit(main())
