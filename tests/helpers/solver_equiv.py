"""Unified solver API on 8 forced host devices.

Three modes, selected by argv[1] (default "sync"):

  * ``sync``  -- every solver must produce the same iterates under
    (engine="shard_map", local_backend="pallas") as under
    (engine="simulated", local_backend="ref"), including when P*Q does
    not divide m (both engines pad identically).  Also the regression
    check that ``make_radisa_step`` fails loudly instead of silently
    truncating feature columns when P does not divide m_q.
  * ``async`` -- the Engine API v2 staleness contract: for all three
    solvers x both block formats, engine="async" with staleness=0 must
    match engine="shard_map" to 1e-8 (it is the same program), and a
    staleness=2 run must still converge (duality gap / objective under
    a loose threshold).
  * ``compress`` -- the compressed-communication contract: for all
    three solvers x both block formats (and the pallas backend),
    compression=None and the identity codec produce bit-identical
    iterates on the mesh engines (diff 0.0); the identity accounting
    reports exactly the uncompressed bytes; compression composes with
    the async engine's staleness rings; and EF-int8 D3CA reaches the
    uncompressed duality gap within 2x the iterations.
  * ``overlap`` -- the communication-overlap contract: for all three
    solvers x both block formats (and the pallas backend),
    engine="overlap" with staleness=0 is BIT-identical (diff 0.0) to
    engine="shard_map", and at staleness=2 its trajectory equals
    engine="async" at the same tau (overlap changes wall-clock, never
    numerics).  Composition: overlap + int8 at tau=2 equals async +
    int8 at tau=2 bit for bit (EF residuals ride the dispatch step);
    wire accounting is additive (sync == async == overlap byte totals
    for the identity wire); and a hierarchical topology run
    (pods=2:int8) under overlap still converges.

Executed as a subprocess by tests/test_solver.py / test_compress.py
(the device count must be fixed before jax initializes).  Prints
max-abs diffs; exits nonzero on failure.
"""
import os
import sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, SFKConfig,
                        get_loss, get_solver, make_radisa_step,
                        objective)
from repro.data import make_svm_data

Pn, Qn = 4, 2


def main_async():
    """async engine: tau=0 == shard_map at 1e-8; tau>0 still converges."""
    lam = 1.0
    X, y = make_svm_data(120, 42, seed=1)

    fails = 0

    def check(name, a, b, tol=1e-8):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if not d <= tol:
            fails += 1

    cases = [
        ("d3ca", D3CAConfig(lam=lam, outer_iters=3, local_steps=12)),
        ("radisa", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("sfk", SFKConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=4)),
    ]
    for block_format in ("dense", "sparse"):
        for name, cfg in cases:
            sync = get_solver(name)(engine="shard_map",
                                    block_format=block_format)
            asn = get_solver(name)(engine="async", staleness=0,
                                   block_format=block_format)
            rs = sync.solve("hinge", X, y, P=Pn, Q=Qn, cfg=cfg,
                            record_history=False)
            ra = asn.solve("hinge", X, y, P=Pn, Q=Qn, cfg=cfg,
                           record_history=False)
            check(f"{name}_{block_format}_tau0_w", rs.w, ra.w)
            if rs.alpha is not None:
                check(f"{name}_{block_format}_tau0_alpha", rs.alpha, ra.alpha)

    # the pallas local backend runs inside the async cells unchanged
    cfg = D3CAConfig(lam=lam, outer_iters=3, local_steps=12)
    rs = get_solver("d3ca")(engine="shard_map",
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    ra = get_solver("d3ca")(engine="async", staleness=0,
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check("d3ca_pallas_tau0_w", rs.w, ra.w)

    # tau > 0 convergence smoke: stale reductions still close the
    # duality gap (d3ca) / reduce the objective (radisa)
    res = get_solver("d3ca")(engine="async", staleness=2).solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=D3CAConfig(lam=lam, outer_iters=12))
    gap = res.history[-1]["duality_gap"]
    print(f"d3ca_tau2_gap {gap:.3e}")
    if not gap < 0.5:
        fails += 1
    # stale gradients need a smaller step size than the sync smoke
    res = get_solver("radisa")(engine="async", staleness=2).solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=RADiSAConfig(lam=lam, gamma=0.01, outer_iters=12))
    f0 = float(objective("hinge", X, y, jnp.zeros(X.shape[1]), lam))
    f_end = res.history[-1]["objective"]
    print(f"radisa_tau2_objective {f_end:.4f} (zero-w {f0:.4f})")
    if not f_end < f0:
        fails += 1
    raise SystemExit(fails)


def main_overlap():
    """overlap engine: tau=0 == shard_map bit for bit; tau=2 == async
    at equal tau; codec composition; additive wire accounting."""
    lam = 1.0
    X, y = make_svm_data(120, 42, seed=1)

    fails = 0

    def check_zero(name, a, b):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if d != 0.0:
            fails += 1

    cases = [
        ("d3ca", D3CAConfig(lam=lam, outer_iters=3, local_steps=12)),
        ("radisa", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("sfk", SFKConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=4)),
    ]
    for block_format in ("dense", "sparse"):
        for name, cfg in cases:
            kw = dict(block_format=block_format)
            rs = get_solver(name)(engine="shard_map", **kw).solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            r0 = get_solver(name)(engine="overlap", staleness=0, **kw).solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            check_zero(f"{name}_{block_format}_tau0_w", rs.w, r0.w)
            if rs.alpha is not None:
                check_zero(f"{name}_{block_format}_tau0_alpha",
                           rs.alpha, r0.alpha)
            ra = get_solver(name)(engine="async", staleness=2, **kw).solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            ro = get_solver(name)(engine="overlap", staleness=2, **kw).solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            check_zero(f"{name}_{block_format}_tau2_w", ra.w, ro.w)
            # additive wire accounting: re-timing consumption never
            # changes what goes on the wire
            if (rs.comm_bytes["bytes_per_step"]
                    != ro.comm_bytes["bytes_per_step"]
                    or ra.comm_bytes["bytes_per_step"]
                    != ro.comm_bytes["bytes_per_step"]):
                print(f"{name}_{block_format}_bytes MISMATCH "
                      f"sync={rs.comm_bytes['bytes_per_step']} "
                      f"async={ra.comm_bytes['bytes_per_step']} "
                      f"overlap={ro.comm_bytes['bytes_per_step']}")
                fails += 1

    # the pallas local backend runs inside overlap cells unchanged
    cfg = D3CAConfig(lam=lam, outer_iters=3, local_steps=12)
    rs = get_solver("d3ca")(engine="shard_map",
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    r0 = get_solver("d3ca")(engine="overlap", staleness=0,
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check_zero("d3ca_pallas_tau0_w", rs.w, r0.w)

    # codec composition: the EF residual lives with the DISPATCH step,
    # so overlap+int8 must equal async+int8 at equal tau bit for bit
    ra = get_solver("d3ca")(engine="async", staleness=2,
                            compression="int8").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    ro = get_solver("d3ca")(engine="overlap", staleness=2,
                            compression="int8").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check_zero("d3ca_tau2_int8_w", ra.w, ro.w)

    # hierarchical topology under overlap: pods=2, int8 across pods
    # with error feedback -- still closes the duality gap
    r = get_solver("d3ca")(engine="overlap", staleness=2,
                           topology="pods=2:int8").solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=D3CAConfig(lam=lam, outer_iters=12))
    gap = r.history[-1]["duality_gap"]
    print(f"d3ca_overlap_tau2_hier_gap {gap:.3e}")
    if not gap < 0.5:
        fails += 1
    # ...and hierarchical identity matches the flat overlap run up to
    # f32 reassociation (the two-level psum reorders the sum)
    rf = get_solver("d3ca")(engine="overlap", staleness=2).solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    rh = get_solver("d3ca")(engine="overlap", staleness=2,
                            topology="pods=2").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    d = float(jnp.abs(rf.w - rh.w).max())
    print(f"d3ca_hier_identity_vs_flat_w {d:.3e}")
    if not d < 1e-5:
        fails += 1
    raise SystemExit(fails)


def main_compress():
    """compression=None == identity codec (bit for bit) on the mesh
    engines; exact identity accounting; async composition; EF-int8
    convergence within 2x iterations."""
    lam = 1.0
    X, y = make_svm_data(120, 42, seed=1)

    fails = 0

    def check_zero(name, a, b):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if d != 0.0:
            fails += 1

    cases = [
        ("d3ca", D3CAConfig(lam=lam, outer_iters=3, local_steps=12)),
        ("radisa", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("sfk", SFKConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=4)),
    ]
    for block_format in ("dense", "sparse"):
        for name, cfg in cases:
            rn = get_solver(name)(engine="shard_map",
                                  block_format=block_format).solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            ri = get_solver(name)(engine="shard_map",
                                  block_format=block_format,
                                  compression="identity").solve(
                "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
            check_zero(f"{name}_{block_format}_identity_w", rn.w, ri.w)
            if rn.alpha is not None:
                check_zero(f"{name}_{block_format}_identity_alpha",
                           rn.alpha, ri.alpha)
            # identity accounting invariant: exactly uncompressed bytes
            if (ri.comm_bytes["bytes_per_step"]
                    != rn.comm_bytes["bytes_per_step"]
                    or ri.comm_bytes["bytes_per_step"]
                    != ri.comm_bytes["uncompressed_bytes_per_step"]):
                print(f"{name}_{block_format}_identity_bytes MISMATCH "
                      f"{ri.comm_bytes}")
                fails += 1

    # the pallas local backend runs inside compressed cells unchanged
    cfg = D3CAConfig(lam=lam, outer_iters=3, local_steps=12)
    rn = get_solver("d3ca")(engine="shard_map",
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    ri = get_solver("d3ca")(engine="shard_map", local_backend="pallas",
                            compression="identity").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check_zero("d3ca_pallas_identity_w", rn.w, ri.w)

    # compression composes with the async engine's staleness rings:
    # identity + tau=2 must equal the uncompressed tau=2 run bit for bit
    ra = get_solver("d3ca")(engine="async", staleness=2).solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    rb = get_solver("d3ca")(engine="async", staleness=2,
                            compression="identity").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check_zero("d3ca_async_tau2_identity_w", ra.w, rb.w)
    # ...and a lossy codec under staleness still closes the gap
    r = get_solver("d3ca")(engine="async", staleness=2,
                           compression="int8").solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=D3CAConfig(lam=lam, outer_iters=12))
    gap = r.history[-1]["duality_gap"]
    print(f"d3ca_async_tau2_int8_gap {gap:.3e}")
    if not gap < 0.5:
        fails += 1

    # EF convergence: int8-compressed D3CA reaches the uncompressed
    # duality gap within 2x the iterations on the small SVM fixture
    T = 8
    gap_ref = get_solver("d3ca")(engine="shard_map").solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=D3CAConfig(lam=lam, outer_iters=T)
    ).history[-1]["duality_gap"]
    r8 = get_solver("d3ca")(engine="shard_map", compression="int8").solve(
        "hinge", X, y, P=Pn, Q=Qn,
        cfg=D3CAConfig(lam=lam, outer_iters=2 * T))
    gap_8 = min(h["duality_gap"] for h in r8.history)
    bytes_ratio = (r8.comm_bytes["uncompressed_bytes_per_step"]
                   / r8.comm_bytes["bytes_per_step"])
    print(f"d3ca_int8_ef_gap {gap_8:.3e} (uncompressed@{T} {gap_ref:.3e}, "
          f"bytes cut {bytes_ratio:.2f}x)")
    if not gap_8 <= gap_ref:
        fails += 1
    if not bytes_ratio >= 3.0:
        print("d3ca_int8_bytes_ratio TOO SMALL")
        fails += 1
    raise SystemExit(fails)


def main():
    lam = 1.0
    # m = 42: P*Q = 8 does not divide it -> exercises the shared padding
    X, y = make_svm_data(120, 42, seed=1)

    fails = 0

    def check(name, a, b, tol=2e-4):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if not d < tol:
            fails += 1

    cases = [
        ("d3ca", D3CAConfig(lam=lam, outer_iters=3, local_steps=12)),
        ("radisa", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("radisa_avg", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3,
                                    L=12, variant="avg")),
        ("sfk", SFKConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=4)),
    ]
    for label, cfg in cases:
        name = "radisa" if label.startswith("radisa") else label
        base = get_solver(name)(engine="simulated", local_backend="ref")
        dist = get_solver(name)(engine="shard_map", local_backend="pallas")
        rb = base.solve("hinge", X, y, P=Pn, Q=Qn, cfg=cfg,
                        record_history=False)
        rd = dist.solve("hinge", X, y, P=Pn, Q=Qn, cfg=cfg,
                        record_history=False)
        check(f"{label}_w", rb.w, rd.w)
        if rb.alpha is not None:
            check(f"{label}_alpha", rb.alpha, rd.alpha)

    # beta step mode across the engine x backend diagonal
    cfg = D3CAConfig(lam=lam, outer_iters=2, local_steps=12,
                     step_mode="beta")
    rb = get_solver("d3ca")(engine="simulated", local_backend="ref").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    rd = get_solver("d3ca")(engine="shard_map",
                            local_backend="pallas").solve(
        "hinge", X, y, P=Pn, Q=Qn, cfg=cfg, record_history=False)
    check("d3ca_beta_w", rb.w, rd.w)

    # regression: silent trailing-column drop is now a loud error
    mesh = jax.make_mesh((Pn, Qn), ("data", "model"))
    try:
        make_radisa_step(get_loss("hinge"), mesh, RADiSAConfig(lam=lam),
                         n=120, n_p=30, m_q=21)
        print("make_radisa_step_mq_check MISSING")
        fails += 1
    except ValueError as e:
        assert "sub-block" in str(e), e
        print("make_radisa_step_mq_check raises ValueError")
    # ... but variant="avg" never sub-splits, so it must still build
    make_radisa_step(get_loss("hinge"), mesh,
                     RADiSAConfig(lam=lam, variant="avg"),
                     n=120, n_p=30, m_q=21)
    print("make_radisa_step_avg_ok")

    raise SystemExit(fails)


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "sync"
    if mode == "async":
        main_async()
    elif mode == "compress":
        main_compress()
    elif mode == "overlap":
        main_overlap()
    else:
        main()
