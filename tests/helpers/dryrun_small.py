"""Lower+compile train/prefill/decode for reduced archs on a 4x2 mesh of
8 forced host devices -- the same code path as the 512-device dry-run."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax

from repro.configs import ARCHS, get_config
from repro.launch.mesh import mesh_context
from repro.launch.steps import input_specs
from repro.models import Transformer, reduced
from repro.models.config import ShapeConfig

SHAPES = [ShapeConfig("t", 64, 8, "train"),
          ShapeConfig("p", 64, 8, "prefill"),
          ShapeConfig("d", 64, 8, "decode")]


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fails = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        for shape in SHAPES:
            try:
                with mesh_context(mesh):
                    cell = input_specs(cfg, shape, mesh)
                    if cell.kind == "train":
                        args = (cell.params, cell.opt, cell.batch)
                    elif cell.kind == "prefill":
                        args = (cell.params, cell.batch)
                    else:
                        args = (cell.params, cell.cache, cell.batch)
                    jax.jit(cell.fn).lower(*args).compile()
                print(f"ok {arch} {shape.kind}")
            except Exception as e:
                fails.append((arch, shape.kind, repr(e)[:300]))
                print(f"FAIL {arch} {shape.kind}: {e!r}"[:400])
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
