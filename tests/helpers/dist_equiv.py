"""Run shard_map engines on 8 forced host devices and compare to simulated.

Executed as a subprocess by tests (device count must be set before jax init).
Prints max-abs diffs as `name diff` lines; exits nonzero on failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import *
from repro.data import make_svm_data

def main():
    P_, Q_ = 4, 2
    X, y = make_svm_data(400, 120, seed=1)
    lam = 1.0
    data = partition(X, y, P=P_, Q=Q_)
    mesh = jax.make_mesh((P_, Q_), ("data", "model"))

    Xd, yd = np.asarray(data.dense()[0]), np.asarray(data.dense()[1])
    n_pad, m_pad = P_ * data.n_p, Q_ * data.m_q
    Xp = np.zeros((n_pad, m_pad), np.float32); Xp[:400, :120] = Xd
    yp = np.zeros((n_pad,), np.float32); yp[:400] = yd
    maskp = np.zeros((n_pad,), np.float32); maskp[:400] = 1.0
    Xp, yp, maskp = jnp.array(Xp), jnp.array(yp), jnp.array(maskp)

    fails = 0
    def check(name, a, b, tol=2e-4):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if not d < tol:
            fails += 1

    cfg = D3CAConfig(lam=lam, outer_iters=3)
    w_sim, a_sim = d3ca_simulated("hinge", data, cfg)
    w_dist, a_dist = d3ca_distributed("hinge", mesh, Xp, yp, maskp, cfg)
    check("d3ca_w", w_sim, w_dist[:120]); check("d3ca_alpha", a_sim, a_dist[:400])

    rcfg = RADiSAConfig(lam=lam, gamma=0.02, outer_iters=3)
    check("radisa_w", radisa_simulated("hinge", data, rcfg),
          radisa_distributed("hinge", mesh, Xp, yp, maskp, rcfg)[:120])

    rcfg = RADiSAConfig(lam=lam, gamma=0.02, outer_iters=3, variant="avg")
    check("radisa_avg_w", radisa_simulated("hinge", data, rcfg),
          radisa_distributed("hinge", mesh, Xp, yp, maskp, rcfg)[:120])

    acfg = ADMMConfig(lam=lam, rho=lam, outer_iters=5)
    check("admm_w", admm_simulated("hinge", data, acfg),
          admm_distributed("hinge", mesh, Xp, yp, maskp, acfg)[:120])

    # multi-pod: the same P=4 observation split expressed as a collapsed
    # ("pod","data") tuple axis on a (2,2,2) mesh must reproduce the flat
    # (4,2) mesh result bit-for-bit (same grid, same fold_in indices)
    from jax.sharding import NamedSharding, PartitionSpec as SP
    from repro.core.losses import get_loss
    from repro.core.d3ca import make_d3ca_step
    from repro.core.radisa import make_radisa_step
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    daxes = ("pod", "data")
    loss = get_loss("hinge")
    key0 = jax.random.PRNGKey(0)

    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh3, spec))

    x3 = put(Xp, SP(daxes, "model"))
    y3, m3 = put(yp, SP(daxes)), put(maskp, SP(daxes))

    cfg = D3CAConfig(lam=lam, outer_iters=3)
    step2 = make_d3ca_step(loss, mesh, cfg, n=n_pad, n_p=data.n_p)
    step3 = make_d3ca_step(loss, mesh3, cfg, n=n_pad, n_p=data.n_p,
                           data_axis=daxes)
    a2, w2 = jnp.zeros((n_pad,)), jnp.zeros((m_pad,))
    a3 = put(jnp.zeros((n_pad,)), SP(daxes))
    w3 = put(jnp.zeros((m_pad,)), SP("model"))
    for t in range(1, 4):
        a2, w2 = step2(t, key0, Xp, yp, maskp, a2, w2)
        a3, w3 = step3(t, key0, x3, y3, m3, a3, w3)
    check("d3ca_multipod_w", w2, w3, tol=1e-6)
    check("d3ca_multipod_alpha", a2, a3, tol=1e-6)

    rcfg = RADiSAConfig(lam=lam, gamma=0.02, outer_iters=3)
    rstep2 = make_radisa_step(loss, mesh, rcfg, n=n_pad, n_p=data.n_p,
                              m_q=data.m_q)
    rstep3 = make_radisa_step(loss, mesh3, rcfg, n=n_pad, n_p=data.n_p,
                              m_q=data.m_q, data_axis=daxes)
    rw2 = jnp.zeros((m_pad,))
    rw3 = put(jnp.zeros((m_pad,)), SP("model"))
    for t in range(1, 4):
        rw2 = rstep2(t, key0, Xp, yp, maskp, rw2)
        rw3 = rstep3(t, key0, x3, y3, m3, rw3)
    check("radisa_multipod_w", rw2, rw3, tol=1e-6)

    raise SystemExit(fails)

if __name__ == "__main__":
    main()
