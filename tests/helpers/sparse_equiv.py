"""Sparse block format on 8 forced host devices: every solver under
(engine="shard_map", block_format="sparse") must match
(engine="simulated", block_format="dense", local_backend="ref") on the
same instance -- including a non-dividing m (P*Q padding) and an
all-zero feature-block column -- for both local backends, from a
CSRMatrix input that is never densified on the solve path.

Also asserts the device-side ELL buffers scale with nnz, not m_q.

Executed as a subprocess by tests/test_sparse.py (the device count must
be fixed before jax initializes).  Prints max-abs diffs; exits nonzero
on failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, get_solver,
                        prepare_shard_map_sparse)
from repro.data import csr_from_dense, make_sparse_svm_data


def main():
    Pn, Qn = 4, 2
    lam = 1.0
    # m = 41: P*Q = 8 does not divide it -> padded to 48, m_q = 24.
    # Zeroing columns 24+ makes feature block q=1 entirely zero.
    X, y = make_sparse_svm_data(120, 41, density=0.15, seed=7)
    X[:, 24:] = 0.0
    Xcsr = csr_from_dense(X)

    fails = 0

    def check(name, a, b, tol=2e-4):
        nonlocal fails
        d = float(jnp.abs(a - b).max())
        print(f"{name} {d:.3e}")
        if not d < tol:
            fails += 1

    cases = [
        ("d3ca", D3CAConfig(lam=lam, outer_iters=3, local_steps=12)),
        ("d3ca_beta", D3CAConfig(lam=lam, outer_iters=2, local_steps=12,
                                 step_mode="beta")),
        ("radisa", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3, L=12)),
        ("radisa_avg", RADiSAConfig(lam=lam, gamma=0.03, outer_iters=3,
                                    L=12, variant="avg")),
        ("admm", ADMMConfig(lam=lam, rho=lam, outer_iters=4)),
    ]
    for label, cfg in cases:
        name = label.split("_")[0]
        base = get_solver(name)(engine="simulated", local_backend="ref")
        rb = base.solve("hinge", X, y, P=Pn, Q=Qn, cfg=cfg,
                        record_history=False)
        backends = ("ref",) if name == "admm" else ("ref", "pallas")
        for backend in backends:
            dist = get_solver(name)(engine="shard_map",
                                    local_backend=backend,
                                    block_format="sparse")
            rd = dist.solve("hinge", Xcsr, y, P=Pn, Q=Qn, cfg=cfg,
                            record_history=False)
            check(f"{label}_{backend}_w", rb.w, rd.w)
            if rb.alpha is not None:
                check(f"{label}_{backend}_alpha", rb.alpha, rd.alpha)

    # device buffers are ELL-sized: k ~ max row nnz, nowhere near m_q
    mesh = jax.make_mesh((Pn, Qn), ("data", "model"))
    sdata = prepare_shard_map_sparse(mesh, Xcsr, y, m_multiple=Pn * Qn)
    print(f"ell k={sdata.k} m_q={sdata.m_q} "
          f"cols={sdata.cols.shape} vals={sdata.vals.shape}")
    assert sdata.cols.shape == (sdata.n_pad, Qn * sdata.k)
    if not sdata.k < sdata.m_q:
        print("ELL width k does not beat m_q")
        fails += 1

    raise SystemExit(fails)


if __name__ == "__main__":
    main()
