"""Sharding rules: divisibility fallbacks, axis reuse, spec trees."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import logical_to_spec, spec_tree


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})
MESH1 = FakeMesh({"data": 16, "model": 16})


def test_basic_rules():
    assert logical_to_spec((4096, 24576), ("fsdp", "ff"), MESH1) == \
        P("data", "model")
    assert logical_to_spec((49152, 6144), ("vocab", "fsdp"), MESH1) == \
        P("model", "data")


def test_divisibility_fallback():
    # 8 experts on a 16-way model axis -> replicate experts
    spec = logical_to_spec((8, 4096, 14336), ("experts", "fsdp", "ff"), MESH1)
    assert spec == P(None, "data", "model")
    # 64 experts divide -> expert parallelism; ff falls back (axis used)
    spec = logical_to_spec((64, 2048, 1408), ("experts", "fsdp", "ff"), MESH1)
    assert spec == P("model", "data", None)


def test_multi_axis_fsdp_prefix():
    # pod*data = 32 divides 2048 -> both axes used
    assert logical_to_spec((2048,), ("fsdp",), MESH) == P(("pod", "data"))
    # 48 % 2 == 0 but 48 % 32 != 0 -> only the pod prefix
    assert logical_to_spec((48,), ("fsdp",), MESH) == P("pod")
    # odd dim -> no axis
    assert logical_to_spec((47,), ("fsdp",), MESH) == P(None)


def test_axis_never_reused():
    spec = logical_to_spec((16, 16), ("heads", "kv_heads"), MESH1)
    assert spec == P("model", None)


def test_spec_tree_parallel_structure():
    params = {"a": jnp.zeros((32, 64)), "b": [jnp.zeros((16,))]}
    logical = {"a": ("fsdp", "ff"), "b": [("heads",)]}
    tree = spec_tree(logical, params, MESH1)
    assert tree["a"] == P("data", "model")
    assert tree["b"][0] == P("model")
