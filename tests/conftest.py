import os
import sys

# tests run on ONE device (the dry-run sets its own 512-device flag in a
# subprocess); make sure src/ is importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
