import os
import sys

# tests run on ONE device (the dry-run sets its own 512-device flag in a
# subprocess); make sure src/ is importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # CI splits tier1 into a matrix over the engines/policies:
    #   -m "not shard_map and not async_engine and not compression and
    #       not overlap"
    #                       -> everything single-device (simulated split)
    #   -m shard_map        -> the subprocess suites that force a device
    #                          grid (shard_map split)
    #   -m async_engine     -> the bounded-staleness engine's subprocess
    #                          suites (async split)
    #   -m compression      -> the compressed-reduction subprocess suites
    #                          (compression split)
    #   -m overlap          -> the communication-overlap engine's
    #                          subprocess suites (overlap split)
    config.addinivalue_line(
        "markers",
        "shard_map: exercises the shard_map engine in a subprocess with a "
        "forced multi-device grid (CI runs these in their own matrix leg)")
    config.addinivalue_line(
        "markers",
        "async_engine: exercises the bounded-staleness async engine in a "
        "subprocess with a forced multi-device grid (own CI matrix leg)")
    config.addinivalue_line(
        "markers",
        "compression: exercises compressed reductions on the mesh engines "
        "in a subprocess with a forced multi-device grid (own CI matrix "
        "leg)")
    config.addinivalue_line(
        "markers",
        "obs: telemetry-subsystem integration tests that run real solves "
        "under a tracer/registry (own CI matrix leg; the pure tracer/"
        "registry unit tests stay in the simulated split)")
    config.addinivalue_line(
        "markers",
        "overlap: exercises the communication-overlap engine in a "
        "subprocess with a forced multi-device grid (own CI matrix leg)")
    config.addinivalue_line(
        "markers",
        "online: online-service integration tests that run real "
        "warm-started incremental solves (own CI matrix leg; the pure "
        "queue/store/snapshot unit tests stay in the simulated split)")
    config.addinivalue_line(
        "markers",
        "fleet: multi-tenant batched-solve integration tests that run "
        "real fleet-vs-solo equivalence solves (own CI matrix leg; the "
        "pure packing/bucketing unit tests stay in the simulated split)")
