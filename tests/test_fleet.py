"""Multi-tenant fleet subsystem: packing invariants, per-tenant
bit-equivalence with solo solves, converged-tenant freezing, warm-start
chains, and the scheduler's bucketing/warm-registry behavior.

Equivalence tests keep ``lam * n`` (and ``n * sample_frac``,
``rho * n``) powers of two: XLA strength-reduces division by a
compile-time constant into reciprocal multiplication, which is exact
only for power-of-two divisors.  The solo path bakes those products as
constants while the fleet path divides by traced per-tenant scalars,
so bit-equality holds exactly on that lattice and to float tolerance
off it (see ``test_non_pow2_products_match_to_float_tol``)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, SFKConfig,
                        get_solver)
from repro.data import make_svm_data
from repro.fleet import (FleetProblem, FleetScheduler, FleetSolver,
                         bucket_key, solo_config, stack_grid, with_tenant)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

Pn, Qn = 2, 2
N, M = 64, 24
LAMS = (1.0, 0.5, 0.25)    # lam * n = 64 / 32 / 16


def make_problems(loss, n=N, m=M, lams=LAMS, f_stars=None):
    probs = []
    for i, lam in enumerate(lams):
        X, y = make_svm_data(n, m, seed=10 + i)
        probs.append(FleetProblem(
            tenant_id=f"t{i}", loss_name=loss, X=X, y=y, lam=lam, seed=i,
            f_star=None if f_stars is None else f_stars[i]))
    return probs


def solo_solve(name, p, cfg, *, engine="simulated", local_backend="ref",
               block_format="dense", **kw):
    s = get_solver(name)(engine=engine, local_backend=local_backend,
                         block_format=block_format)
    return s.solve(p.loss_name, p.X, p.y, P=Pn, Q=Qn,
                   cfg=solo_config(cfg, p), record_history=False, **kw)


# ---------------------------------------------------------------------------
# constructor validation / engine restriction
# ---------------------------------------------------------------------------

def test_fleet_knob_validation():
    with pytest.raises(ValueError, match="solver"):
        FleetSolver(solver="sgd")
    with pytest.raises(ValueError, match="engine"):
        FleetSolver(engine="async")
    with pytest.raises(ValueError, match="engine"):
        FleetSolver(engine="overlap")
    with pytest.raises(ValueError, match="staleness"):
        FleetSolver(engine="shard_map", staleness=2)
    with pytest.raises(ValueError, match="compression"):
        FleetSolver(compression="int8")
    with pytest.raises(ValueError, match="local_backend"):
        FleetSolver(local_backend="triton")
    with pytest.raises(ValueError, match="block_format"):
        FleetSolver(block_format="csr")
    # "sync" aliases the shard_map mesh, as in the solo registry
    assert FleetSolver(engine="sync").engine == "shard_map"


def test_solve_batch_rejects_mixed_buckets():
    a = make_problems("hinge", n=64, m=24, lams=(1.0,))
    b = make_problems("hinge", n=96, m=24, lams=(1.0,))
    with pytest.raises(ValueError, match="bucket"):
        FleetSolver().solve_batch(a + b, P=Pn, Q=Qn,
                                  cfg=D3CAConfig(outer_iters=1))


# ---------------------------------------------------------------------------
# packing invariants (pure unit tests: stay in the simulated split)
# ---------------------------------------------------------------------------

def test_bucket_key_uses_padded_shapes():
    # rows pad to a multiple of P, features to a multiple of P*Q: shapes
    # that pad equal are one bucket even when the raw shapes differ
    a = make_problems("hinge", n=63, m=22, lams=(1.0,))[0]
    b = make_problems("hinge", n=64, m=24, lams=(1.0,))[0]
    assert bucket_key(a, Pn, Qn) == bucket_key(b, Pn, Qn) \
        == ("hinge", 64, 24)
    c = make_problems("squared", n=64, m=24, lams=(1.0,))[0]
    assert bucket_key(c, Pn, Qn) != bucket_key(b, Pn, Qn)


def test_with_tenant_and_stack_grid_axis_rule():
    # the tenant axis lands right after the named block axes
    assert with_tenant((("data", "model"),)) == ((None, "data", "model"),)
    assert with_tenant(("model",)) == (None, "model")
    arrs = [np.full((3, 2, 4, 5), i, np.float32) for i in range(2)]
    assert stack_grid(arrs, ("data", "model")).shape == (3, 2, 2, 4, 5)
    ys = [np.zeros((3, 4), np.float32) for _ in range(2)]
    assert stack_grid(ys, ("data",)).shape == (3, 2, 4)
    ks = [np.zeros((2,), np.float32) for _ in range(2)]
    assert stack_grid(ks, ()).shape == (2, 2)


def test_repad_k_pads_zero_slots():
    from repro.core.partition import partition_sparse
    X, y = make_svm_data(16, 8, seed=0)
    part = partition_sparse(np.asarray(X) * (np.asarray(X) > 0), y, 2, 2,
                            m_multiple=4)
    bigger = FleetSolver._repad_k(part, part.k + 8)
    assert bigger.k == part.k + 8
    np.testing.assert_array_equal(np.asarray(bigger.cols[..., part.k:]), 0)
    np.testing.assert_array_equal(np.asarray(bigger.vals[..., part.k:]), 0.0)
    np.testing.assert_array_equal(np.asarray(bigger.vals[..., : part.k]),
                                  np.asarray(part.vals))


# ---------------------------------------------------------------------------
# grid engine: per-tenant results bit-match solo solves
# ---------------------------------------------------------------------------

GRID_CASES = [
    ("d3ca", D3CAConfig(local_steps=8, outer_iters=3), "hinge",
     "dense", "ref"),
    ("d3ca", D3CAConfig(local_steps=8, outer_iters=3), "logistic",
     "dense", "ref"),
    ("d3ca", D3CAConfig(local_steps=8, outer_iters=3), "hinge",
     "sparse", "ref"),
    ("d3ca", D3CAConfig(local_steps=8, outer_iters=3), "hinge",
     "dense", "pallas"),
    ("radisa", RADiSAConfig(gamma=0.125, L=8, outer_iters=3), "squared",
     "dense", "ref"),
    ("radisa", RADiSAConfig(gamma=0.125, L=8, outer_iters=3), "hinge",
     "sparse", "ref"),
    ("radisa", RADiSAConfig(gamma=0.125, L=8, outer_iters=3), "hinge",
     "dense", "pallas"),
    ("sfk", SFKConfig(gamma=0.125, L=8, sample_frac=0.5, outer_iters=3),
     "hinge", "dense", "ref"),
    ("admm", ADMMConfig(rho=0.5, outer_iters=3), "hinge", "dense", "ref"),
    ("admm", ADMMConfig(rho=0.5, outer_iters=3), "hinge", "sparse",
     "ref"),
]


@pytest.mark.fleet
@pytest.mark.parametrize(
    "name,cfg,loss,block_format,backend", GRID_CASES,
    ids=[f"{c[0]}-{c[2]}-{c[3]}-{c[4]}" for c in GRID_CASES])
def test_grid_fleet_bitmatches_solo(name, cfg, loss, block_format, backend):
    probs = make_problems(loss)
    fleet = FleetSolver(solver=name, local_backend=backend,
                        block_format=block_format)
    batch = fleet.solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                              record_history=False)
    for p, res in zip(probs, batch):
        solo = solo_solve(name, p, cfg, local_backend=backend,
                          block_format=block_format)
        np.testing.assert_array_equal(np.asarray(res.w),
                                      np.asarray(solo.w))
        if res.alpha is not None:
            np.testing.assert_array_equal(np.asarray(res.alpha),
                                          np.asarray(solo.alpha))
        assert (res.solver, res.engine, res.block_format) == \
            (name, "simulated", block_format)


@pytest.mark.fleet
def test_non_pow2_products_match_to_float_tol():
    """Off the power-of-two lattice the solo path's constant-folded
    reciprocal differs from the fleet path's traced division in the
    last bit; results agree to float tolerance.  Two instances: a
    non-pow2 ``lam * n`` (= 48), and admm's squared prox, whose
    ``1 + 2c`` denominator (1.125) is never a power of two."""
    probs = make_problems("hinge", n=96, lams=(0.5,))
    cfg = D3CAConfig(local_steps=8, outer_iters=3)
    res = FleetSolver().solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                                    record_history=False)[0]
    solo = solo_solve("d3ca", probs[0], cfg)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(solo.w),
                               rtol=0, atol=1e-6)

    probs = make_problems("squared", lams=(0.5,))
    cfg = ADMMConfig(rho=0.5, outer_iters=3)
    res = FleetSolver(solver="admm").solve_batch(
        probs, P=Pn, Q=Qn, cfg=cfg, record_history=False)[0]
    solo = solo_solve("admm", probs[0], cfg)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(solo.w),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# convergence freezing + warm starts
# ---------------------------------------------------------------------------

@pytest.mark.fleet
def test_frozen_tenant_state_is_exact():
    """A tenant frozen at iteration k bit-equals a solo solve truncated
    at k outer iterations -- jnp.where carries its state untouched."""
    from repro.core import objective, serial_sdca
    probs = make_problems("hinge")
    f_stars = []
    for p in probs:
        w_ref, _ = serial_sdca("hinge", p.X, p.y, lam=p.lam, epochs=200)
        f_stars.append(float(objective("hinge", p.X, p.y, w_ref, p.lam)))
    probs = [FleetProblem(tenant_id=p.tenant_id, loss_name=p.loss_name,
                          X=p.X, y=p.y, lam=p.lam, seed=p.seed,
                          f_star=f_stars[i]) for i, p in enumerate(probs)]
    cfg = D3CAConfig(local_steps=16, outer_iters=30)
    batch = FleetSolver().solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                                      tol=0.05, check_every=2)
    assert any(r.converged for r in batch)
    iters = {r.iters for r in batch}
    for p, res in zip(probs, batch):
        if not res.converged:
            continue
        solo = solo_solve(
            "d3ca", p, D3CAConfig(local_steps=16, outer_iters=res.iters))
        np.testing.assert_array_equal(np.asarray(res.w),
                                      np.asarray(solo.w))
        assert res.history[-1]["rel_opt"] < 0.05
    # tenants froze at different segment boundaries (the mask matters)
    assert len(iters) > 1 or not all(r.converged for r in batch)


@pytest.mark.fleet
def test_warm_start_chain_bitmatches_solo_chain():
    probs = make_problems("hinge")
    cfg = D3CAConfig(local_steps=8, outer_iters=3)
    fleet = FleetSolver()
    first = fleet.solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                              record_history=False)
    second = fleet.solve_batch(probs, P=Pn, Q=Qn, cfg=cfg,
                               warm_starts=first, record_history=False)
    for p, res in zip(probs, second):
        s1 = solo_solve("d3ca", p, cfg)
        s2 = solo_solve("d3ca", p, cfg, warm_start=s1)
        np.testing.assert_array_equal(np.asarray(res.w), np.asarray(s2.w))
        np.testing.assert_array_equal(np.asarray(res.alpha),
                                      np.asarray(s2.alpha))


# ---------------------------------------------------------------------------
# scheduler: bucketing, chunking, warm registry, callbacks
# ---------------------------------------------------------------------------

@pytest.mark.fleet
def test_scheduler_buckets_and_matches_solo():
    cfg = D3CAConfig(local_steps=8, outer_iters=3)
    small = make_problems("hinge", n=64, m=24)
    big = make_problems("hinge", n=128, m=24, lams=(0.5, 0.25))
    big = [FleetProblem(tenant_id=f"big{i}", loss_name=p.loss_name,
                        X=p.X, y=p.y, lam=p.lam, seed=p.seed)
           for i, p in enumerate(big)]
    sched = FleetScheduler(P=Pn, Q=Qn, solver="d3ca", cfg=cfg)
    for p in small + big:
        sched.submit(p)
    assert sched.pending() == 5
    assert len(sched.buckets()) == 2
    results = sched.run()
    assert sched.pending() == 0
    assert list(results) == [p.tenant_id for p in small + big]
    for p in small + big:
        solo = solo_solve("d3ca", p, cfg)
        np.testing.assert_array_equal(np.asarray(results[p.tenant_id].w),
                                      np.asarray(solo.w))


@pytest.mark.fleet
def test_scheduler_chunking_and_warm_registry():
    cfg = D3CAConfig(local_steps=8, outer_iters=3)
    probs = make_problems("hinge")
    seen = []
    sched = FleetScheduler(P=Pn, Q=Qn, solver="d3ca", cfg=cfg,
                           max_tenants=2,
                           on_result=lambda tid, res: seen.append(tid))
    for p in probs:
        sched.submit(p)
    first = sched.run()
    assert seen == [p.tenant_id for p in probs]
    # round 2 warm-starts every tenant from its round-1 result
    for p in probs:
        sched.submit(p)
    second = sched.run()
    for p in probs:
        assert sched.warm_start_of(p.tenant_id) is not None
        s1 = solo_solve("d3ca", p, cfg)
        np.testing.assert_array_equal(np.asarray(first[p.tenant_id].w),
                                      np.asarray(s1.w))
        s2 = solo_solve("d3ca", p, cfg, warm_start=s1)
        np.testing.assert_array_equal(np.asarray(second[p.tenant_id].w),
                                      np.asarray(s2.w))


def test_fleet_obs_hooks():
    from repro.obs import Registry, Tracer
    tr, reg = Tracer(), Registry()
    probs = make_problems("hinge", lams=(1.0, 0.5))
    sched = FleetScheduler(P=Pn, Q=Qn, solver="d3ca",
                           cfg=D3CAConfig(local_steps=4, outer_iters=2),
                           tracer=tr, registry=reg)
    for p in probs:
        sched.submit(p)
    sched.run()
    names = {s["name"] for s in tr.spans()}
    assert {"fleet/pack", "fleet/step", "fleet/unpack"} <= names
    gauges = reg.snapshot()["gauges"]
    for want in ("fleet/bucket_tenants", "fleet/tenants", "fleet/active"):
        assert any(k.startswith(want) for k in gauges), (want, gauges)


# ---------------------------------------------------------------------------
# shard_map mesh (subprocess: forced 4 x 2 device grid)
# ---------------------------------------------------------------------------

@pytest.mark.fleet
@pytest.mark.shard_map
def test_mesh_fleet_matches_solo():
    """Per-tenant fleet-vs-solo equivalence on the shard_map mesh: bit
    for sparse and hinge-path dense, <= 1e-6 for the dense smooth-loss
    matvec cases (see helpers/fleet_equiv.py)."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "fleet_equiv.py")],
        env=ENV, timeout=600, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
