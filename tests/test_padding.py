"""Padding inertness: masked (padded) rows contribute EXACTLY zero to
the objective, the dual, and every SDCA coordinate update -- the
invariant the row-padded block layout (and the fleet subsystem's shape
buckets) rely on.

These live outside test_losses.py because that module's
hypothesis-based property tests skip wholesale when hypothesis is
absent; the padding guarantees must be asserted unconditionally."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.local import local_sdca
from repro.core.losses import get_loss

LOSSES = ["hinge", "squared", "logistic"]


def _padded_problem(n=24, m=8, pad=5, fill=0.0):
    rng = np.random.default_rng(3)      # same draw for every fill value
    X = rng.standard_normal((n, m)).astype(np.float32)
    y = np.where(rng.standard_normal(n) > 0, 1.0, -1.0).astype(np.float32)
    alpha = (0.5 * y).astype(np.float32)   # dual-feasible for all 3 losses
    Xp = np.concatenate([X, np.full((pad, m), fill, np.float32)])
    yp = np.concatenate([y, np.zeros(pad, np.float32)])
    ap = np.concatenate([alpha, np.zeros(pad, np.float32)])
    mask = np.concatenate([np.ones(n), np.zeros(pad)]).astype(np.float32)
    return X, y, alpha, Xp, yp, ap, mask


@pytest.mark.parametrize("loss_name", LOSSES)
def test_padded_rows_inert_in_objectives(loss_name):
    """objective/dual over masked padded arrays == unpadded, bit for bit,
    regardless of what the padded rows contain."""
    loss = get_loss(loss_name)
    n = 24
    X, y, alpha, *_ = _padded_problem(n=n)
    w = np.random.default_rng(5).standard_normal(X.shape[1]).astype(
        np.float32)
    lam = 0.5
    f = float(loss.objective(jnp.asarray(X), jnp.asarray(y),
                             jnp.asarray(w), lam))
    d = float(loss.dual_objective(jnp.asarray(X), jnp.asarray(y),
                                  jnp.asarray(alpha), lam))
    # zero fill AND garbage fill: the mask, not the fill value, is load-
    # bearing (garbage X rows ride y = 0 + alpha = 0 exactly like padding)
    for fill in (0.0, 37.5):
        _, _, _, Xp, yp, ap, mask = _padded_problem(n=n, fill=fill)
        fp = float(loss.objective(jnp.asarray(Xp), jnp.asarray(yp),
                                  jnp.asarray(w), lam,
                                  mask=jnp.asarray(mask), n=n))
        dp = float(loss.dual_objective(jnp.asarray(Xp), jnp.asarray(yp),
                                       jnp.asarray(ap), lam,
                                       mask=jnp.asarray(mask), n=n))
        assert f == fp, (loss_name, fill, f, fp)
        assert d == dp, (loss_name, fill, d, dp)


@pytest.mark.parametrize("loss_name", LOSSES)
def test_padded_rows_finite_grad_and_delta(loss_name):
    """Padded rows carry y = 0; value/grad/sdca_delta must stay finite
    there (a padded row's contribution is then x_i * (finite) = 0, and
    the logistic Newton solve must not poison the lanes it shares with
    real rows -- the safe_y guard)."""
    loss = get_loss(loss_name)
    zs = jnp.linspace(-4.0, 4.0, 17)
    y0 = jnp.zeros_like(zs)
    assert bool(jnp.all(jnp.isfinite(loss.value(zs, y0))))
    assert bool(jnp.all(jnp.isfinite(loss.grad(zs, y0))))
    d = jax.vmap(lambda z: loss.sdca_delta(
        jnp.float32(0.0), jnp.float32(0.0), z, jnp.float32(0.0),
        0.5, 24, 2))(zs)
    assert bool(jnp.all(jnp.isfinite(d)))


@pytest.mark.parametrize("loss_name", LOSSES)
def test_padded_rows_never_move_in_local_sdca(loss_name):
    """One local SDCA epoch over a block with garbage padded rows: the
    padded coordinates' dual change is exactly zero (local_sdca gates
    the delta with the row mask before it touches w or alpha)."""
    loss = get_loss(loss_name)
    n = 24
    _, _, alpha, Xp, yp, ap, mask = _padded_problem(n=n, fill=3.25)
    # nonzero w0: alpha = 0.5 y with w = 0 is exactly stationary for
    # logistic (t = 1/2, zloc = 0), which would hide real-row movement
    w0 = jnp.asarray(np.random.default_rng(5).standard_normal(
        Xp.shape[1]).astype(np.float32))
    dalpha = local_sdca(loss, jnp.asarray(Xp), jnp.asarray(yp),
                        jnp.asarray(mask), jnp.asarray(ap), w0,
                        lam=0.5, n=n, Q=2, steps=4 * Xp.shape[0],
                        key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(dalpha)[n:], 0.0)
    # and the real rows did move (the epoch was not a no-op)
    assert float(np.abs(np.asarray(dalpha)[:n]).sum()) > 0.0
