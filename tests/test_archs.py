"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward + one full train step (AdamW) with
shape/finiteness asserts, plus a prefill->decode consistency check against
the full forward in float32 (exact to ~1e-4 logprob).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Transformer, reduced
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, with_labels=True, key=KEY):
    b = {}
    if cfg.embed_input == "tokens":
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        b["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), cfg.cdtype)
    if with_labels:
        b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.encoder_len:
        b["encoder"] = jax.random.normal(key, (B, cfg.encoder_len,
                                               cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    opt = adamw_init(params)
    batch = _batch(cfg, B=2, S=32)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        params, opt, gn = adamw_update(AdamWConfig(lr=1e-3), grads, opt,
                                       params)
        return params, opt, loss, gn

    params2, opt2, loss, gn = step(params, opt, batch)
    assert jnp.isfinite(loss) and jnp.isfinite(gn)
    assert float(gn) > 0
    # params actually moved, shapes preserved
    moved = jax.tree.map(lambda a, b: (a.shape == b.shape,
                                       bool(jnp.any(a != b))), params, params2)
    shapes_ok, any_moved = zip(*jax.tree.leaves(moved,
                                                is_leaf=lambda x: isinstance(
                                                    x, tuple)))
    assert all(shapes_ok) and any(any_moved)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward_fp32(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    B, S, nd = 2, 16, 3
    total = S + nd
    full = _batch(cfg, B, total, with_labels=False)
    pre = {k: (v[:, :S] if k in ("tokens", "embeds") else v)
           for k, v in full.items()}

    full_logits = model.logits_fn(params, full)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, total))(params,
                                                                     pre)
    lp = jax.nn.log_softmax
    errs = [float(jnp.abs(lp(logits[:, 0]) - lp(full_logits[:, S - 1])).max())]
    step = jax.jit(model.decode_step)
    for i in range(nd - 1):
        tok = {k: v[:, S + i:S + i + 1] for k, v in full.items()
               if k in ("tokens", "embeds")}
        if cfg.encoder_len:
            tok["encoder"] = full["encoder"]
        logits, cache = step(params, cache, tok)
        errs.append(float(jnp.abs(lp(logits[:, 0])
                                  - lp(full_logits[:, S + i])).max()))
    assert max(errs) < 1e-3, errs


def test_swa_ring_buffer_decode():
    """Mixtral-family SWA: decoding past the window uses a ring buffer."""
    cfg = dataclasses.replace(reduced(get_config("mixtral_8x7b")),
                              compute_dtype="float32", swa_window=8)
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    B, S, nd = 1, 12, 6   # cross the window boundary while decoding
    total = S + nd
    full = _batch(cfg, B, total, with_labels=False)
    pre = {"tokens": full["tokens"][:, :S]}
    full_logits = model.logits_fn(params, full)
    logits, cache = model.prefill(params, pre, total)
    step = jax.jit(model.decode_step)
    errs = []
    lp = jax.nn.log_softmax
    errs.append(float(jnp.abs(lp(logits[:, 0])
                              - lp(full_logits[:, S - 1])).max()))
    for i in range(nd - 1):
        tok = {"tokens": full["tokens"][:, S + i:S + i + 1]}
        logits, cache = step(params, cache, tok)
        errs.append(float(jnp.abs(lp(logits[:, 0])
                                  - lp(full_logits[:, S + i])).max()))
    assert max(errs) < 1e-3, errs


def test_long_context_flags():
    assert not get_config("granite_20b").sub_quadratic
    assert get_config("rwkv6_3b").sub_quadratic
    assert get_config("recurrentgemma_9b").sub_quadratic
    assert get_config("mixtral_8x7b").sub_quadratic   # SWA window


def test_full_configs_match_assignment():
    """Pin the exact published numbers for all 10 archs."""
    expect = {
        "granite_20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "llama32_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, dm, H, KV, dff, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff,
                c.vocab) == (L, dm, H, KV, dff, V), arch
    assert get_config("mixtral_8x7b").moe.n_experts == 8
    assert get_config("mixtral_8x7b").moe.top_k == 2
    assert get_config("moonshot_v1_16b_a3b").moe.n_experts == 64
    assert get_config("moonshot_v1_16b_a3b").moe.top_k == 6
