"""Worked observability example: trace a D3CA solve, attribute its
wall-clock to local-solve / communication / host phases, and export a
Chrome-trace you can open in https://ui.perfetto.dev.

    PYTHONPATH=src python examples/trace_solve.py [--out trace.json]

What it shows:

  * ``Tracer`` spans around the whole solve (data prep, every outer
    iteration, the synthesized per-collective spans named after the
    solver's declared ``CommSchedule`` collectives);
  * a ``Registry`` collecting the same run as counters / gauges /
    histograms -- the one snapshot schema the BENCH emitters embed;
  * the per-iteration ``step_s`` / ``local_s`` / ``comm_s`` / ``host_s``
    fields that telemetry adds to ``SolveResult.history``.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.json",
                    help="Chrome-trace JSON path (a .jsonl raw-event "
                         "log is written next to it)")
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()

    from repro.core import D3CAConfig, get_solver
    from repro.data import make_svm_data
    from repro.obs import Registry, Tracer

    X, y = make_svm_data(800, 200, seed=0)
    cfg = D3CAConfig(lam=1e-1, outer_iters=args.iters, local_steps=64)
    solver = get_solver("d3ca")(engine="simulated")

    tracer, reg = Tracer(), Registry()
    res = solver.solve("hinge", X, y, P=2, Q=2, cfg=cfg,
                       tracer=tracer, registry=reg)

    # 1. the per-phase fields telemetry added to the solve history
    print("per-iteration phase attribution:")
    for h in res.history:
        print(f"  t={h['iter']:3d}  step {h['step_s'] * 1e3:7.3f} ms"
              f"  = local {h['local_s'] * 1e3:7.3f}"
              f"  + comm {h['comm_s'] * 1e3:7.3f}"
              f"  (obs host {h['host_s'] * 1e3:7.3f} ms)"
              f"   f={h['objective']:.6f}")

    # 2. span totals straight off the tracer
    solve_s = tracer.total("solve")
    print(f"\nspan totals over {solve_s * 1e3:.1f} ms of solve:")
    for name in ("data_prep", "calibrate", "outer_iter", "step",
                 "local_solve", "comm/dalpha", "comm/w_contrib",
                 "observe"):
        t = tracer.total(name)
        print(f"  {name:<14s} {t * 1e3:8.2f} ms  ({100 * t / solve_s:5.1f}%)")

    # 3. the registry snapshot -- the same schema BENCH emitters embed
    snap = reg.snapshot()
    print("\nregistry snapshot (counters + a few gauges):")
    print(json.dumps({"counters": snap["counters"],
                      "gauges": snap["gauges"]}, indent=1))

    # 4. export: drag args.out into ui.perfetto.dev (or chrome://tracing)
    tracer.write_chrome_trace(args.out)
    base, _ = os.path.splitext(args.out)
    tracer.write_jsonl(base + ".jsonl")
    print(f"\nwrote {args.out} (+ {base}.jsonl) -- "
          f"{len(tracer.events)} events; open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
