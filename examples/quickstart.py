"""Quickstart: the paper's doubly distributed setting in ~40 lines.

Trains a hinge-loss SVM whose data matrix is partitioned BOTH across
observations (P=4) and features (Q=2) -- no node ever sees a full row or a
full column of the data -- using all three optimizers, and prints their
convergence against a serial reference.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (ADMMConfig, D3CAConfig, RADiSAConfig, admm_simulated,
                        d3ca_simulated, objective, partition,
                        radisa_simulated, rel_opt, serial_sdca)
from repro.data import make_svm_data


def main():
    # 1. the paper's synthetic binary classification data (§IV)
    X, y = make_svm_data(n=1200, m=360, seed=0)
    lam = 1e-1

    # 2. reference optimum from long serial SDCA
    w_star, _ = serial_sdca("hinge", X, y, lam=lam, epochs=300)
    f_star = float(objective("hinge", X, y, w_star, lam))
    print(f"f* = {f_star:.5f}")

    # 3. doubly distributed partition: P=4 observation x Q=2 feature blocks
    data = partition(X, y, P=4, Q=2)

    # 4. the two proposed methods + the ADMM baseline
    def report(name):
        def cb(t, w, *_):
            if t % 5 == 0:
                print(f"  {name} iter {t:3d}: rel-opt "
                      f"{float(rel_opt(objective('hinge', X, y, w, lam), f_star)):.4f}")
        return cb

    print("D3CA (dual coordinate ascent):")
    d3ca_simulated("hinge", data, D3CAConfig(lam=lam, outer_iters=15),
                   callback=report("d3ca"))
    print("RADiSA (SGD x CD + SVRG):")
    radisa_simulated("hinge", data,
                     RADiSAConfig(lam=lam, gamma=0.05, outer_iters=15),
                     callback=report("radisa"))
    print("block-splitting ADMM (baseline):")
    admm_simulated("hinge", data, ADMMConfig(lam=lam, rho=lam,
                                             outer_iters=60),
                   callback=report("admm"))


if __name__ == "__main__":
    main()
