"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the doubly distributed mesh, with checkpointing and the
fault-tolerant trainer.  (Reduced further with --small for CI.)

    PYTHONPATH=src python examples/lm_train.py [--small]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    from repro.launch import train as train_mod

    if args.small:
        argv = ["--arch", "qwen3-1.7b", "--reduced",
                "--steps", str(args.steps or 60),
                "--batch", "4", "--seq", "64", "--lr", "5e-3",
                "--ckpt-dir", "/tmp/repro_lm_small"]
    else:
        # ~100M params: qwen3 family scaled (12L x 768 x 12H, vocab 32k)
        import dataclasses
        import repro.configs.qwen3_1_7b as q
        from repro.models.config import MoEConfig  # noqa: F401
        cfg100m = dataclasses.replace(
            q.CONFIG, name="qwen3-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv=4, d_ff=2048, vocab=32768, head_dim=64)
        # register it under a temp name by monkeypatching get_config
        import repro.configs as configs
        configs._ALIASES["qwen3-100m"] = "qwen3_100m"
        import types
        mod = types.ModuleType("repro.configs.qwen3_100m")
        mod.CONFIG = cfg100m
        sys.modules["repro.configs.qwen3_100m"] = mod
        argv = ["--arch", "qwen3-100m",
                "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "256", "--lr", "3e-4",
                "--ckpt-dir", "/tmp/repro_lm_100m", "--ckpt-every", "100"]

    hist = train_mod.main(argv)
    import numpy as np
    losses = [h["loss"] for h in hist]
    print(f"\nfirst 5 losses: {[round(l, 3) for l in losses[:5]]}")
    print(f"last 5 losses:  {[round(l, 3) for l in losses[-5:]]}")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), "did not learn!"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
