"""Batched serving example: prefill a batch of prompts, decode with a KV
cache (ring buffer for SWA archs), report per-token latency.

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--reduced", "--batch", "4",
                    "--prompt-len", "24", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
