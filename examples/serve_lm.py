"""Serving example: a mixed-length request trace through the
continuous-batching engine (paged KV cache, per-request sampling seeds),
reporting tokens/s, TTFT and latency percentiles.

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--reduced", "--requests", "6",
                    "--slots", "3", "--prompt-len", "8",
                    "--prompt-len-max", "24", "--gen", str(args.gen),
                    "--page-size", "8", "--max-seq-len", "64"])


if __name__ == "__main__":
    main()
