"""Production-path example: the shard_map engines on a REAL device mesh.

Runs D3CA and RADiSA with one (observation, feature) block per device on
a P x Q mesh of forced host devices -- identical code to a TPU pod run,
where x_[p,q] lives in device (p,q)'s HBM and the reductions are ICI
collectives.

    python examples/svm_doubly_distributed.py          # 8 fake devices
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (D3CAConfig, RADiSAConfig, d3ca_distributed,
                        objective, radisa_distributed, rel_opt, serial_sdca)
from repro.data import make_svm_data
from repro.launch.mesh import make_grid_mesh


def main():
    P, Q = 4, 2
    n, m = 1600, 400
    X, y = make_svm_data(n, m, seed=0)
    lam = 1e-1
    w_star, _ = serial_sdca("hinge", X, y, lam=lam, epochs=200)
    f_star = float(objective("hinge", X, y, w_star, lam))

    mesh = make_grid_mesh(P, Q)
    print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    mask = jnp.ones((n,))

    w, alpha = d3ca_distributed("hinge", mesh, Xj, yj, mask,
                                D3CAConfig(lam=lam, outer_iters=15))
    print(f"D3CA   rel-opt: "
          f"{float(rel_opt(objective('hinge', X, y, w, lam), f_star)):.4f}")

    w2 = radisa_distributed("hinge", mesh, Xj, yj, mask,
                            RADiSAConfig(lam=lam, gamma=0.05,
                                         outer_iters=15))
    print(f"RADiSA rel-opt: "
          f"{float(rel_opt(objective('hinge', X, y, w2, lam), f_star)):.4f}")


if __name__ == "__main__":
    main()
