"""Worked online-learning example: stream observations through the
admission queue into warm-started gated D3CA passes while the live
scorer keeps serving, then inspect staleness and the snapshot history.

    PYTHONPATH=src python examples/online_loop.py [--rounds 12]

What it shows:

  * the request lifecycle -- ``submit`` (admission), ``run_pending``
    (ring-store insert, gated incremental solve, atomic snapshot
    publish + scorer swap), ``predict`` (serving the last published
    version);
  * why warm starts matter: the same batch folded in with and without
    the previous iterates;
  * the staleness gauge / version-lag bookkeeping and the
    ``online/update_s`` / ``online/swap_s`` histograms;
  * checkpoint-backed recovery: a second service resumes from the
    newest persisted snapshot.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--m", type=int, default=32)
    args = ap.parse_args()

    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.core import D3CAConfig, objective
    from repro.obs import Registry
    from repro.online import OnlineConfig, OnlineSolverService

    rng = np.random.default_rng(0)
    w_star = np.linspace(-1.0, 1.0, args.m).astype(np.float32)

    def stream(b):
        X = rng.normal(size=(b, args.m)).astype(np.float32)
        y = np.where(X @ w_star >= 0, 1.0, -1.0).astype(np.float32)
        return X, y

    ckpt_dir = tempfile.mkdtemp(prefix="online_ck_")
    reg = Registry()
    svc = OnlineSolverService(
        OnlineConfig(m=args.m, capacity=256, P=2, Q=2, loss="hinge",
                     solver_cfg=D3CAConfig(lam=1e-2), passes=2),
        manager=CheckpointManager(ckpt_dir, keep_n=3), registry=reg)

    # 1. the streaming loop: admit -> update -> serve
    print("round  version  filled   objective   accuracy  staleness")
    for r in range(args.rounds):
        svc.submit(*stream(args.batch))
        version = svc.run_pending()
        Xs, ys = stream(128)
        acc = float(np.mean(svc.predict(Xs) * ys > 0))
        mask = svc.store.filled_mask > 0
        f = float(objective("hinge", svc.store.X[mask], svc.store.y[mask],
                            svc.book.current().w, 1e-2))
        print(f"  {r:3d}  {version:7d}  {svc.store.filled:4d}/"
              f"{svc.store.capacity}   {f:.6f}   {acc:.3f}    "
              f"{svc.staleness_s * 1e3:6.1f} ms")

    # 2. warm start vs cold: fold one more batch in both ways
    cur = svc.book.current()
    Xb, yb = stream(args.batch)
    touched = svc.store.insert(Xb, yb)
    warm = svc.solver.update("hinge", svc.store.X, svc.store.y,
                             touched=touched, warm_start=(cur.w, cur.alpha),
                             P=2, Q=2, cfg=D3CAConfig(lam=1e-2), passes=2)
    zeros = (np.zeros_like(cur.w), np.zeros_like(cur.alpha))
    cold = svc.solver.update("hinge", svc.store.X, svc.store.y,
                             touched=touched, warm_start=zeros,
                             P=2, Q=2, cfg=D3CAConfig(lam=1e-2), passes=2)
    mask = svc.store.filled_mask > 0
    f_warm = objective("hinge", svc.store.X[mask], svc.store.y[mask],
                       np.asarray(warm.w), 1e-2)
    f_cold = objective("hinge", svc.store.X[mask], svc.store.y[mask],
                       np.asarray(cold.w), 1e-2)
    print(f"\nsame gated passes, warm f={f_warm:.6f} vs cold "
          f"f={f_cold:.6f} (warm start carries the converged dual)")

    # 3. the service's metrics: staleness gauge + update/swap histograms
    snap = reg.snapshot()
    print("\nonline metrics:")
    for k, v in snap["counters"].items():
        if k.startswith("online/"):
            print(f"  {k:<55s} {v:.0f}")
    for k, v in snap["gauges"].items():
        if k.startswith("online/"):
            print(f"  {k:<55s} {v:.4f}")
    for k, h in snap["histograms"].items():
        if k.startswith("online/"):
            print(f"  {k:<55s} p50={h['p50'] * 1e3:.2f} ms "
                  f"(n={h['count']})")

    # 4. crash recovery: a fresh service resumes from the newest
    #    persisted snapshot (write-to-tmp + atomic rename on disk)
    svc.book.flush()
    svc2 = OnlineSolverService(
        OnlineConfig(m=args.m, capacity=256, P=2, Q=2),
        manager=CheckpointManager(ckpt_dir, keep_n=3))
    v = svc2.recover()
    same = np.allclose(svc2.book.current().w, svc.book.current().w)
    print(f"\nrecovered version {v} from {ckpt_dir} "
          f"(weights match: {same})")


if __name__ == "__main__":
    main()
