"""Fault-tolerant checkpointing.

Design (multi-host ready, single-host exercised here):
  * every leaf of the pytree is written as one ``.npy`` per host, holding
    the concatenation of this host's addressable shards plus an index json
    describing the global shape/dtype and tree structure;
  * writes go to ``step_XXXX.tmp`` and are atomically renamed -- a crash
    mid-write can never corrupt the latest checkpoint;
  * ``save_async`` hands the device->host transfer result to a background
    thread so the train loop only blocks for the D2H copy;
  * ``restore`` re-shards to ANY mesh: arrays are loaded full and
    ``jax.device_put`` with the target sharding -- this is the elastic
    re-scale path (checkpoint written on a 16x16 mesh restores onto 2x16x16
    or a single device);
  * ``keep_n`` garbage-collects old steps, never touching the newest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_tree(path: str, tree: Any):
    """Synchronous atomic save of a pytree of arrays."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    index = {"leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        index["leaves"].append({"path": p, "file": fn,
                                "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "index.json"), "w") as fh:
        json.dump(index, fh)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_tree(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (re-sharding if given).

    ``shardings``: optional pytree of jax.sharding.Sharding matching
    ``like`` -- arrays are placed with ``device_put`` (elastic re-scale).
    """
    with open(os.path.join(path, "index.json")) as fh:
        index = json.load(fh)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in index["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        e = by_path[p]
        arr = np.load(os.path.join(path, e["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {p}: ckpt {arr.shape} "
                             f"vs target {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any):
        save_tree(self._step_dir(step), tree)
        self._gc()

    def save_async(self, step: int, tree: Any):
        """Device->host copy now; disk write in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save_tree(self._step_dir(step), host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return step, restore_tree(self._step_dir(step), like, shardings)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
