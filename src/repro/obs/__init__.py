"""repro.obs -- unified telemetry: tracing, metrics, phase attribution.

The paper's central claim is about *scaling properties* -- where
wall-clock goes as the P x Q grid grows -- so the repo needs one
measurement substrate that attributes time to the local Pallas solve vs
the declared collectives vs host bookkeeping, instead of four
instrumentation dialects (solver ``history`` dicts, ``ServeMetrics``,
``Comm.wire_bytes``, BENCH provenance stamps).

Modules:
  * ``trace``   -- :class:`Tracer`: nestable spans with an injectable
                   clock, thread-safe, near-zero overhead when disabled
                   (``NULL_TRACER``); exports Chrome-trace JSON
                   (chrome://tracing / Perfetto) and a JSONL event log;
                   optional ``jax.profiler`` TraceAnnotation
                   pass-through so spans appear in device profiles
  * ``metrics`` -- :class:`Registry` of labelled counters / gauges /
                   histograms with one ``snapshot()`` schema shared by
                   every BENCH emitter; absorbs the legacy percentile
                   helpers
  * ``phases``  -- per-phase wall-clock attribution: calibrates the
                   local-solve vs communication split of an
                   :class:`~repro.core.engines.EngineProgram` (via its
                   collective-free ``local_step``) and prices each
                   named collective's share; per-codec encode/decode
                   microbench
  * ``serve``   -- :class:`RequestMetrics`: the serving engine's
                   request-lifecycle bookkeeping (tok/s, TTFT, latency
                   percentiles) written through a Registry; the legacy
                   ``repro.serve.metrics.ServeMetrics`` is a deprecated
                   shim over it
  * ``recorder``-- :class:`FlightRecorder`: bounded ring-buffer tracer
                   (drop-oldest, O(capacity) memory) for the services
                   that run indefinitely, with atomic postmortem
                   bundles (``dump`` / ``crash_guard`` /
                   :func:`load_bundle`)
  * ``health``  -- declarative :class:`HealthRule` catalog over the
                   registry (divergence, gap stall, staleness, queue
                   shed, fleet starvation, exposed-comm share) and the
                   :class:`HealthMonitor` that evaluates them, records
                   verdicts as metrics, and edge-triggers recorder
                   dumps on CRIT
  * ``export``  -- Prometheus text-format rendering of a registry
                   snapshot (:func:`render_prometheus`) and its
                   validating inverse (:func:`parse_prometheus_text`)
  * ``http``    -- :class:`ObsServer`: stdlib-only background HTTP
                   endpoint with ``/metrics`` (Prometheus),
                   ``/healthz`` (503 on CRIT), and ``/varz``

Nothing in this package imports ``repro.core`` or ``repro.serve`` --
the observability layer sits below both and is threaded through them.
"""
from .export import parse_prometheus_text, render_prometheus
from .health import (CRIT, OK, WARN, HealthEvent, HealthMonitor, HealthRule,
                     fleet_rules, online_rules, rule_comm_exposed,
                     rule_divergence, rule_fleet_starvation, rule_gap_stall,
                     rule_queue_shed, rule_staleness, rule_version_lag,
                     serve_rules, solver_rules)
from .http import ObsServer
from .metrics import Counter, Gauge, Histogram, Registry, percentiles
from .phases import PhaseSplit, bench_codecs, calibrate_phases
from .recorder import BUNDLE_SCHEMA, FlightRecorder, load_bundle
from .serve import RequestMetrics
from .trace import NULL_TRACER, NullTracer, Tracer, as_tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "percentiles",
    "PhaseSplit", "bench_codecs", "calibrate_phases",
    "RequestMetrics",
    "NULL_TRACER", "NullTracer", "Tracer", "as_tracer",
    "BUNDLE_SCHEMA", "FlightRecorder", "load_bundle",
    "OK", "WARN", "CRIT", "HealthEvent", "HealthRule", "HealthMonitor",
    "rule_divergence", "rule_gap_stall", "rule_staleness",
    "rule_version_lag", "rule_queue_shed", "rule_fleet_starvation",
    "rule_comm_exposed",
    "solver_rules", "online_rules", "serve_rules", "fleet_rules",
    "render_prometheus", "parse_prometheus_text",
    "ObsServer",
]
