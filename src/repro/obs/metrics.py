"""Metrics registry: labelled counters / gauges / histograms.

One :class:`Registry` absorbs the repo's scattered bookkeeping dialects
-- the serving engine's percentile counters, the solver driver's
per-iteration history fields, the compressed-comm wire accounting --
behind a single ``snapshot()`` schema every BENCH emitter can embed::

    reg = Registry()
    reg.counter("serve/prefills").inc()
    reg.gauge("solver/objective", solver="d3ca").set(0.31)
    reg.histogram("solver/step_s", solver="d3ca").observe(0.002)
    reg.snapshot()
    # {"counters":   {"serve/prefills": 1},
    #  "gauges":     {"solver/objective{solver=d3ca}": 0.31},
    #  "histograms": {"solver/step_s{solver=d3ca}":
    #                   {"count": 1, "sum": ..., "mean": ..., "min": ...,
    #                    "max": ..., "p50": ..., "p90": ..., "p99": ...}}}

Metrics are host-side and cheap (a dict lookup + float op per update);
get-or-create is lock-protected so engine threads can share a registry.
The default percentile set is (50, 90, 99) -- p90 joined p50/p99 when
the serving metrics moved here (the SLO middle ground the serve ROADMAP
item needs).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Tuple

import numpy as np

#: default percentile set for histograms and the legacy helpers
DEFAULT_PERCENTILES = (50, 90, 99)


def percentiles(xs, qs: Tuple[int, ...] = DEFAULT_PERCENTILES) -> dict:
    """{f"p{q}": value} over ``xs`` (empty input -> zeros)."""
    if len(xs) == 0:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(xs, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class Counter:
    """Monotonic float counter (``+=`` semantics via :meth:`inc`)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        self.value += v

    def set(self, v: float):
        """Direct assignment -- for shims that mirror legacy attributes
        (``metrics.preemptions += 1`` through a property)."""
        self.value = v


class Gauge:
    """Last-value-wins metric."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Raw-observation histogram with percentile summaries."""

    __slots__ = ("qs", "observations")

    def __init__(self, qs: Tuple[int, ...] = DEFAULT_PERCENTILES):
        self.qs = tuple(qs)
        self.observations: List[float] = []

    def observe(self, v: float):
        self.observations.append(float(v))

    @property
    def count(self) -> int:
        return len(self.observations)

    @property
    def sum(self) -> float:
        return float(sum(self.observations))

    def summary(self) -> dict:
        obs = self.observations
        out = {"count": len(obs), "sum": self.sum,
               "mean": self.sum / len(obs) if obs else 0.0,
               "min": float(min(obs)) if obs else 0.0,
               "max": float(max(obs)) if obs else 0.0}
        out.update(percentiles(obs, self.qs))
        return out


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Get-or-create store of labelled metrics with one snapshot schema.

    The same (kind, name, labels) triple always returns the same metric
    object; a name may exist as several kinds (a gauge tracking the
    latest value and a histogram of the series do not collide).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, str], object] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, _key(name, labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, factory())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, qs: Tuple[int, ...] = DEFAULT_PERCENTILES,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, lambda: Histogram(qs))

    def snapshot(self) -> dict:
        """The one schema every BENCH emitter embeds: plain JSON-able
        dicts keyed by ``name{label=value,...}``."""
        with self._lock:
            items = list(self._metrics.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, key), metric in sorted(items):
            if kind == "counter":
                out["counters"][key] = metric.value
            elif kind == "gauge":
                out["gauges"][key] = metric.value
            else:
                out["histograms"][key] = metric.summary()
        return out
