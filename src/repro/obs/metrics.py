"""Metrics registry: labelled counters / gauges / histograms.

One :class:`Registry` absorbs the repo's scattered bookkeeping dialects
-- the serving engine's percentile counters, the solver driver's
per-iteration history fields, the compressed-comm wire accounting --
behind a single ``snapshot()`` schema every BENCH emitter can embed::

    reg = Registry()
    reg.counter("serve/prefills").inc()
    reg.gauge("solver/objective", solver="d3ca").set(0.31)
    reg.histogram("solver/step_s", solver="d3ca").observe(0.002)
    reg.snapshot()
    # {"counters":   {"serve/prefills": 1},
    #  "gauges":     {"solver/objective{solver=d3ca}": 0.31},
    #  "histograms": {"solver/step_s{solver=d3ca}":
    #                   {"count": 1, "sum": ..., "mean": ..., "min": ...,
    #                    "max": ..., "p50": ..., "p90": ..., "p99": ...}}}

Metrics are host-side and cheap (a dict lookup + float op per update);
get-or-create is lock-protected so engine threads can share a registry,
and every *update* (``inc`` / ``observe``) is itself lock-protected so
concurrent writers never lose increments and a concurrent ``snapshot()``
always sees a self-consistent histogram (the online service scores and
publishes from different threads; the obs HTTP endpoint scrapes from a
third).  The default percentile set is (50, 90, 99) -- p90 joined
p50/p99 when the serving metrics moved here (the SLO middle ground the
serve ROADMAP item needs).

Histograms are **bounded**: ``count`` / ``sum`` / ``min`` / ``max`` are
exact running aggregates, while percentiles come from a fixed-size
reservoir (Vitter's algorithm R, deterministic per-histogram PRNG).
Below ``reservoir`` observations the reservoir holds every observation
in arrival order, so the percentile summaries are bit-identical to the
unbounded implementation; beyond it the memory stays O(reservoir) no
matter how long the service runs.
"""
from __future__ import annotations

import random
import threading
from typing import Dict, List, Tuple

import numpy as np

#: default percentile set for histograms and the legacy helpers
DEFAULT_PERCENTILES = (50, 90, 99)

#: default histogram reservoir size: exact percentiles below this many
#: observations, O(1) memory above (long-running services observe
#: millions of step/update/latency samples)
DEFAULT_RESERVOIR = 4096


def percentiles(xs, qs: Tuple[int, ...] = DEFAULT_PERCENTILES) -> dict:
    """{f"p{q}": value} over ``xs`` (empty input -> zeros)."""
    if len(xs) == 0:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(xs, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class Counter:
    """Monotonic float counter (``+=`` semantics via :meth:`inc`).

    ``inc`` is lock-protected: a bare float ``+=`` is read-modify-write
    at the bytecode level, so two threads incrementing concurrently can
    lose updates without it."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0):
        with self._lock:
            self.value += v

    def set(self, v: float):
        """Direct assignment -- for shims that mirror legacy attributes
        (``metrics.preemptions += 1`` through a property)."""
        with self._lock:
            self.value = v


class Gauge:
    """Last-value-wins metric (a single assignment is atomic enough)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Bounded histogram: exact count/sum/min/max, reservoir percentiles.

    The reservoir (algorithm R, deterministic seed) holds every
    observation in arrival order until ``cap`` is reached -- below the
    cap, ``summary()`` is bit-identical to a summary over the full
    series -- and replaces uniformly at random beyond it, keeping memory
    O(cap) over an unbounded observation stream."""

    __slots__ = ("qs", "cap", "_xs", "_count", "_sum", "_min", "_max",
                 "_rng", "_lock")

    def __init__(self, qs: Tuple[int, ...] = DEFAULT_PERCENTILES,
                 cap: int = DEFAULT_RESERVOIR):
        if cap < 1:
            raise ValueError(f"histogram reservoir cap must be >= 1, "
                             f"got {cap}")
        self.qs = tuple(qs)
        self.cap = int(cap)
        self._xs: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._rng = random.Random(0x0B5E7E)   # deterministic reservoir
        self._lock = threading.Lock()

    def observe(self, v: float):
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._xs) < self.cap:
                self._xs.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < self.cap:
                    self._xs[j] = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def observations(self) -> List[float]:
        """The retained observations (the full series below ``cap``, a
        uniform sample of it above)."""
        with self._lock:
            return list(self._xs)

    def summary(self) -> dict:
        with self._lock:            # consistent (count, sum, reservoir)
            n, s = self._count, self._sum
            mn = self._min if n else 0.0
            mx = self._max if n else 0.0
            xs = list(self._xs)
        out = {"count": n, "sum": s,
               "mean": s / n if n else 0.0,
               "min": mn, "max": mx}
        out.update(percentiles(xs, self.qs))
        return out


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Get-or-create store of labelled metrics with one snapshot schema.

    The same (kind, name, labels) triple always returns the same metric
    object; a name may exist as several kinds (a gauge tracking the
    latest value and a histogram of the series do not collide).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, str], object] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, _key(name, labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, factory())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, qs: Tuple[int, ...] = DEFAULT_PERCENTILES,
                  cap: int = DEFAULT_RESERVOIR, **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(qs, cap))

    def snapshot(self) -> dict:
        """The one schema every BENCH emitter embeds: plain JSON-able
        dicts keyed by ``name{label=value,...}``."""
        with self._lock:
            items = list(self._metrics.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, key), metric in sorted(items):
            if kind == "counter":
                out["counters"][key] = metric.value
            elif kind == "gauge":
                out["gauges"][key] = metric.value
            else:
                out["histograms"][key] = metric.summary()
        return out
