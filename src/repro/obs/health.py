"""Declarative health monitoring over the metrics the stack already emits.

A :class:`HealthRule` is a named check over a ``Registry.snapshot()``
returning an (status, message, value) verdict; a :class:`HealthMonitor`
evaluates a set of rules, records the verdicts back into the registry
(``health/status{rule=...}`` gauges, ``health/transitions`` counters),
and -- on an OK->CRIT edge -- fires **exactly one** postmortem dump of
its paired :class:`~repro.obs.recorder.FlightRecorder` per transition.

Rules are pure functions of the snapshot plus whatever window state
their closure keeps, so they are cheap enough to poll from the drive
loop / service loops; :meth:`HealthMonitor.poll` additionally
rate-limits evaluation (``min_interval_s``) so per-decode-step polling
in the serve engine costs a clock read.

The catalog (:func:`rule_divergence`, :func:`rule_gap_stall`,
:func:`rule_staleness`, :func:`rule_version_lag`,
:func:`rule_queue_shed`, :func:`rule_fleet_starvation`,
:func:`rule_comm_exposed`) covers the signals the algorithms already
export: NaN / non-improving ``solver/objective``-``solver/rel_opt``
(the D3CA dual ascent diverging), a stalled duality gap, the online
service's staleness gauge and version lag, the admission queue's shed
rate, starved fleet buckets, and the exposed-communication share of a
step.  :func:`solver_rules` / :func:`online_rules` / :func:`serve_rules`
/ :func:`fleet_rules` bundle sensible defaults per service.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: health statuses, in increasing severity
OK, WARN, CRIT = "ok", "warn", "crit"
SEVERITY = {OK: 0, WARN: 1, CRIT: 2}


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One rule's verdict at one evaluation."""
    rule: str
    status: str                  # OK | WARN | CRIT
    message: str
    value: Optional[float] = None
    t: float = 0.0               # monitor clock at evaluation


@dataclasses.dataclass(frozen=True)
class HealthRule:
    """A named check: ``check(snapshot) -> (status, message, value)``.

    ``check`` receives the full ``Registry.snapshot()`` dict; closures
    may keep window state (e.g. the last N observed values) across
    evaluations.  A rule that raises is reported WARN with the
    exception message -- a broken rule must never take the service
    down."""
    name: str
    check: Callable[[dict], Tuple[str, str, Optional[float]]]
    description: str = ""


def _series(section: Dict[str, object], name: str) -> Dict[str, float]:
    """All entries of a snapshot section whose base metric name is
    ``name`` (label-decorated keys render as ``name{k=v,...}``)."""
    pfx = name + "{"
    return {k: v for k, v in section.items()
            if k == name or k.startswith(pfx)}


def _is_bad(v) -> bool:
    return v is None or not math.isfinite(v)


# ---------------------------------------------------------------------------
# the rule catalog
# ---------------------------------------------------------------------------

def rule_divergence(gauge: str = "solver/objective",
                    improve_gauge: str = "solver/rel_opt",
                    window: int = 8,
                    name: str = "divergence") -> HealthRule:
    """CRIT on a NaN/inf objective; WARN when ``improve_gauge`` (falling
    back to ``gauge``) has not decreased over the last ``window``
    evaluations -- the D3CA dual ascent diverging or wedged."""
    hist: Dict[str, collections.deque] = {}

    def check(snap):
        gauges = snap.get("gauges", {})
        objs = _series(gauges, gauge)
        for key, v in objs.items():
            if _is_bad(v):
                return CRIT, f"{key} is {v!r} (non-finite)", v
        tracked = _series(gauges, improve_gauge) or objs
        worst = None
        for key, v in tracked.items():
            if _is_bad(v):
                return CRIT, f"{key} is {v!r} (non-finite)", v
            dq = hist.setdefault(key, collections.deque(maxlen=window + 1))
            dq.append(float(v))
            if len(dq) == window + 1 and min(dq) >= dq[0]:
                worst = (key, v)
        if worst is not None:
            return WARN, (f"{worst[0]} has not improved over the last "
                          f"{window} evaluations"), worst[1]
        if not objs and not tracked:
            return OK, f"no {gauge} series yet", None
        return OK, "objective finite and improving", None

    return check_rule(name, check, "NaN objective or stalled rel_opt")


def rule_gap_stall(gauge: str = "solver/duality_gap", window: int = 8,
                   min_rel_decrease: float = 1e-3,
                   name: str = "duality_gap_stall") -> HealthRule:
    """WARN when the duality gap shrank less than ``min_rel_decrease``
    (relatively) over the last ``window`` evaluations; CRIT when it is
    non-finite or grew."""
    hist: Dict[str, collections.deque] = {}

    def check(snap):
        gaps = _series(snap.get("gauges", {}), gauge)
        if not gaps:
            return OK, f"no {gauge} series yet", None
        for key, v in gaps.items():
            if _is_bad(v):
                return CRIT, f"{key} is {v!r} (non-finite)", v
            dq = hist.setdefault(key, collections.deque(maxlen=window + 1))
            dq.append(float(v))
            if len(dq) == window + 1:
                first, last = dq[0], dq[-1]
                if last > first and last > 0:
                    return CRIT, f"{key} grew {first:.3e} -> {last:.3e}", v
                denom = max(abs(first), 1e-30)
                if (first - last) / denom < min_rel_decrease:
                    return WARN, (f"{key} stalled at {last:.3e} over "
                                  f"{window} evaluations"), v
        return OK, "gap shrinking", None

    return check_rule(name, check, "duality gap stalled or growing")


def rule_staleness(max_s: float, gauge: str = "online/staleness_s",
                   warn_frac: float = 0.5,
                   name: str = "staleness") -> HealthRule:
    """Served-snapshot age: CRIT above ``max_s`` seconds, WARN above
    ``warn_frac * max_s``."""

    def check(snap):
        vals = _series(snap.get("gauges", {}), gauge)
        if not vals:
            return OK, f"no {gauge} series yet", None
        key, v = max(vals.items(), key=lambda kv: kv[1])
        if v > max_s:
            return CRIT, f"{key}={v:.3f}s > {max_s:.3f}s", v
        if v > warn_frac * max_s:
            return WARN, f"{key}={v:.3f}s > {warn_frac * max_s:.3f}s", v
        return OK, f"staleness {v:.3f}s", v

    return check_rule(name, check, f"served snapshot older than {max_s}s")


def rule_version_lag(max_lag: float, gauge: str = "online/version_lag",
                     warn_frac: float = 0.5,
                     name: str = "version_lag") -> HealthRule:
    """Admitted-but-unserved observations: CRIT above ``max_lag``."""

    def check(snap):
        vals = _series(snap.get("gauges", {}), gauge)
        if not vals:
            return OK, f"no {gauge} series yet", None
        key, v = max(vals.items(), key=lambda kv: kv[1])
        if v > max_lag:
            return CRIT, f"{key}={v:.0f} > {max_lag:.0f}", v
        if v > warn_frac * max_lag:
            return WARN, f"{key}={v:.0f} > {warn_frac * max_lag:.0f}", v
        return OK, f"version lag {v:.0f}", v

    return check_rule(name, check,
                      f"served model more than {max_lag} observations "
                      "behind the stream")


def rule_queue_shed(max_rate: float = 0.1,
                    rejected: str = "online/rejected",
                    admitted: str = "online/ingested",
                    name: str = "queue_shed") -> HealthRule:
    """Admission shed rate between evaluations: CRIT when more than
    ``max_rate`` of offered rows were rejected since the last
    evaluation (queue saturation), WARN above half of it.  The first
    evaluation sees the cumulative counters (baseline zero)."""
    last = {"rej": 0.0, "adm": 0.0}

    def check(snap):
        counters = snap.get("counters", {})
        rej = sum(_series(counters, rejected).values())
        adm = sum(_series(counters, admitted).values())
        d_rej, d_adm = rej - last["rej"], adm - last["adm"]
        last["rej"], last["adm"] = rej, adm
        offered = d_rej + d_adm
        if offered <= 0:
            return OK, "no traffic since last evaluation", 0.0
        rate = d_rej / offered
        if rate > max_rate:
            return CRIT, (f"shed {d_rej:.0f}/{offered:.0f} offered rows "
                          f"({100 * rate:.1f}% > {100 * max_rate:.1f}%)"), \
                rate
        if rate > 0.5 * max_rate:
            return WARN, f"shed rate {100 * rate:.1f}%", rate
        return OK, f"shed rate {100 * rate:.1f}%", rate

    return check_rule(name, check,
                      f"admission queue shedding more than "
                      f"{100 * max_rate:.0f}% of offered rows")


def rule_fleet_starvation(min_tenants: int = 2,
                          gauge: str = "fleet/bucket_tenants",
                          name: str = "fleet_starvation") -> HealthRule:
    """WARN when any fleet shape bucket runs with fewer than
    ``min_tenants`` tenants -- a starved bucket pays a whole compiled
    program for almost no batching win."""

    def check(snap):
        vals = _series(snap.get("gauges", {}), gauge)
        if not vals:
            return OK, "no fleet buckets yet", None
        starved = {k: v for k, v in vals.items() if v < min_tenants}
        if starved:
            key, v = min(starved.items(), key=lambda kv: kv[1])
            return WARN, (f"{len(starved)} bucket(s) below "
                          f"{min_tenants} tenants (worst {key}={v:.0f})"), v
        return OK, f"all {len(vals)} buckets >= {min_tenants} tenants", None

    return check_rule(name, check,
                      f"fleet bucket running under {min_tenants} tenants")


def rule_comm_exposed(max_share: float = 0.5,
                      comm: str = "solver/comm_exposed_s",
                      comm_fallback: str = "solver/comm_s",
                      step: str = "solver/step_s",
                      name: str = "comm_exposed") -> HealthRule:
    """WARN when the exposed-communication share of the mean outer step
    exceeds ``max_share`` -- the wire is eating the critical path
    (overlap cells report ``comm_exposed_s``; hidden comm is free)."""

    def check(snap):
        hists = snap.get("histograms", {})
        steps = _series(hists, step)
        comms = _series(hists, comm) or _series(hists, comm_fallback)
        step_sum = sum(h["sum"] for h in steps.values())
        comm_sum = sum(h["sum"] for h in comms.values())
        if step_sum <= 0 or not comms:
            return OK, "no phased step series yet", None
        share = comm_sum / step_sum
        if share > max_share:
            return WARN, (f"exposed comm is {100 * share:.1f}% of step "
                          f"(> {100 * max_share:.1f}%)"), share
        return OK, f"exposed comm {100 * share:.1f}% of step", share

    return check_rule(name, check,
                      f"exposed comm share of a step above "
                      f"{100 * max_share:.0f}%")


def check_rule(name: str, check, description: str = "") -> HealthRule:
    """Tiny constructor shim so the factories above read declaratively."""
    return HealthRule(name=name, check=check, description=description)


# ---------------------------------------------------------------------------
# bundled defaults per service
# ---------------------------------------------------------------------------

def solver_rules(*, stall_window: int = 8,
                 max_comm_share: float = 0.75) -> List[HealthRule]:
    """Rules for a batch/long solve driven through ``Solver.solve``."""
    return [rule_divergence(window=stall_window),
            rule_gap_stall(window=stall_window),
            rule_comm_exposed(max_share=max_comm_share)]


def online_rules(*, max_staleness_s: float = 60.0, max_lag: float = 10_000,
                 max_shed_rate: float = 0.1,
                 stall_window: int = 8) -> List[HealthRule]:
    """Rules for the :class:`~repro.online.OnlineSolverService` (adds a
    NaN check on the published weights via ``online/w_norm``)."""
    return [rule_divergence(gauge="online/w_norm",
                            improve_gauge="online/w_norm",
                            window=10 ** 9,   # norm drift is not a stall
                            name="online_divergence"),
            rule_staleness(max_staleness_s),
            rule_version_lag(max_lag),
            rule_queue_shed(max_shed_rate)]


def serve_rules(*, max_shed_rate: float = 0.1) -> List[HealthRule]:
    """Rules for the continuous-batching serve engine."""
    return [rule_queue_shed(max_shed_rate,
                            rejected="serve/rejections",
                            admitted="serve/requests_finished",
                            name="serve_shed")]


def fleet_rules(*, min_tenants: int = 2) -> List[HealthRule]:
    """Rules for the multi-tenant fleet scheduler."""
    return [rule_divergence(), rule_fleet_starvation(min_tenants)]


# ---------------------------------------------------------------------------
# the monitor
# ---------------------------------------------------------------------------

class HealthMonitor:
    """Evaluates rules over a registry; edge-triggers postmortem dumps.

    Args:
      registry: the :class:`~repro.obs.metrics.Registry` the monitored
        code writes into; verdicts land back in it as
        ``health/status{rule=...}`` gauges (0 = OK, 1 = WARN, 2 = CRIT)
        and ``health/transitions{rule=...,status=...}`` counters.
      rules: iterable of :class:`HealthRule` (add more with
        :meth:`add_rule`).
      recorder: optional :class:`~repro.obs.recorder.FlightRecorder`;
        on each rule's OK/WARN -> CRIT transition the monitor writes
        exactly one postmortem bundle into ``dump_dir`` (re-arming only
        after the rule leaves CRIT).
      dump_dir: directory for CRIT bundles (required for dumping).
      min_interval_s: :meth:`poll` rate limit -- evaluations are
        skipped until this much monitor-clock time has passed, so
        hot-loop polling is a clock read.
      clock: injectable monotonic clock (tests freeze it).
    """

    def __init__(self, registry, rules: Sequence[HealthRule] = (), *,
                 recorder=None, dump_dir: Optional[str] = None,
                 min_interval_s: float = 0.0, clock=time.monotonic):
        self.registry = registry
        self.rules: List[HealthRule] = list(rules)
        self.recorder = recorder
        self.dump_dir = dump_dir
        self.min_interval_s = float(min_interval_s)
        self.clock = clock
        self._lock = threading.Lock()
        self._last_eval = float("-inf")
        self._last_status: Dict[str, str] = {}
        self._events: collections.deque = collections.deque(maxlen=256)
        self._dump_seq = 0
        self.status = OK
        self.evaluations = 0

    def add_rule(self, rule: HealthRule):
        with self._lock:
            self.rules.append(rule)

    # ------------------------------------------------------------------
    def poll(self) -> str:
        """Rate-limited :meth:`evaluate`; returns the current overall
        status either way."""
        now = self.clock()
        with self._lock:
            due = now - self._last_eval >= self.min_interval_s
        if due:
            self.evaluate()
        return self.status

    def evaluate(self) -> List[HealthEvent]:
        """Run every rule once; returns this evaluation's events."""
        now = self.clock()
        with self._lock:
            self._last_eval = now
            rules = list(self.rules)
        snap = self.registry.snapshot()
        events: List[HealthEvent] = []
        worst = OK
        for rule in rules:
            try:
                status, message, value = rule.check(snap)
            except Exception as e:      # a broken rule must not crash us
                status, message, value = WARN, f"rule error: {e!r}", None
            if SEVERITY[status] > SEVERITY[worst]:
                worst = status
            ev = HealthEvent(rule=rule.name, status=status,
                             message=message, value=value, t=now)
            events.append(ev)
            self.registry.gauge("health/status", rule=rule.name).set(
                SEVERITY[status])
            prev = self._last_status.get(rule.name, OK)
            if status != prev:
                self.registry.counter("health/transitions", rule=rule.name,
                                      status=status).inc()
                if status == CRIT:
                    self._fire_dump(rule.name, message)
            self._last_status[rule.name] = status
        with self._lock:
            self._events.extend(events)
            self.evaluations += 1
        self.status = worst
        self.registry.gauge("health/overall").set(SEVERITY[worst])
        return events

    def _fire_dump(self, rule_name: str, message: str):
        """Exactly one bundle per transition INTO CRIT (edge-triggered:
        a rule staying CRIT across evaluations does not re-dump; it
        re-arms when it recovers)."""
        if self.recorder is None or self.dump_dir is None:
            return
        with self._lock:
            self._dump_seq += 1
            seq = self._dump_seq
        safe = rule_name.replace("/", "_")
        path = os.path.join(self.dump_dir, f"health-{safe}-{seq}.json")
        try:
            self.recorder.dump(path, reason=f"health:{rule_name}:{message}")
        except Exception:
            pass                        # dumping must never crash the loop

    # ------------------------------------------------------------------
    def healthz(self, evaluate: bool = True) -> dict:
        """The ``/healthz`` payload: overall status plus the latest
        per-rule verdicts."""
        if evaluate:
            events = self.evaluate()
        else:
            with self._lock:
                latest: Dict[str, HealthEvent] = {}
                for ev in self._events:
                    latest[ev.rule] = ev
                events = list(latest.values())
        return {
            "status": self.status,
            "evaluations": self.evaluations,
            "rules": {ev.rule: {"status": ev.status,
                                "message": ev.message,
                                "value": ev.value} for ev in events},
        }

    def events(self) -> List[HealthEvent]:
        """The retained event tail (most recent last)."""
        with self._lock:
            return list(self._events)
