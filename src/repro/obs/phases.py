"""Per-phase wall-clock attribution for engine programs.

An outer iteration's wall-clock decomposes into

  * ``local_s`` -- the cell-local solve (the Pallas/ref kernel work),
  * ``comm_s``  -- the declared collectives (wire + codec encode/decode),
  * ``host_s``  -- host bookkeeping (objective/gap eval, scheduling).

Nothing inside a jitted step can be timed from the host, so the split
is measured *differentially*: every :class:`~repro.core.engines`
program built since the telemetry PR also carries ``local_step`` -- the
SAME cell program with every collective executed cell-locally
(:class:`~repro.core.comm.LocalComm`: psum/pmean return the cell's own
contribution, allgather broadcasts it) -- which costs the local math
without the reductions.  ``comm_s = step_s - local_step_s`` is then the
communication share, and it is split across the named collectives
proportionally to their exact bytes-on-wire (from the program's
``comm_bytes`` accounting), which is the attribution model a bandwidth
-bound interconnect obeys.

:func:`calibrate_phases` measures the split once per program (a few
timed steps of each variant); :meth:`PhaseSplit.attribute` then prices
every subsequent iteration from its measured ``step_s`` alone, so the
steady-state tracing overhead stays at host-timer resolution.

Overlap-aware attribution: programs built by the overlap engine
(``EngineProgram.overlap`` with ``staleness = tau > 0``) consume each
reduction tau steps after dispatch, so up to tau steps of local solve
can hide the wire.  For those programs :meth:`PhaseSplit.attribute`
further splits ``comm_s`` into ``comm_hidden_s`` (overlapped with the
local solve, up to ``tau * local_s``) and ``comm_exposed_s`` (the
remainder that extends the critical path) via
:func:`repro.core.comm_model.overlap_split`.

:func:`bench_codecs` microbenchmarks each compressed collective's
encode/decode path on a representative payload (per-codec cost the
fig_compress sweep reports next to the byte savings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional


def _timeit(fn, reps: int) -> float:
    import jax
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)      # min: calibration wants the noise floor


@dataclasses.dataclass(frozen=True)
class PhaseSplit:
    """Calibrated local/comm split of one engine program."""

    #: fraction of a step spent in the cell-local solve (0..1)
    local_frac: float
    #: each named collective's share of the comm fraction (sums to 1)
    comm_shares: Dict[str, float]
    #: calibration measurements, for provenance
    step_s: float
    local_s: float
    #: reduction delay tau of the program (0 = synchronous)
    staleness: int = 0
    #: True for overlap-engine programs: comm_s further splits into
    #: hidden (overlapped with local solve) and exposed shares
    overlap: bool = False

    def attribute(self, step_s: float) -> dict:
        """Split one measured step duration into phases::

            {"local_s": ..., "comm_s": ...,
             ["comm_hidden_s": ..., "comm_exposed_s": ...,]
             "collectives": {name: seconds}}
        """
        local = step_s * self.local_frac
        comm = max(step_s - local, 0.0)
        out = {"local_s": local, "comm_s": comm,
               "collectives": {name: comm * share
                               for name, share in self.comm_shares.items()}}
        if self.overlap and self.staleness > 0:
            from repro.core.comm_model import overlap_split
            out.update(overlap_split(comm, local, self.staleness))
        return out


def calibrate_phases(prog, *, reps: int = 3) -> Optional[PhaseSplit]:
    """Measure a program's local/comm split (see module docstring).

    Returns None when the program carries no ``local_step`` (legacy
    programs built outside the generic executors) -- callers then emit
    only the undivided ``step`` span.  Warmup compiles both variants;
    the calibration steps are pure (engine state is functional), so a
    calibrated solve returns bit-identical iterates.
    """
    local_step = getattr(prog, "local_step", None)
    if local_step is None:
        return None
    state = prog.state
    import jax
    donated = bool(getattr(prog, "donated", False))
    if donated:
        # the overlap engine's jitted step donates its state operand on
        # accelerators; re-stepping from the saved state0 would read
        # freed buffers, so every calibration call gets its own copy
        # (made outside the timed region)
        import jax.numpy as jnp
        copies = [jax.tree_util.tree_map(jnp.copy, state)
                  for _ in range(reps + 1)]
        pool = iter(copies)
        jax.block_until_ready(prog.step(1, next(pool)))   # compile + warm
        step_s = _timeit(lambda: prog.step(1, next(pool)), reps)
    else:
        jax.block_until_ready(prog.step(1, state))        # compile + warm
        step_s = _timeit(lambda: prog.step(1, state), reps)
    jax.block_until_ready(local_step(1, state))
    local_s = _timeit(lambda: local_step(1, state), reps)
    local_frac = min(local_s / step_s, 1.0) if step_s > 0 else 1.0

    acct = getattr(prog, "comm_bytes", None) or {}
    coll = acct.get("collectives", {})
    total_bytes = sum(c["bytes_per_step"] for c in coll.values())
    if coll and total_bytes > 0:
        shares = {name: c["bytes_per_step"] / total_bytes
                  for name, c in coll.items()}
    elif coll:                      # all-zero payloads: split evenly
        shares = {name: 1.0 / len(coll) for name in coll}
    else:
        shares = {}
    return PhaseSplit(local_frac=local_frac, comm_shares=shares,
                      step_s=step_s, local_s=local_s,
                      staleness=int(getattr(prog, "staleness", 0)),
                      overlap=bool(getattr(prog, "overlap", False)))


def bench_codecs(policy, acct: dict, *, reps: int = 3) -> Dict[str, float]:
    """Seconds per encode/decode of each *compressed* collective.

    ``policy`` is a CompressionPolicy (duck-typed: ``codec_for(name)``),
    ``acct`` the program's wire accounting, whose per-collective entries
    carry the payload aval (``payload_shape`` / ``payload_dtype``).
    Identity-codec collectives are skipped (their apply is free).
    """
    import jax
    import jax.numpy as jnp
    out: Dict[str, float] = {}
    for name, cell in acct.get("collectives", {}).items():
        codec = policy.codec_for(name)
        if codec.name == "identity" or "payload_shape" not in cell:
            continue
        x = jnp.zeros(tuple(cell["payload_shape"]),
                      jnp.dtype(cell["payload_dtype"]))
        if codec.stateful:
            err = jnp.zeros(x.shape, jnp.float32)
            fn = jax.jit(lambda v, e, c=codec: c.apply(v, e))
            jax.block_until_ready(fn(x, err))
            out[name] = _timeit(lambda: fn(x, err), reps)
        else:
            fn = jax.jit(lambda v, c=codec: c.apply(v))
            jax.block_until_ready(fn(x))
            out[name] = _timeit(lambda: fn(x), reps)
    return out
