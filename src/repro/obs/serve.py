"""Request-lifecycle metrics for the serving engine, registry-backed.

:class:`RequestMetrics` is the engine-facing API the legacy
``repro.serve.metrics.ServeMetrics`` exposed -- ``start_request`` /
``first_token`` / ``finish`` around the step loop, ``summary()`` at the
end, attribute counters (``preemptions`` / ``rejections`` /
``decode_steps`` / ``prefills``) that the engine bumps with ``+=`` --
now writing every aggregate through a :class:`~repro.obs.metrics.
Registry`, so one ``registry.snapshot()`` carries serving numbers in
the same schema as solver telemetry.

Changes vs the legacy class:

  * the default percentile set gained **p90** (via the registry's
    ``DEFAULT_PERCENTILES``);
  * ``summary()`` **skips unfinished requests** (e.g. preempted and
    never replayed because the trace was cut short) instead of ever
    raising on them, and reports their count as
    ``requests_unfinished``;
  * TTFT / latency observations land in registry histograms
    (``serve/ttft_s``, ``serve/latency_s``) at finish time, so the
    snapshot percentiles match ``summary()`` bit for bit.

The clock stays injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .metrics import Registry, percentiles


@dataclasses.dataclass
class _RequestRecord:
    arrival: float
    n_prompt: int
    first_token: Optional[float] = None
    finish: Optional[float] = None
    n_generated: int = 0


def _counter_property(name: str):
    def get(self):
        return self.registry.counter(name).value

    def set_(self, v):
        self.registry.counter(name).set(v)

    return property(get, set_)


class RequestMetrics:
    """Serving metrics: tokens/s, TTFT, latency percentiles."""

    def __init__(self, clock=time.perf_counter,
                 registry: Optional[Registry] = None):
        self.clock = clock
        self.registry = registry if registry is not None else Registry()
        self._req: Dict[object, _RequestRecord] = {}
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None

    # engine-side "metrics.X += 1" attributes, backed by registry counters
    preemptions = _counter_property("serve/preemptions")
    rejections = _counter_property("serve/rejections")
    decode_steps = _counter_property("serve/decode_steps")
    prefills = _counter_property("serve/prefills")

    # ---- per-request lifecycle ----
    def start_request(self, rid, n_prompt, arrival=None):
        t = self.clock() if arrival is None else arrival
        if self._t0 is None:
            self._t0 = t
        # re-registration after preemption keeps the ORIGINAL arrival
        if rid not in self._req:
            self._req[rid] = _RequestRecord(arrival=t, n_prompt=n_prompt)

    def first_token(self, rid):
        rec = self._req.get(rid)
        if rec is not None and rec.first_token is None:
            rec.first_token = self.clock()

    def finish(self, rid, n_generated):
        rec = self._req.get(rid)
        if rec is None:             # finish without start: count nothing
            return
        rec.finish = self.clock()
        rec.n_generated = n_generated
        self._t1 = rec.finish
        reg = self.registry
        reg.counter("serve/requests_finished").inc()
        reg.counter("serve/generated_tokens").inc(n_generated)
        if rec.first_token is not None:
            reg.histogram("serve/ttft_s").observe(
                rec.first_token - rec.arrival)
        reg.histogram("serve/latency_s").observe(rec.finish - rec.arrival)

    # ---- aggregates ----
    def _done(self) -> List[_RequestRecord]:
        return [r for r in self._req.values() if r.finish is not None]

    @property
    def generated_tokens(self) -> int:
        return sum(r.n_generated for r in self._done())

    @property
    def elapsed(self) -> float:
        if self._t0 is None or self._t1 is None:
            return 0.0
        return max(self._t1 - self._t0, 1e-9)

    def tokens_per_sec(self) -> float:
        return self.generated_tokens / self.elapsed if self._done() else 0.0

    def summary(self) -> dict:
        # unfinished requests (queued, in flight, or preempted and never
        # replayed) are SKIPPED, never raised on -- a cut-short trace
        # must still summarize cleanly
        done = self._done()
        ttft = [r.first_token - r.arrival for r in done
                if r.first_token is not None]
        lat = [r.finish - r.arrival for r in done]
        out = {
            "requests_finished": len(done),
            "requests_unfinished": len(self._req) - len(done),
            "generated_tokens": self.generated_tokens,
            "elapsed_s": self.elapsed,
            "tokens_per_sec": self.tokens_per_sec(),
            "ttft_s": percentiles(ttft),
            "latency_s": percentiles(lat),
            "prefills": int(self.prefills),
            "decode_steps": int(self.decode_steps),
            "preemptions": int(self.preemptions),
            "rejections": int(self.rejections),
        }
        self.registry.gauge("serve/tokens_per_sec").set(
            out["tokens_per_sec"])
        self.registry.gauge("serve/elapsed_s").set(out["elapsed_s"])
        return out
