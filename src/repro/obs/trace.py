"""Structured tracing: nestable spans, Chrome-trace / JSONL exporters.

A :class:`Tracer` records *spans* -- named wall-clock intervals that
nest (outer_iter > step > comm/dalpha ...) -- plus *instant* events.
Design constraints, in order:

  1. **near-zero overhead when disabled**: the module-level
     :data:`NULL_TRACER` hands out one shared no-op span object, so an
     instrumented hot loop costs a method call and an identity check
     per span when tracing is off;
  2. **injectable clock** for deterministic tests (``clock=`` takes any
     ``() -> float`` in seconds);
  3. **thread-safe**: span stacks are per-thread (serving runs the
     engine loop on one thread and callbacks elsewhere), the event list
     is lock-protected;
  4. **post-measured spans**: phase attribution times a jitted step and
     then *synthesizes* child spans inside the measured interval
     (:meth:`Tracer.record`), since nothing can be timed inside an XLA
     computation from the host.

Exports: :meth:`Tracer.to_chrome_trace` produces the Trace Event Format
consumed by ``chrome://tracing`` and https://ui.perfetto.dev (complete
"X" events, microsecond timestamps); :meth:`Tracer.write_jsonl` writes
one JSON object per event for ad-hoc analysis.

Optional ``jax_annotations=True`` additionally enters a
``jax.profiler.TraceAnnotation`` for every live span so the same names
show up inside real device profiles captured with
``jax.profiler.trace``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional


def _jax_annotation(name: str):
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:               # jax absent or profiler API moved
        return None


class _Span:
    """A live span; created by :meth:`Tracer.span`, closed on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr.jax_annotations:
            self._ann = _jax_annotation(self.name)
            if self._ann is not None:
                self._ann.__enter__()
        tr._stack().append(self.name)
        self._t0 = tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        tr = self._tracer
        t1 = tr.clock()
        stack = tr._stack()
        stack.pop()
        tr._push_event(self.name, self._t0, t1 - self._t0, len(stack),
                       self.args)
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        return False


class _NullSpan:
    """Shared no-op context manager handed out by the null tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans and instants; exports Chrome-trace JSON / JSONL.

    Events are dicts ``{name, ts, dur, depth, tid, args}`` with ``ts``
    (seconds since the tracer's epoch -- its construction time under the
    injected clock) and ``dur`` in seconds; instants have ``dur=None``.
    """

    def __init__(self, clock=time.perf_counter, enabled: bool = True,
                 jax_annotations: bool = False):
        self.clock = clock
        self.enabled = enabled
        self.jax_annotations = jax_annotations
        self.events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self.epoch = clock()

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing a named span; ``args`` land in the
        Chrome-trace ``args`` payload."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def record(self, name: str, t0: float, dur: float, **args):
        """Add an already-measured span (``t0`` in this tracer's clock).
        Used to synthesize attribution spans inside a timed interval --
        e.g. per-collective comm spans inside a jitted step."""
        if not self.enabled:
            return
        self._push_event(name, t0, dur, len(self._stack()), args or None)

    def instant(self, name: str, **args):
        """Add a zero-duration marker event at the current clock."""
        if not self.enabled:
            return
        self._push_event(name, self.clock(), None, len(self._stack()),
                         args or None)

    def now(self) -> float:
        return self.clock()

    # -- internals -----------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push_event(self, name, t0, dur, depth, args):
        ev = {"name": name, "ts": t0 - self.epoch,
              "dur": dur, "depth": depth,
              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- export --------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Trace Event Format payload (load in chrome://tracing or
        https://ui.perfetto.dev): complete ``"X"`` events with
        microsecond ``ts``/``dur``, instants as ``"i"`` events."""
        out = []
        with self._lock:
            events = list(self.events)
        for ev in events:
            entry = {"name": ev["name"], "cat": "repro", "pid": 0,
                     "tid": ev["tid"], "ts": ev["ts"] * 1e6}
            if ev["dur"] is None:
                entry["ph"] = "i"
                entry["s"] = "t"
            else:
                entry["ph"] = "X"
                entry["dur"] = ev["dur"] * 1e6
            if "args" in ev:
                entry["args"] = ev["args"]
            out.append(entry)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str):
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(), fh)

    def write_jsonl(self, path: str):
        """One JSON object per event, in recording order."""
        with self._lock:
            events = list(self.events)
        with open(path, "w") as fh:
            for ev in events:
                fh.write(json.dumps(ev) + "\n")

    # -- queries (tests, breakdown summaries) --------------------------------
    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Finished spans (``dur`` is not None), optionally by name."""
        with self._lock:
            events = list(self.events)
        return [e for e in events if e["dur"] is not None
                and (name is None or e["name"] == name)]

    def total(self, name: str) -> float:
        """Sum of durations over all spans with this name."""
        return sum(e["dur"] for e in self.spans(name))


class NullTracer(Tracer):
    """Disabled tracer: every call is a no-op and :meth:`span` returns
    one shared context-manager object (no per-call allocation beyond
    the kwargs machinery), so instrumented code needs no ``if`` guards.
    """

    def __init__(self):
        super().__init__(clock=lambda: 0.0, enabled=False)

    def span(self, name: str, **args):
        return _NULL_SPAN

    def record(self, name: str, t0: float, dur: float, **args):
        pass

    def instant(self, name: str, **args):
        pass


#: the shared disabled tracer -- default for every instrumented code path
NULL_TRACER = NullTracer()


def as_tracer(tracer) -> Tracer:
    """Normalize an optional tracer argument: None -> NULL_TRACER."""
    return NULL_TRACER if tracer is None else tracer
