"""Prometheus text-format rendering of a :class:`Registry` snapshot.

Stdlib-only (the container has no ``prometheus_client``): we emit the
`text exposition format
<https://prometheus.io/docs/instrumenting/exposition_formats/>`_
directly -- ``# TYPE`` headers, label-decorated sample lines, and the
``_count`` / ``_sum`` / quantile triplet per histogram (rendered as a
Prometheus *summary*, the type for client-side quantiles).

Name mapping: registry names are path-like (``solver/step_s``); the
exposition grammar only allows ``[a-zA-Z_:][a-zA-Z0-9_:]*``, so every
illegal character becomes ``_`` (``solver/step_s`` ->
``solver_step_s``).  Registry label syntax (``name{k=v,...}``) is
parsed back out of the snapshot keys and re-emitted as quoted
Prometheus labels.

:func:`parse_prometheus_text` is the inverse used by the smoke tests
(and by anyone without a scraper handy): it validates the grammar line
by line and returns ``{metric_name: {frozenset(labels): value}}``.
"""
from __future__ import annotations

import math
import re
from typing import Dict, Tuple

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_NAME_FIX = re.compile(r"[^a-zA-Z0-9_:]")
_SAMPLE = re.compile(
    r"([a-zA-Z_:][a-zA-Z0-9_:]*)"         # metric name
    r"(?:\{([^}]*)\})?"                   # optional {labels}
    r"\s+(\S+)\s*\Z")                     # value
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"\Z')


def sanitize_name(name: str) -> str:
    """Map a registry metric name onto the Prometheus name grammar."""
    out = _NAME_FIX.sub("_", name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def split_key(key: str) -> Tuple[str, Dict[str, str]]:
    """``"name{k=v,k2=v2}"`` -> ``("name", {"k": "v", "k2": "v2"})``."""
    if not key.endswith("}") or "{" not in key:
        return key, {}
    name, _, inner = key.partition("{")
    labels = {}
    for part in inner[:-1].split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels[k] = v
    return name, labels


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


_UNESCAPE = re.compile(r"\\(.)")


def _unescape(v: str) -> str:
    return _UNESCAPE.sub(
        lambda m: {"n": "\n"}.get(m.group(1), m.group(1)), v)


def _labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{sanitize_name(k)}="{_escape(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _value(v: float) -> str:
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v)


def render_prometheus(snapshot: dict, *, prefix: str = "") -> str:
    """Render a ``Registry.snapshot()`` as Prometheus text format.

    Args:
      snapshot: the ``{"counters": ..., "gauges": ..., "histograms": ...}``
        dict from :meth:`Registry.snapshot`.
      prefix: optional namespace prepended to every metric name
        (``prefix="repro_"`` yields ``repro_solver_step_s``).

    Returns the exposition body, terminated by a newline (required by
    the format for non-empty bodies).
    """
    lines = []

    def header(name, kind):
        lines.append(f"# TYPE {name} {kind}")

    # group label variants under one TYPE header per metric name
    def by_name(section):
        groups: Dict[str, list] = {}
        for key, val in sorted(section.items()):
            name, labels = split_key(key)
            groups.setdefault(prefix + sanitize_name(name), []) \
                  .append((labels, val))
        return groups

    for name, entries in by_name(snapshot.get("counters", {})).items():
        header(name, "counter")
        for labels, val in entries:
            lines.append(f"{name}{_labels(labels)} {_value(val)}")

    for name, entries in by_name(snapshot.get("gauges", {})).items():
        header(name, "gauge")
        for labels, val in entries:
            lines.append(f"{name}{_labels(labels)} {_value(val)}")

    for name, entries in by_name(snapshot.get("histograms", {})).items():
        header(name, "summary")
        for labels, summ in entries:
            for k, v in summ.items():
                if k.startswith("p") and k[1:].isdigit():
                    q = {**labels, "quantile": str(int(k[1:]) / 100.0)}
                    lines.append(f"{name}{_labels(q)} {_value(v)}")
            lines.append(f"{name}_count{_labels(labels)} "
                         f"{_value(summ['count'])}")
            lines.append(f"{name}_sum{_labels(labels)} "
                         f"{_value(summ['sum'])}")

    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus_text(text: str) -> Dict[str, Dict[frozenset, float]]:
    """Parse/validate exposition text; the smoke tests' scraper.

    Returns ``{metric_name: {frozenset(label_pairs): value}}``.

    Raises:
      ValueError: on any line that is neither a comment, blank, nor a
        grammar-conforming sample line.
    """
    out: Dict[str, Dict[frozenset, float]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: not a valid sample: {line!r}")
        name, labelstr, valstr = m.groups()
        labels = {}
        if labelstr:
            for part in _split_labels(labelstr, lineno):
                lm = _LABEL.match(part)
                if lm is None:
                    raise ValueError(
                        f"line {lineno}: bad label pair {part!r}")
                labels[lm.group(1)] = _unescape(lm.group(2))
        try:
            value = float(valstr)
        except ValueError:
            raise ValueError(f"line {lineno}: bad value {valstr!r}")
        out.setdefault(name, {})[frozenset(labels.items())] = value
    return out


def _split_labels(labelstr: str, lineno: int):
    """Split ``k="v",k2="v2"`` on commas outside quotes."""
    parts, buf, in_q, esc = [], [], False, False
    for ch in labelstr:
        if esc:
            buf.append(ch)
            esc = False
        elif ch == "\\":
            buf.append(ch)
            esc = True
        elif ch == '"':
            buf.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if in_q:
        raise ValueError(f"line {lineno}: unterminated label quote")
    if buf:
        parts.append("".join(buf))
    return parts
