"""Flight recorder: a bounded ring-buffer tracer + postmortem bundles.

The PR 6 :class:`~repro.obs.trace.Tracer` accumulates spans without
bound and exports at end-of-run -- right for a batch solve, wrong for
the services that run indefinitely (the online service, the fleet
scheduler, the serve engine).  :class:`FlightRecorder` is the same span
API over a drop-oldest ring buffer: O(capacity) memory forever, cheap
enough to leave on, and always holding the *last* ``capacity`` events
-- the ones that matter when something goes wrong.

Because it subclasses :class:`Tracer`, everything that takes a tracer
(``as_tracer``, ``Solver.solve(tracer=...)``, the serve engine, the
online service) works unchanged; ``to_chrome_trace`` / ``write_jsonl``
export the retained tail.

:meth:`FlightRecorder.dump` writes a **postmortem bundle**: one JSON
file carrying the trace tail (Chrome-trace payload, loadable in
ui.perfetto.dev after extracting the ``trace`` field or via
:func:`load_bundle`), the paired registry's ``snapshot()``, and
provenance (git sha, reason, caller metadata).  Bundles are written

  * explicitly (``recorder.dump(path, reason=...)``),
  * on crash (:meth:`crash_guard` re-raises after dumping), or
  * on a health-rule CRIT transition (see :mod:`repro.obs.health` --
    the monitor fires exactly one dump per OK->CRIT edge).

Writes are atomic (tmp file + rename), so a half-written bundle is
never observed by whatever collects them.
"""
from __future__ import annotations

import collections
import contextlib
import json
import os
import subprocess
import threading
import time
from typing import Optional

from .trace import Tracer

#: bundle schema identifier (bump on incompatible layout changes)
BUNDLE_SCHEMA = "repro.obs.flight_recorder/1"

#: default ring capacity -- at one outer_iter + step + observe + a few
#: comm spans per iteration this holds on the order of the last ~500
#: iterations of a solve, in a few MB of host memory
DEFAULT_CAPACITY = 4096


def _git_sha() -> Optional[str]:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or None
    except Exception:
        return None


class FlightRecorder(Tracer):
    """A :class:`Tracer` over a fixed-capacity drop-oldest ring buffer.

    Args:
      capacity: maximum retained events; the oldest event is dropped
        (and counted in :attr:`dropped`) when a new one arrives at
        capacity.
      clock: injectable clock, as for :class:`Tracer`.
      registry: optional :class:`~repro.obs.metrics.Registry` whose
        ``snapshot()`` is embedded in every bundle.
      meta: JSON-able dict merged into every bundle's ``meta`` block
        (the services stamp their config here).
      jax_annotations: see :class:`Tracer`.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock=time.perf_counter, registry=None, meta=None,
                 jax_annotations: bool = False):
        if capacity < 1:
            raise ValueError(f"recorder capacity must be >= 1, "
                             f"got {capacity}")
        super().__init__(clock=clock, enabled=True,
                         jax_annotations=jax_annotations)
        self.capacity = int(capacity)
        # the ring: deque(maxlen=) drops the oldest entry on append-at-
        # capacity in O(1); every Tracer export/query path copies it
        # under the lock, so they work unchanged
        self.events = collections.deque(maxlen=self.capacity)
        self.dropped = 0
        self.registry = registry
        self.meta = dict(meta or {})
        self.dumps: list = []           # bundle paths written, in order

    # -- recording -----------------------------------------------------------
    def _push_event(self, name, t0, dur, depth, args):
        ev = {"name": name, "ts": t0 - self.epoch,
              "dur": dur, "depth": depth,
              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            if len(self.events) == self.capacity:
                self.dropped += 1
            self.events.append(ev)

    # -- postmortem bundles --------------------------------------------------
    def bundle(self, reason: str = "manual") -> dict:
        """The postmortem payload as a plain JSON-able dict."""
        with self._lock:
            dropped, retained = self.dropped, len(self.events)
        return {
            "schema": BUNDLE_SCHEMA,
            "reason": reason,
            "meta": {"git_sha": _git_sha(),
                     "written_at": time.time(), **self.meta},
            "capacity": self.capacity,
            "retained_events": retained,
            "dropped_events": dropped,
            "trace": self.to_chrome_trace(),
            "metrics": (self.registry.snapshot()
                        if self.registry is not None else None),
        }

    def dump(self, path: str, reason: str = "manual") -> str:
        """Write the bundle to ``path`` atomically; returns ``path``."""
        payload = self.bundle(reason)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = os.path.join(d, f".{os.path.basename(path)}.tmp")
        with open(tmp, "w") as fh:
            json.dump(payload, fh)
        os.replace(tmp, path)
        self.dumps.append(path)
        return path

    @contextlib.contextmanager
    def crash_guard(self, path: str):
        """Context manager that dumps a bundle when the body raises
        (reason ``crash:<ExcType>``) and re-raises -- wrap a service's
        main loop in it so the trace tail survives the crash."""
        try:
            yield self
        except BaseException as e:
            try:
                self.dump(path, reason=f"crash:{type(e).__name__}")
            except Exception:
                pass                # never mask the original failure
            raise


def load_bundle(path: str) -> dict:
    """Load and validate a postmortem bundle.

    Checks the schema tag and that the embedded trace is a well-formed
    Chrome-trace payload (the same structure ``chrome://tracing`` /
    Perfetto consume: a ``traceEvents`` list of ``"X"``/``"i"`` events
    with microsecond timestamps).

    Raises:
      ValueError: on a missing/foreign schema tag or a malformed trace.
    """
    with open(path) as fh:
        payload = json.load(fh)
    if payload.get("schema") != BUNDLE_SCHEMA:
        raise ValueError(f"{path}: not a flight-recorder bundle "
                         f"(schema={payload.get('schema')!r}, expected "
                         f"{BUNDLE_SCHEMA!r})")
    trace = payload.get("trace")
    if not isinstance(trace, dict) or \
            not isinstance(trace.get("traceEvents"), list):
        raise ValueError(f"{path}: bundle trace is not a Chrome-trace "
                         "payload (no traceEvents list)")
    for ev in trace["traceEvents"]:
        if ev.get("ph") not in ("X", "i"):
            raise ValueError(f"{path}: unexpected trace event phase "
                             f"{ev.get('ph')!r}")
        missing = {"name", "pid", "tid", "ts"} - set(ev)
        if missing:
            raise ValueError(f"{path}: trace event missing {missing}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"{path}: complete event without dur")
    return payload
