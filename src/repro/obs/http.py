"""Stdlib-only background HTTP endpoint for the observability plane.

:class:`ObsServer` serves three read-only endpoints off a daemon
thread (``http.server.ThreadingHTTPServer`` -- no third-party deps):

  * ``/metrics`` -- the registry rendered as Prometheus text format
    (:func:`~repro.obs.export.render_prometheus`); scrape it with any
    Prometheus-compatible collector.
  * ``/healthz`` -- JSON verdict from the attached
    :class:`~repro.obs.health.HealthMonitor`; HTTP 200 when OK/WARN,
    **503** when CRIT (so load balancers and probes need no body
    parsing).  Without a monitor it reports ``{"status": "ok"}``.
  * ``/varz`` -- the raw ``Registry.snapshot()`` as JSON plus server
    metadata (uptime, recorder occupancy) for humans with ``curl``.

The handler only *reads* (snapshot / evaluate); the solver and service
threads never block on a scrape beyond the registry's per-metric
locks, which is why the live-endpoint test can demand bit-identical
solve results with the endpoint on vs off.

Bind with ``port=0`` to let the OS pick (tests do); the resolved port
is on :attr:`ObsServer.port` after :meth:`start`.
"""
from __future__ import annotations

import http.server
import json
import threading
import time
from typing import Optional

from .export import render_prometheus

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ObsServer:
    """Background HTTP server exposing a registry (+ optional monitor).

    Args:
      registry: the :class:`~repro.obs.metrics.Registry` to expose.
      monitor: optional :class:`~repro.obs.health.HealthMonitor`; its
        (rate-limited) evaluation runs on each ``/healthz`` hit.
      recorder: optional :class:`~repro.obs.recorder.FlightRecorder`;
        surfaces ring occupancy on ``/varz``.
      host/port: bind address; ``port=0`` -> ephemeral.
      prefix: Prometheus metric-name prefix (see ``render_prometheus``).
    """

    def __init__(self, registry, *, monitor=None, recorder=None,
                 host: str = "127.0.0.1", port: int = 0,
                 prefix: str = ""):
        self.registry = registry
        self.monitor = monitor
        self.recorder = recorder
        self.host = host
        self.port = int(port)
        self.prefix = prefix
        self._httpd: Optional[http.server.ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._started_at: Optional[float] = None

    # ------------------------------------------------------------------
    def start(self) -> "ObsServer":
        """Bind and start serving on a daemon thread; returns self."""
        if self._httpd is not None:
            return self
        obs = self

        class Handler(http.server.BaseHTTPRequestHandler):
            # requests are short and read-only; keep stderr quiet
            def log_message(self, fmt, *args):
                pass

            def _send(self, code, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        body = render_prometheus(
                            obs.registry.snapshot(),
                            prefix=obs.prefix).encode()
                        self._send(200, body, PROM_CONTENT_TYPE)
                    elif path == "/healthz":
                        payload, code = obs._healthz()
                        self._send(code, json.dumps(payload).encode(),
                                   "application/json")
                    elif path in ("/varz", "/"):
                        self._send(200,
                                   json.dumps(obs._varz()).encode(),
                                   "application/json")
                    else:
                        self._send(404, b'{"error": "not found"}',
                                   "application/json")
                except BrokenPipeError:      # scraper went away mid-write
                    pass
                except Exception as e:       # never kill the serving thread
                    try:
                        self._send(500,
                                   json.dumps({"error": repr(e)}).encode(),
                                   "application/json")
                    except Exception:
                        pass

        self._httpd = http.server.ThreadingHTTPServer(
            (self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._started_at = time.monotonic()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.1},
            name="obs-http", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._httpd = self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ------------------------------------------------------------------
    def _healthz(self):
        if self.monitor is None:
            return {"status": "ok", "rules": {}}, 200
        self.monitor.poll()
        payload = self.monitor.healthz(evaluate=False)
        code = 503 if payload["status"] == "crit" else 200
        return payload, code

    def _varz(self) -> dict:
        out = {
            "uptime_s": (time.monotonic() - self._started_at
                         if self._started_at is not None else 0.0),
            "metrics": self.registry.snapshot(),
        }
        if self.recorder is not None:
            out["recorder"] = {
                "capacity": self.recorder.capacity,
                "retained": len(self.recorder.events),
                "dropped": self.recorder.dropped,
                "dumps": list(self.recorder.dumps),
            }
        if self.monitor is not None:
            out["health"] = self.monitor.healthz(evaluate=False)
        return out
