"""FleetScheduler: admission, shape bucketing, per-tenant unpacking.

The scheduler is the multi-tenant front door: tenants ``submit()``
problems of any shape; ``run()`` groups the queue into shape buckets
(:func:`~repro.fleet.batch.bucket_key`), caps each batch at
``max_tenants``, drives every batch through one
:class:`~repro.fleet.solver.FleetSolver` call, and hands back results
keyed by tenant id.  A per-tenant warm-start registry carries each
tenant's last iterates into its next submission (same semantics as
passing ``warm_start=previous_result`` to the solo API).

Retracing is bounded by the number of distinct (bucket, batch-size)
pairs -- NOT by the number of tenants: every batch of the same padded
shapes and tenant count reuses the compiled step.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.solver import SolveResult

from .batch import FleetProblem, bucket_key
from .solver import FleetSolver


class FleetScheduler:
    """Admission queue + bucketed batched execution.

    Args:
      P, Q: the block grid every batch runs on.
      solver, engine, local_backend, block_format: forwarded to
        :class:`FleetSolver`.
      cfg: shared solver config template (per-tenant ``lam`` / ``seed``
        come from each problem).
      tol, check_every: per-tenant convergence policy (see
        :meth:`FleetSolver.solve_batch`).
      max_tenants: cap on tenants per batched solve; a larger bucket is
        split into chunks of this size (None = unbounded).
      warm_registry: keep each tenant's last result and warm-start its
        next submission from it.
      on_result: optional callback ``on_result(tenant_id, result)``
        fired per tenant as each batch completes (the online publishing
        hook -- see ``repro/launch/fleet.py``).
      tracer, registry: :mod:`repro.obs` hooks, forwarded per batch;
        the scheduler adds per-bucket ``fleet/bucket_tenants`` gauges.
      monitor: a :class:`repro.obs.HealthMonitor`; polled after each
        bucket's gauges land and once per drained batch, so bucket
        starvation / divergence verdicts track the live queue.
    """

    def __init__(self, *, P: int, Q: int, solver: str = "d3ca",
                 engine: str = "simulated", local_backend: str = "ref",
                 block_format: str = "dense", cfg=None,
                 tol: Optional[float] = None, check_every: int = 5,
                 max_tenants: Optional[int] = None,
                 warm_registry: bool = True,
                 on_result: Optional[Callable[[str, SolveResult], None]]
                 = None,
                 tracer=None, registry=None, monitor=None):
        self.P, self.Q = P, Q
        self.fleet = FleetSolver(solver=solver, engine=engine,
                                 local_backend=local_backend,
                                 block_format=block_format)
        self.cfg = cfg
        self.tol = tol
        self.check_every = check_every
        self.max_tenants = max_tenants
        self.warm_registry = warm_registry
        self.on_result = on_result
        self.tracer = tracer
        self.registry = registry
        self.monitor = monitor
        self._queue: List[FleetProblem] = []
        self._warm: Dict[str, SolveResult] = {}

    # ------------------------------------------------------------------

    def submit(self, problem: FleetProblem) -> str:
        """Queue one tenant's problem; returns its tenant id."""
        self._queue.append(problem)
        return problem.tenant_id

    def pending(self) -> int:
        return len(self._queue)

    def buckets(self) -> Dict[Tuple, List[FleetProblem]]:
        """The queued problems grouped by shape bucket (insertion
        order preserved within each bucket)."""
        groups: Dict[Tuple, List[FleetProblem]] = collections.OrderedDict()
        for p in self._queue:
            groups.setdefault(bucket_key(p, self.P, self.Q), []).append(p)
        return groups

    def warm_start_of(self, tenant_id: str) -> Optional[SolveResult]:
        return self._warm.get(tenant_id)

    # ------------------------------------------------------------------

    def _chunks(self, probs: Sequence[FleetProblem]):
        cap = self.max_tenants
        if cap is None or cap >= len(probs):
            yield list(probs)
            return
        for lo in range(0, len(probs), cap):
            yield list(probs[lo:lo + cap])

    def run(self) -> Dict[str, SolveResult]:
        """Drain the queue: one batched solve per (bucket, chunk).

        Returns results keyed by tenant id, in submission order.
        """
        results: Dict[str, SolveResult] = collections.OrderedDict()
        groups = self.buckets()
        self._queue = []
        for key, probs in groups.items():
            if self.registry is not None:
                self.registry.gauge(
                    "fleet/bucket_tenants", bucket="/".join(map(str, key)),
                    solver=self.fleet.solver,
                    engine=self.fleet.engine).set(len(probs))
            for chunk in self._chunks(probs):
                warm = ([self._warm.get(p.tenant_id) for p in chunk]
                        if self.warm_registry else None)
                batch = self.fleet.solve_batch(
                    chunk, P=self.P, Q=self.Q, cfg=self.cfg,
                    tol=self.tol, check_every=self.check_every,
                    warm_starts=warm, tracer=self.tracer,
                    registry=self.registry)
                for p, res in zip(chunk, batch):
                    if self.warm_registry:
                        self._warm[p.tenant_id] = res
                    results[p.tenant_id] = res
                    if self.on_result is not None:
                        self.on_result(p.tenant_id, res)
                if self.monitor is not None:
                    self.monitor.poll()
        ordered: Dict[str, SolveResult] = collections.OrderedDict()
        for key in results:
            ordered[key] = results[key]
        return ordered
