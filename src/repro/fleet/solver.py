"""FleetSolver: T tenants, one compiled step, per-tenant results.

Packs a shape bucket of :class:`~repro.fleet.batch.FleetProblem`\\ s
into tenant-major arrays (:func:`~repro.fleet.batch.stack_grid` /
:func:`~repro.fleet.batch.stack_mesh`), wraps the solver's per-problem
:class:`~repro.core.engines.CellProgram` with
:func:`~repro.fleet.batch.fleet_cell_program`, and drives the batched
program through the *existing* executors
(:func:`~repro.core.engines.grid_program` /
:func:`~repro.core.engines.mesh_program`) -- no new execution machinery.

Per-tenant semantics preserved relative to a solo
:meth:`repro.core.solver.Solver.solve` of the same problem:

  * block extents, padding, and every PRNG draw are identical (the
    bucket key uses the framework's natural padded shapes);
  * ``lam_t`` / ``n_t`` ride through the data tuple (the solvers'
    ``per_problem=True`` path) instead of being baked into the trace;
  * converged tenants are frozen *exactly* (state carried through
    ``jnp.where``) at segment boundaries (every ``check_every`` outer
    iterations), and warm starts accept the same
    ``SolveResult | (w, alpha) | w`` forms as the solo API.

Bit-equivalence caveat: XLA strength-reduces division by a
compile-time constant into multiplication by its reciprocal.  The solo
path bakes ``lam * n`` (and ``n * sample_frac``, ``rho * n``) as
constants, the fleet path divides by the same values as traced
scalars, so per-tenant results are bit-identical exactly when those
products are powers of two and agree to float tolerance otherwise
(docs/consistency.md, tests/test_fleet.py).

Engine restriction: the fleet path supports the ``simulated`` grid and
the synchronous ``shard_map`` mesh.  Staleness rings, the overlap
engine's donated buffers and compression error-feedback all carry
per-build device state that cannot hold a tenant axis; requesting them
raises ``ValueError`` up front.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admm import (admm_cell_program, admm_setup_distributed,
                             admm_setup_distributed_sparse,
                             admm_setup_simulated)
from repro.core.d3ca import d3ca_cell_program
from repro.core.engines import cached_build, grid_program, mesh_program
from repro.core.losses import get_loss
from repro.core.partition import (SparseDoublyPartitioned, _ceil_to,
                                  partition, partition_sparse)
from repro.core.radisa import radisa_cell_program
from repro.core.reference import rel_opt
from repro.core.sfk import sfk_cell_program
from repro.core.solver import (BLOCK_FORMATS, ENGINE_ALIASES, LOCAL_BACKENDS,
                               SolveResult, _unpack_warm_start)

from .batch import FleetProblem, bucket_key, fleet_cell_program, stack_grid

#: engines the fleet path supports (``"sync"`` aliases ``"shard_map"``)
FLEET_ENGINES = ("simulated", "shard_map")
FLEET_SOLVERS = ("d3ca", "radisa", "sfk", "admm")


@dataclasses.dataclass
class _Packed:
    """One packed batch, ready to drive."""

    step: Callable          # step(t, (active, *data_core), state)
    data_core: Tuple        # tenant-stacked data tuple (minus active)
    state: Any              # tenant-stacked engine state
    unpack: Callable        # state -> (ws, alphas | None) per tenant
    n_tenants: int


class FleetSolver:
    """Batched multi-tenant solves over one P x Q grid.

    Args:
      solver: one of ``d3ca | radisa | sfk | admm``.
      engine: ``simulated`` (vmap grid) or ``shard_map``/``sync`` (one
        block per device).  Async/overlap/compression/topology are
        rejected -- see the module docstring.
      local_backend, block_format: as in :class:`repro.core.solver.Solver`.
    """

    def __init__(self, solver: str = "d3ca", engine: str = "simulated",
                 local_backend: str = "ref", block_format: str = "dense",
                 staleness: int = 0, compression=None, topology=None,
                 overlap: bool = False):
        if solver not in FLEET_SOLVERS:
            raise ValueError(f"solver={solver!r}; expected one of "
                             f"{FLEET_SOLVERS}")
        engine = ENGINE_ALIASES.get(engine, engine)
        if engine not in FLEET_ENGINES:
            raise ValueError(
                f"engine={engine!r}: the fleet path runs the simulated "
                f"grid or the synchronous mesh ({FLEET_ENGINES}); "
                "async/overlap programs carry per-build ring state that "
                "cannot hold a tenant axis")
        if staleness:
            raise ValueError("fleet solves are synchronous; staleness="
                             f"{staleness} is not supported")
        if compression is not None or topology is not None or overlap:
            raise ValueError("fleet solves do not support compression, "
                             "topology or overlap: their error-feedback/"
                             "ring buffers are per-build device state "
                             "with no tenant axis")
        if local_backend not in LOCAL_BACKENDS:
            raise ValueError(f"local_backend={local_backend!r}; expected "
                             f"one of {LOCAL_BACKENDS}")
        if block_format not in BLOCK_FORMATS:
            raise ValueError(f"block_format={block_format!r}; expected "
                             f"one of {BLOCK_FORMATS}")
        self.solver = solver
        self.engine = engine
        self.local_backend = local_backend
        self.block_format = block_format
        # jitted batched steps, keyed on (engine, grid, padded shapes,
        # tenant count, loss, cfg-sans-outer_iters): repeated batches of
        # one shape bucket reuse the compiled program -- retracing is
        # bounded by the number of distinct buckets, not solve calls
        self._prog_cache: Dict = {}

    def _prog_key(self, kind, P, Q, T, loss, cfg, *shape_bits):
        return (kind, P, Q, T, loss.name,
                dataclasses.replace(cfg, outer_iters=0),
                self.local_backend, self.block_format) + shape_bits

    # ------------------------------------------------------------------
    # shared pieces
    # ------------------------------------------------------------------

    def _config(self, cfg):
        from repro.core.solver import get_solver
        cls = get_solver(self.solver)
        return cfg if cfg is not None else cls.config_cls()

    def _cell_program(self, loss, cfg, *, n, n_p, m_q, sparse):
        kw = dict(n=n, n_p=n_p, m_q=m_q, sparse=sparse,
                  local_backend=self.local_backend, per_problem=True)
        if self.solver == "d3ca":
            return d3ca_cell_program(loss, cfg, **kw)
        if self.solver == "radisa":
            return radisa_cell_program(loss, cfg, **kw)
        if self.solver == "sfk":
            return sfk_cell_program(loss, cfg, **kw)
        return admm_cell_program(loss.name, cfg, n=n, m_q=m_q,
                                 sparse=sparse, per_problem=True)

    @staticmethod
    def _repad_k(part: SparseDoublyPartitioned, k: int):
        """Zero-pad a sparse part's ELL slot axis to a common k.

        Padding slots are (col=0, val=0.0): every consumer gathers
        (reads of w[0] scaled by 0.0) or scatter-ADDs (zero increments),
        so a larger k never changes a result bit.
        """
        if part.k == k:
            return part
        pad = ((0, 0), (0, 0), (0, 0), (0, k - part.k))
        return dataclasses.replace(part, cols=jnp.pad(part.cols, pad),
                                   vals=jnp.pad(part.vals, pad))

    @staticmethod
    def _keys(problems):
        return jnp.stack([jax.random.PRNGKey(p.seed) for p in problems])

    @staticmethod
    def _scalars(problems, parts):
        lam = jnp.asarray([p.lam for p in problems], jnp.float32)
        n = jnp.asarray([float(pt.n) for pt in parts], jnp.float32)
        return lam, n

    # ------------------------------------------------------------------
    # grid packing
    # ------------------------------------------------------------------

    def _pack_grid(self, problems, P, Q, cfg, loss, w0s, a0s) -> _Packed:
        sparse = self.block_format == "sparse"
        if sparse:
            parts = [partition_sparse(p.X, p.y, P, Q, m_multiple=P * Q)
                     for p in problems]
            kmax = max(pt.k for pt in parts)
            parts = [self._repad_k(pt, kmax) for pt in parts]
            x_st = (stack_grid([pt.cols for pt in parts],
                               ("data", "model")),
                    stack_grid([pt.vals for pt in parts],
                               ("data", "model")))
        else:
            parts = [partition(p.X, p.y, P, Q, m_multiple=P * Q)
                     for p in problems]
            x_st = (stack_grid([pt.x_blocks for pt in parts],
                               ("data", "model")),)
        y_st = stack_grid([pt.y_blocks for pt in parts], ("data",))
        mask_st = stack_grid([pt.mask for pt in parts], ("data",))
        lam_arr, n_arr = self._scalars(problems, parts)
        n_p, m_q = parts[0].n_p, parts[0].m_q

        base = self._cell_program(loss, cfg, n=parts[0].n, n_p=n_p,
                                  m_q=m_q, sparse=sparse)
        key = self._prog_key("grid", P, Q, len(problems), loss, cfg,
                             parts[0].n, n_p, m_q,
                             kmax if sparse else None)
        step = cached_build(
            self._prog_cache, key,
            lambda: grid_program(fleet_cell_program(base), P, Q))

        w_st = stack_grid(
            [jnp.zeros((Q, m_q)) if w is None
             else parts[i].w_to_blocks(jnp.asarray(w))
             for i, w in enumerate(w0s)], ("model",))

        if self.solver == "d3ca":
            data_core = (self._keys(problems), *x_st, y_st, mask_st,
                         lam_arr, n_arr)
            a_st = stack_grid(
                [jnp.zeros((P, n_p)) if a is None
                 else parts[i].alpha_to_blocks(jnp.asarray(a))
                 for i, a in enumerate(a0s)], ("data",))
            state = (a_st, w_st)

            def unpack(s):
                a_b, w_b = s
                ws = [parts[i].w_from_blocks(w_b[:, i])
                      for i in range(len(parts))]
                alphas = [parts[i].alpha_from_blocks(
                    a_b[:, i] * parts[i].mask) for i in range(len(parts))]
                return ws, alphas
        elif self.solver == "admm":
            chols = [admm_setup_simulated(
                parts[i], dataclasses.replace(cfg, lam=p.lam))
                for i, p in enumerate(problems)]
            chol_st = stack_grid([c[:, None] for c in chols], ("model",))
            data_core = (*x_st, y_st, mask_st, chol_st, n_arr)
            zeros_su = jnp.zeros((P, Q, len(problems), n_p, 1))
            state = (zeros_su, zeros_su, w_st)

            def unpack(s):
                w_b = s[2]
                return [parts[i].w_from_blocks(w_b[:, i])
                        for i in range(len(parts))], None
        else:
            data_core = (self._keys(problems), *x_st, y_st, mask_st,
                         lam_arr, n_arr)
            state = w_st

            def unpack(s):
                return [parts[i].w_from_blocks(s[:, i])
                        for i in range(len(parts))], None

        return _Packed(step=step, data_core=data_core, state=state,
                       unpack=unpack, n_tenants=len(problems))

    # ------------------------------------------------------------------
    # mesh packing
    # ------------------------------------------------------------------

    def _pack_mesh(self, problems, P, Q, cfg, loss, w0s, a0s) -> _Packed:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS

        from repro.launch.mesh import make_grid_mesh

        mesh = make_grid_mesh(P, Q)

        def put(arr, *axes):
            return jax.device_put(jnp.asarray(arr),
                                  NamedSharding(mesh, PS(*axes)))

        sparse = self.block_format == "sparse"
        T = len(problems)
        n_pads = {_ceil_to(p.n, P) for p in problems}
        m_pads = {_ceil_to(p.m, P * Q) for p in problems}
        if len(n_pads) != 1 or len(m_pads) != 1:
            raise ValueError("solve_batch needs a single shape bucket; "
                             "route mixed shapes through FleetScheduler")
        n_pad, m_pad = n_pads.pop(), m_pads.pop()
        n_p, m_q = n_pad // P, m_pad // Q

        if sparse:
            # identical host-side bucketing to partition_sparse, then the
            # same (P,Q,n_p,k)->(n_pad, Q*k) layout prepare_shard_map_sparse
            # uses -- bit-for-bit the blocks a solo mesh solve sees.
            parts = [partition_sparse(p.X, p.y, P, Q, m_multiple=P * Q)
                     for p in problems]
            kmax = max(pt.k for pt in parts)
            parts = [self._repad_k(pt, kmax) for pt in parts]

            def flat(a):
                return jnp.transpose(a, (0, 2, 1, 3)).reshape(
                    P * n_p, Q * kmax)
            cols_st = put(jnp.stack([flat(pt.cols) for pt in parts]),
                          None, "data", "model")
            vals_st = put(jnp.stack([flat(pt.vals) for pt in parts]),
                          None, "data", "model")
            x_st = (cols_st, vals_st)
        else:
            parts = [partition(p.X, p.y, P, Q, m_multiple=P * Q)
                     for p in problems]
            xs = np.zeros((T, n_pad, m_pad), np.float32)
            for i, p in enumerate(problems):
                xs[i, : p.n, : p.m] = np.asarray(p.X, np.float32)
            x_st = (put(xs, None, "data", "model"),)

        ys = np.zeros((T, n_pad), np.float32)
        masks = np.zeros((T, n_pad), np.float32)
        for i, p in enumerate(problems):
            ys[i, : p.n] = np.asarray(p.y, np.float32)
            masks[i, : p.n] = 1.0
        y_st = put(ys, None, "data")
        mask_st = put(masks, None, "data")
        lam_arr, n_arr = self._scalars(problems, parts)

        base = self._cell_program(loss, cfg, n=problems[0].n, n_p=n_p,
                                  m_q=m_q, sparse=sparse)
        cellprog = fleet_cell_program(base)

        def pad_stack(vals, pad_to, axes):
            out = np.zeros((T, pad_to), np.float32)
            for i, v in enumerate(vals):
                if v is not None:
                    v = np.asarray(v, np.float32)
                    out[i, : v.shape[0]] = v
            return put(out, None, axes)

        w_init = pad_stack(w0s, m_pad, "model")

        if self.solver == "d3ca":
            mdata = (self._keys(problems), *x_st, y_st, mask_st,
                     lam_arr, n_arr)
            core0 = (pad_stack(a0s, n_pad, "data"), w_init)
        elif self.solver == "admm":
            chols = []
            for i, p in enumerate(problems):
                cfg_t = dataclasses.replace(cfg, lam=p.lam)
                if sparse:
                    chols.append(admm_setup_distributed_sparse(
                        mesh, x_st[0][i], x_st[1][i], m_q, cfg_t))
                else:
                    chols.append(admm_setup_distributed(
                        mesh, x_st[0][i], cfg_t))
            chol_st = put(jnp.stack(chols), None, "model")
            mdata = (*x_st, y_st, mask_st, chol_st, n_arr)
            zeros_su = put(np.zeros((T, n_pad, Q), np.float32),
                           None, "data", "model")
            core0 = (zeros_su, zeros_su, w_init)
        else:
            mdata = (self._keys(problems), *x_st, y_st, mask_st,
                     lam_arr, n_arr)
            core0 = w_init

        active0 = jnp.ones((T,), jnp.float32)
        key = self._prog_key("mesh", P, Q, T, loss, cfg, n_pad, m_pad,
                             kmax if sparse else None)
        step, comm0 = cached_build(
            self._prog_cache, key,
            lambda: mesh_program(
                cellprog, mesh, (active0, *mdata), core0,
                data_axis="data", model_axis="model", staleness=0,
                compression=None, overlap=False, topology=None)[:2])
        state = (core0, comm0)

        if self.solver == "d3ca":
            def unpack(s):
                a, w = s[0]
                return ([w[i, : problems[i].m] for i in range(T)],
                        [a[i, : problems[i].n] for i in range(T)])
        elif self.solver == "admm":
            def unpack(s):
                w = s[0][2]
                return [w[i, : problems[i].m] for i in range(T)], None
        else:
            def unpack(s):
                w = s[0]
                return [w[i, : problems[i].m] for i in range(T)], None

        return _Packed(step=step, data_core=mdata, state=state,
                       unpack=unpack, n_tenants=T)

    # ------------------------------------------------------------------
    # the batched drive loop
    # ------------------------------------------------------------------

    def solve_batch(self, problems: Sequence[FleetProblem], *,
                    P: int, Q: int, cfg=None,
                    tol: Optional[float] = None, check_every: int = 5,
                    warm_starts: Optional[Sequence] = None,
                    record_history: bool = True,
                    tracer=None, registry=None) -> List[SolveResult]:
        """Solve every problem of one shape bucket in a single batched run.

        Args:
          problems: tenants of ONE shape bucket (same loss, same padded
            shapes -- :func:`~repro.fleet.batch.bucket_key`); mixed
            shapes go through :class:`~repro.fleet.scheduler.FleetScheduler`.
          P, Q: the block grid.
          cfg: the shared solver config; its ``lam`` (and ``seed``) are
            overridden per tenant by each problem's values.
          tol: per-tenant early stopping, evaluated every
            ``check_every`` outer iterations with the solo driver's
            metric preference (rel_opt vs ``f_star``, duality gap,
            relative objective change).  Converged tenants freeze
            exactly; the batch stops early when all are frozen.
          check_every: segment length between convergence checks.
          warm_starts: optional per-tenant ``SolveResult | (w, alpha) |
            w`` (None entries cold-start).
          record_history: collect per-tenant history entries at segment
            boundaries.
          tracer / registry: :mod:`repro.obs` hooks -- spans
            ``fleet/pack``, ``fleet/step``, ``fleet/unpack``; gauges
            ``fleet/tenants``, ``fleet/active``, per-tenant
            ``fleet/rel_opt``.

        Returns:
          One :class:`~repro.core.solver.SolveResult` per problem, in
          input order.
        """
        from repro.obs import as_tracer
        if not problems:
            return []
        keys = {bucket_key(p, P, Q) for p in problems}
        if len(keys) != 1:
            raise ValueError(
                f"solve_batch got {len(keys)} shape buckets {sorted(keys)}; "
                "pack one bucket per batch (FleetScheduler does this)")
        tr = as_tracer(tracer)
        reg = registry
        cfg = self._config(cfg)
        loss = get_loss(problems[0].loss_name)
        check_every = max(1, int(check_every))
        T = len(problems)
        labels = {"solver": self.solver, "engine": self.engine}

        warm = list(warm_starts) if warm_starts is not None else [None] * T
        if len(warm) != T:
            raise ValueError(f"warm_starts has {len(warm)} entries for "
                             f"{T} problems")
        w0s, a0s = zip(*[_unpack_warm_start(w) for w in warm])

        with tr.span("fleet/pack", tenants=T, **labels):
            pack = (self._pack_grid if self.engine == "simulated"
                    else self._pack_mesh)
            packed = pack(problems, P, Q, cfg, loss, list(w0s), list(a0s))
        if reg is not None:
            reg.gauge("fleet/tenants", **labels).set(T)

        active = np.ones((T,), np.float32)
        conv = [False] * T
        iters = [0] * T
        hist: List[List[Dict[str, float]]] = [[] for _ in range(T)]
        prev_f: List[Optional[float]] = [None] * T
        state = packed.state
        outer = cfg.outer_iters
        # with no early stopping and no history there is nothing to
        # observe between segments: run the whole batch in one stretch
        # (matching the solo driver, which also skips per-iteration
        # objective evaluation in that mode)
        observe = tol is not None or record_history
        t = 0
        t0 = time.perf_counter()
        while t < outer:
            seg_end = outer if not observe else min(t + check_every, outer)
            data = (jnp.asarray(active), *packed.data_core)
            with tr.span("fleet/step", t0=t + 1, t1=seg_end, **labels):
                while t < seg_end:
                    t += 1
                    state = packed.step(t, data, state)
            for i in range(T):
                if not conv[i]:
                    iters[i] = t
            if not observe:
                continue
            with tr.span("fleet/unpack", **labels):
                ws, alphas = packed.unpack(state)
            now = time.perf_counter() - t0
            for i, p in enumerate(problems):
                if conv[i]:
                    continue        # frozen: state is bit-preserved
                iters[i] = t
                f = float(loss.objective(p.X, p.y, ws[i], p.lam))
                entry = {"iter": t, "time_s": now, "objective": f}
                if alphas is not None:
                    entry["duality_gap"] = float(
                        f - loss.dual_objective(p.X, p.y, alphas[i], p.lam))
                if p.f_star is not None:
                    entry["rel_opt"] = float(rel_opt(f, p.f_star))
                    if reg is not None:
                        reg.gauge("fleet/rel_opt", tenant=p.tenant_id,
                                  **labels).set(entry["rel_opt"])
                if record_history:
                    hist[i].append(entry)
                stop = False
                if tol is not None:
                    if "rel_opt" in entry:
                        stop = entry["rel_opt"] < tol
                    elif "duality_gap" in entry:
                        stop = entry["duality_gap"] < tol
                    elif prev_f[i] is not None:
                        stop = abs(f - prev_f[i]) <= tol * max(1.0, abs(f))
                prev_f[i] = f
                if stop:
                    conv[i] = True
                    active[i] = 0.0
            if reg is not None:
                reg.gauge("fleet/active", **labels).set(float(active.sum()))
            if tol is not None and not active.any():
                break

        ws, alphas = packed.unpack(state)
        return [SolveResult(
            w=ws[i], alpha=alphas[i] if alphas is not None else None,
            history=hist[i], iters=iters[i], converged=conv[i],
            solver=self.solver, engine=self.engine,
            local_backend=self.local_backend,
            block_format=self.block_format)
            for i in range(T)]
