"""Batching T independent problems into one tenant-major program.

Three pieces live here, all engine-agnostic:

  * :class:`FleetProblem` / :func:`bucket_key` -- the admission unit and
    the shape-bucket rule.  Problems whose *padded* grid shapes agree
    (same loss, same ``ceil_to(n, P)``, same ``ceil_to(m, P*Q)``) pack
    into one batch; retracing is therefore bounded by the number of
    distinct buckets, not the number of tenants.  The bucket key uses
    the natural padded shapes of the solver framework, so a tenant's
    block extents (``n_p``, ``m_q``) -- and with them every PRNG draw --
    are identical inside the fleet and in a solo
    :meth:`~repro.core.solver.Solver.solve` of the same problem.
  * :func:`with_tenant` / :func:`fleet_cell_program` -- the spec
    transform and cell wrapper that vmap an existing per-problem
    :class:`~repro.core.engines.CellProgram` over a leading tenant axis
    inside each P x Q cell.
  * :func:`stack_grid` / :func:`stack_mesh` -- where the tenant axis
    lands in the packed arrays under each engine's layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engines import CellProgram, _is_dimspec
from repro.core.partition import _ceil_to


@dataclasses.dataclass(frozen=True)
class FleetProblem:
    """One tenant's problem: data, loss, regularizer, seed.

    ``lam`` and ``seed`` are per-tenant (they ride through the packed
    arrays); every other solver knob comes from the shared config of the
    batch.  ``f_star`` (optional) enables the per-tenant ``rel_opt``
    history field and rel-opt early stopping, exactly as in
    :meth:`repro.core.solver.Solver.solve`.
    """

    tenant_id: str
    loss_name: str
    X: Any                      # (n, m) array or CSRMatrix
    y: Any                      # (n,)
    lam: float
    seed: int = 0
    f_star: Optional[float] = None

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    @property
    def m(self) -> int:
        return int(self.X.shape[1])


def bucket_key(problem: FleetProblem, P: int, Q: int) -> Tuple:
    """Shape-bucket key: problems with equal keys pack into one batch.

    Uses the framework's natural padded shapes (rows to a multiple of P,
    features to a multiple of P*Q), so bucketing never changes a
    tenant's block extents relative to its solo solve.
    """
    return (problem.loss_name, _ceil_to(problem.n, P),
            _ceil_to(problem.m, P * Q))


def solo_config(cfg, problem: FleetProblem):
    """The config a solo ``Solver.solve`` needs to reproduce this
    tenant's fleet result: the shared config with the tenant's ``lam``
    (and ``seed``, for configs that carry one) substituted in."""
    updates = {"lam": problem.lam}
    if hasattr(cfg, "seed"):
        updates["seed"] = problem.seed
    return dataclasses.replace(cfg, **updates)


# ---------------------------------------------------------------------------
# the tenant axis: spec transform + cell wrapper
# ---------------------------------------------------------------------------

def with_tenant(specs):
    """Prepend an unnamed (replicated) tenant axis to every dim spec.

    ``None`` entries are ignored by the grid executor's vmap in_axes
    (membership test) and map to a replicated ``PartitionSpec`` entry on
    the mesh -- the tenant axis is never a communication axis.
    """
    return jax.tree_util.tree_map(lambda ds: (None,) + tuple(ds), specs,
                                  is_leaf=_is_dimspec)


def named_axes(ds) -> int:
    """Number of named (block/shard) axes of a dim spec."""
    return sum(1 for e in tuple(ds) if e is not None)


def stack_grid(arrs, ds):
    """Stack per-tenant grid arrays on the tenant axis.

    The grid's blocked layout keeps one leading block axis per NAMED
    dim-spec entry, so the tenant axis lands right after them: the cell
    then sees ``(T, ...per-cell extents)`` and the tenant vmap of
    :func:`fleet_cell_program` peels T.
    """
    return jnp.stack(arrs, axis=named_axes(ds))


def stack_mesh(arrs):
    """Mesh arrays take the tenant axis in front: with the
    :func:`with_tenant` spec the partition spec gains a leading ``None``
    entry, so each device's shard is ``(T, ...per-cell extents)``."""
    return jnp.stack(arrs, axis=0)


def fleet_cell_program(base: CellProgram) -> CellProgram:
    """Vmap a per-problem :class:`CellProgram` over a leading tenant axis.

    The wrapped program's data tuple is ``(active, *tenant_stacked_base
    data)`` where ``active`` ((T,) of 0/1) freezes converged tenants
    exactly: a frozen tenant's state is carried through ``jnp.where``
    untouched, bit for bit, while its lanes keep feeding the shared
    collectives (harmlessly -- the where discards the result).

    The comm calls inside the tenant vmap still see the named grid/mesh
    axes (unnamed vmap batching passes named axes through), so all T
    tenants share ONE CommSchedule round per declared collective: the
    whole point of the fleet path.
    """
    def cell(comm, t, data, state):
        active, *inner = data

        def tenant(d1, s1, a1):
            out = base.cell(comm, t, d1, s1)
            return jax.tree_util.tree_map(
                lambda new, old: jnp.where(a1 > 0, new, old), out, s1)

        return jax.vmap(tenant)(tuple(inner), state, active)

    data_specs = ((None,),) + tuple(with_tenant(ds)
                                    for ds in base.data_specs)
    return CellProgram(base.schedule, cell, data_specs,
                       with_tenant(base.state_specs))
