"""Multi-tenant batched solves: T independent problems, one compiled step.

The fleet subsystem packs many small independent problems
(per-tenant ``(X_t, y_t, loss, lam_t)``) into constant-shape
tenant-major arrays and vmaps the existing per-solver
:class:`~repro.core.engines.CellProgram` over the tenant axis *inside*
each P x Q cell.  All tenants then share one CommSchedule round per
collective and one compiled outer step -- amortizing both the wire and
the trace/compile cost across the whole batch.

  * :mod:`repro.fleet.batch`     -- problems, shape buckets, the tenant
    spec transform + cell-program wrapper, stacking rules;
  * :mod:`repro.fleet.solver`    -- :class:`FleetSolver`, the batched
    drive loop with per-tenant convergence freezing and warm starts;
  * :mod:`repro.fleet.scheduler` -- :class:`FleetScheduler`, admission,
    bucketing and per-tenant result unpacking.
"""
from .batch import (FleetProblem, bucket_key, fleet_cell_program,
                    solo_config, stack_grid, stack_mesh, with_tenant)
from .scheduler import FleetScheduler
from .solver import FLEET_ENGINES, FleetSolver

__all__ = [
    "FLEET_ENGINES",
    "FleetProblem",
    "FleetScheduler",
    "FleetSolver",
    "bucket_key",
    "fleet_cell_program",
    "solo_config",
    "stack_grid",
    "stack_mesh",
    "with_tenant",
]
