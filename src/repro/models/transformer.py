"""Composable decoder stack covering all 10 assigned architectures.

The layer stack is ``pattern`` (a tuple of mixer kinds) repeated; full
periods run under one ``lax.scan`` with stacked parameters (small HLO,
fast SPMD partitioning at 512 devices) and the remainder layers run
unrolled.  Three entry points:

  * ``train_loss``  -- full-sequence forward + mean token cross entropy
  * ``prefill``     -- forward that also materializes the decode caches
  * ``decode_step`` -- one token with cache, O(cache) per layer

Parameters are nested dicts; a parallel "logical axes" tree drives the
doubly distributed sharding rules (repro/sharding/rules.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..sharding.rules import constrain
from .attention import (chunked_attention, decode_attention,
                        full_attention)
from .config import ATTN, LOCAL, RGLRU, RWKV, XATTN, ModelConfig
from .layers import apply_rope, head_rms_norm, rms_norm, trunc_normal
from .moe import init_moe, moe_ffn
from .rglru import init_rglru, rglru_block, rglru_decode
from .rwkv import (init_rwkv, init_rwkv_channel_mix, rwkv_channel_mix,
                   rwkv_time_mix)


def _kv_quant(x):
    """Symmetric int8 quantization over the head dim.

    Returns (int8 values, f32 absmax/127 scales without the head dim)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    q = jnp.clip(jnp.round(xf / jnp.maximum(scale, 1e-8)[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, cross: bool):
    dm, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    s = dm ** -0.5
    p = {
        "wq": trunc_normal(ks[0], (dm, H * hd), s, dt),
        "wk": trunc_normal(ks[1], (dm, KV * hd), s, dt),
        "wv": trunc_normal(ks[2], (dm, KV * hd), s, dt),
        "wo": trunc_normal(ks[3], (H * hd, dm), (H * hd) ** -0.5, dt),
    }
    lg = {
        "wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        lg["q_norm"] = (None,)
        lg["k_norm"] = (None,)
    return p, lg


def _init_mlp(key, cfg: ModelConfig):
    dm, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype
    p = {
        "w_gate": trunc_normal(ks[0], (dm, dff), dm ** -0.5, dt),
        "w_up": trunc_normal(ks[1], (dm, dff), dm ** -0.5, dt),
        "w_down": trunc_normal(ks[2], (dff, dm), dff ** -0.5, dt),
    }
    lg = {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
         "w_down": ("ff", "fsdp")}
    return p, lg


def _init_layer(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dt),
                         "ln2": jnp.ones((cfg.d_model,), dt)}
    lg: Dict[str, Any] = {"ln1": ("fsdp",), "ln2": ("fsdp",)}
    if kind in (ATTN, LOCAL, XATTN):
        p["mixer"], lg["mixer"] = _init_attn(ks[0], cfg, kind == XATTN)
    elif kind == RWKV:
        p["mixer"], lg["mixer"] = init_rwkv(ks[0], cfg)
    elif kind == RGLRU:
        p["mixer"], lg["mixer"] = init_rglru(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == RWKV:
        p["mlp"], lg["mlp"] = init_rwkv_channel_mix(ks[1], cfg)
    elif cfg.moe is not None:
        p["mlp"], lg["mlp"] = init_moe(ks[1], cfg)
    else:
        p["mlp"], lg["mlp"] = _init_mlp(ks[1], cfg)
    return p, lg


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Transformer:
    cfg: ModelConfig
    mesh: Optional[Any] = None

    # ---- init ----
    def init(self, key) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        n_full, n_rem = cfg.n_periods()
        kp = len(cfg.pattern)
        keys = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        logical: Dict[str, Any] = {}

        if cfg.embed_input == "tokens":
            params["embed"] = trunc_normal(keys[0], (cfg.vocab, cfg.d_model),
                                           1.0, cfg.pdtype)
            logical["embed"] = ("vocab", "fsdp")
        params["head"] = trunc_normal(keys[1], (cfg.d_model, cfg.vocab),
                                      cfg.d_model ** -0.5, cfg.pdtype)
        logical["head"] = ("fsdp", "vocab")
        params["final_norm"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        logical["final_norm"] = ("fsdp",)

        # stacked per pattern position: leading dim n_full
        def stack_position(j):
            kind = cfg.pattern[j]
            ks = jax.random.split(jax.random.fold_in(keys[2], j), n_full)
            ps, ls = zip(*[_init_layer(k, cfg, kind) for k in ks])
            return jax.tree.map(lambda *xs: jnp.stack(xs), *ps), ls[0]

        if n_full:
            pos_trees = [stack_position(j) for j in range(kp)]
            params["periods"] = [t[0] for t in pos_trees]
            logical["periods"] = [
                jax.tree.map(lambda ax: (None,) + ax, t[1],
                             is_leaf=lambda x: isinstance(x, tuple) and all(
                                 isinstance(e, (str, type(None))) for e in x))
                for t in pos_trees]
        else:
            params["periods"] = []
            logical["periods"] = []

        rem = []
        rem_l = []
        for r in range(n_rem):
            p, lg = _init_layer(jax.random.fold_in(keys[3], r), cfg,
                               cfg.pattern[r % len(cfg.pattern)])
            rem.append(p)
            rem_l.append(lg)
        params["remainder"] = rem
        logical["remainder"] = rem_l
        return params, logical

    # ---- building blocks ----
    def _constrain_act(self, x):
        if self.mesh is not None:
            return constrain(x, self.mesh, "batch", None, None)
        return x

    def _constrain_kv(self, arr):
        """Pin a (B, L, KV[, hd]) KV-cache tensor (or its int8 scales,
        rank 3) to its decode layout: KV-head-sharded when n_kv divides
        the model axis, else sequence-parallel (length-sharded)."""
        if self.mesh is None:
            return arr
        kv_div = ("model" in self.mesh.axis_names
                  and self.cfg.n_kv % self.mesh.shape["model"] == 0)
        logical = (("batch", None, "kv_heads", None) if kv_div
                   else ("batch", "kv_len", None, None))
        return constrain(arr, self.mesh, *logical[: arr.ndim])

    def _attn_train(self, p, x, kind, positions, enc=None):
        cfg = self.cfg
        cdt = cfg.cdtype
        B, S, dm = x.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
        q = (x @ p["wq"].astype(cdt)).reshape(B, S, H, hd)
        src = enc if kind == XATTN else x
        Skv = src.shape[1]
        k = (src @ p["wk"].astype(cdt)).reshape(B, Skv, KV, hd)
        v = (src @ p["wv"].astype(cdt)).reshape(B, Skv, KV, hd)
        if cfg.qk_norm:
            q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
        attn = (chunked_attention if cfg.attn_impl == "chunked"
                else full_attention)
        if kind != XATTN:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            window = (cfg.swa_window if kind == ATTN else cfg.local_window)
            out = attn(q, k, v, causal=True, window=window)
        else:
            out = attn(q, k, v, causal=False, window=None)
        return out.reshape(B, S, H * hd) @ p["wo"].astype(cdt)

    def _mlp(self, p, x, kind):
        cfg = self.cfg
        cdt = cfg.cdtype
        if kind == RWKV:
            out, _ = rwkv_channel_mix(p, x, cfg)
            return out
        if cfg.moe is not None:
            return moe_ffn(p, x, cfg)
        h = jax.nn.silu(x @ p["w_gate"].astype(cdt)) * \
            (x @ p["w_up"].astype(cdt))
        return h @ p["w_down"].astype(cdt)

    def _layer_train(self, p, x, kind, positions, enc=None):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind == RWKV:
            mix, _ = rwkv_time_mix(p["mixer"], h, cfg)
        elif kind == RGLRU:
            mix, _ = rglru_block(p["mixer"], h, cfg)
        else:
            mix = self._attn_train(p["mixer"], h, kind, positions, enc)
        # name the post-projection (= post-all-reduce under TP) tensors so
        # the "save_boundaries" remat policy can keep them: the backward
        # then re-runs neither the forward collectives nor the projections
        mix = checkpoint_name(mix, "mixer_out")
        x = x + mix
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        out = checkpoint_name(self._mlp(p["mlp"], h, kind), "mlp_out")
        x = x + out
        return self._constrain_act(x)

    # ---- train ----
    def _backbone_train(self, params, x, positions, enc=None):
        cfg = self.cfg
        kp = len(cfg.pattern)

        if params["periods"]:
            def period_body(xc, pslices):
                for j, kind in enumerate(cfg.pattern):
                    xc = self._layer_train(pslices[j], xc, kind, positions,
                                           enc)
                return xc, None

            if cfg.remat_policy == "save_boundaries":
                policy = jax.checkpoint_policies.save_only_these_names(
                    "mixer_out", "mlp_out")
            elif cfg.remat_policy == "save_dots":
                # save every matmul output: backward recomputes only
                # elementwise chains -- no matmul/collective re-execution
                policy = jax.checkpoint_policies.dots_saveable
            else:
                policy = jax.checkpoint_policies.nothing_saveable
            body = jax.checkpoint(period_body, policy=policy)
            x, _ = jax.lax.scan(body, x, tuple(params["periods"]))

        for r, p in enumerate(params["remainder"]):
            x = self._layer_train(p, x, cfg.pattern[r % len(cfg.pattern)],
                                  positions, enc)
        return x

    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.embed_input == "tokens":
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        else:
            x = batch["embeds"]
        return self._constrain_act(x.astype(cfg.cdtype))

    def logits_fn(self, params, batch):
        cfg = self.cfg
        x = self._hidden_fn(params, batch)
        logits = (x @ params["head"].astype(cfg.cdtype)).astype(jnp.float32)
        return logits

    def _hidden_fn(self, params, batch):
        """Backbone forward up to (and including) the final norm."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[:2]
        positions = jnp.arange(S)
        enc = batch.get("encoder") if isinstance(batch, dict) else None
        if enc is not None:
            enc = enc.astype(cfg.cdtype)
        x = self._backbone_train(params, x, positions, enc)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = self._hidden_fn(params, batch)
        labels = batch["labels"]
        head = params["head"]
        B, S = labels.shape
        C = cfg.loss_chunk

        def chunk_nll(xc, lc):
            logits = (xc @ head.astype(cfg.cdtype)).astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum(logz - gold)

        if not C or S <= C or S % C:
            return chunk_nll(x, labels) / (B * S)

        # Chunked cross entropy: the (B, C, vocab) fp32 logits exist for
        # one chunk at a time; nothing_saveable makes the backward
        # recompute them per chunk instead of saving every chunk's logits
        # (which would re-materialize the full logits tensor).
        def body(acc, i):
            xc = jax.lax.dynamic_slice_in_dim(x, i * C, C, axis=1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
            return acc + chunk_nll(xc, lc), None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                jnp.arange(S // C))
        return total / (B * S)

    # ---- caches ----
    def _cache_len(self, kind, cache_len):
        cfg = self.cfg
        if kind == ATTN and cfg.swa_window is not None:
            return min(cache_len, cfg.swa_window)
        if kind == LOCAL:
            return min(cache_len, cfg.local_window)
        if kind == XATTN:
            return max(cfg.encoder_len, 1)
        return cache_len

    def init_cache(self, batch_size, cache_len, *, n_layers, kind):
        """Zero cache subtree for ``n_layers`` stacked layers of ``kind``."""
        cfg = self.cfg
        B, n = batch_size, n_layers
        cdt = cfg.cdtype
        if kind in (ATTN, LOCAL, XATTN):
            L = self._cache_len(kind, cache_len)
            kv = (n, B, L, cfg.n_kv, cfg.hd)
            if cfg.kv_cache_dtype == "int8" and kind != XATTN:
                return {"k": jnp.zeros(kv, jnp.int8),
                        "v": jnp.zeros(kv, jnp.int8),
                        "k_scale": jnp.zeros(kv[:-1], jnp.float32),
                        "v_scale": jnp.zeros(kv[:-1], jnp.float32)}
            return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
        if kind == RWKV:
            H, D = cfg.rwkv_heads, cfg.rwkv_head_dim
            return {"state": jnp.zeros((n, B, H, D, D), jnp.float32),
                    "x_tm": jnp.zeros((n, B, cfg.d_model), cdt),
                    "x_cm": jnp.zeros((n, B, cfg.d_model), cdt)}
        if kind == RGLRU:
            return {"h": jnp.zeros((n, B, cfg.d_model), jnp.float32)}
        raise ValueError(kind)

    def make_cache(self, batch_size, cache_len):
        cfg = self.cfg
        n_full, n_rem = cfg.n_periods()
        cache = {"pos": jnp.zeros((), jnp.int32)}
        cache["periods"] = [
            self.init_cache(batch_size, cache_len, n_layers=n_full, kind=k)
            for k in cfg.pattern] if n_full else []
        cache["remainder"] = [
            self.init_cache(batch_size, cache_len, n_layers=1,
                            kind=cfg.pattern[r % len(cfg.pattern)])
            for r in range(n_rem)]
        return cache

    # ---- prefill ----
    def _layer_prefill(self, p, x, kind, positions, cache_len, enc=None,
                       linear_cache=False):
        """Like _layer_train but also returns this layer's cache entry.

        ``linear_cache=True`` (paged serving): attention layers return the
        prompt's raw full-length k/v (no ring buffer, no padding to
        ``cache_len``, no int8 quant) so the caller can scatter them into
        a paged arena by linear token position (repro/serve/cache.py).
        """
        cfg = self.cfg
        cdt = cfg.cdtype
        B, S, dm = x.shape
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind == RWKV:
            mix, (x_tm, state) = rwkv_time_mix(p["mixer"], h, cfg)
            x = x + mix
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            out, x_cm = rwkv_channel_mix(p["mlp"], h2, cfg)
            x = self._constrain_act(x + out)
            return x, {"state": state, "x_tm": x_tm.astype(cdt),
                       "x_cm": x_cm.astype(cdt)}
        if kind == RGLRU:
            mix, hstate = rglru_block(p["mixer"], h, cfg)
            cache = {"h": hstate}
            x = x + mix
        else:
            H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
            src = enc if kind == XATTN else h
            Skv = src.shape[1]
            k = (src @ p["mixer"]["wk"].astype(cdt)).reshape(B, Skv, KV, hd)
            v = (src @ p["mixer"]["wv"].astype(cdt)).reshape(B, Skv, KV, hd)
            q = (h @ p["mixer"]["wq"].astype(cdt)).reshape(B, S, H, hd)
            if cfg.qk_norm:
                q = head_rms_norm(q, p["mixer"]["q_norm"], cfg.norm_eps)
                k = head_rms_norm(k, p["mixer"]["k_norm"], cfg.norm_eps)
            attn = (chunked_attention if cfg.attn_impl == "chunked"
                    else full_attention)
            if kind != XATTN:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                window = (cfg.swa_window if kind == ATTN else cfg.local_window)
                out = attn(q, k, v, causal=True, window=window)
            else:
                out = attn(q, k, v, causal=False)
            L = self._cache_len(kind, cache_len)
            if kind == XATTN:
                ck, cv = k, v                       # static encoder cache
            elif linear_cache:
                ck, cv = k, v                       # full-length, unrolled
            elif L >= Skv:
                pad = [(0, 0), (0, L - Skv), (0, 0), (0, 0)]
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                # ring buffer: keep the last L, placed at slot pos % L
                ck, cv = k[:, -L:], v[:, -L:]
                shift = (S % L)
                ck = jnp.roll(ck, shift, axis=1)
                cv = jnp.roll(cv, shift, axis=1)
            if kind != XATTN:
                if linear_cache:
                    cache = {"k": ck, "v": cv}
                elif cfg.kv_cache_dtype == "int8":
                    ck, sk = _kv_quant(ck)
                    cv, sv = _kv_quant(cv)
                    cache = {"k": self._constrain_kv(ck),
                             "v": self._constrain_kv(cv),
                             "k_scale": self._constrain_kv(sk),
                             "v_scale": self._constrain_kv(sv)}
                else:
                    cache = {"k": self._constrain_kv(ck),
                             "v": self._constrain_kv(cv)}
            else:
                cache = {"k": ck, "v": cv}
            x = x + out.reshape(B, S, H * hd) @ p["mixer"]["wo"].astype(cdt)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = self._constrain_act(x + self._mlp(p["mlp"], h2, kind))
        return x, cache

    def prefill(self, params, batch, cache_len, *, last_pos=None,
                linear_cache=False):
        """Forward pass that also materializes the decode caches.

        ``last_pos``: position whose next-token logits to return (may be
        traced); default is the final position.  Serving prefills pad
        prompts to a bucket length, so the real last token sits mid-way.
        ``linear_cache``: return raw full-length k/v per attention layer
        (the paged-serving block-table view) instead of the ring-buffer
        cache; see ``_layer_prefill``.
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[:2]
        positions = jnp.arange(S)
        enc = batch.get("encoder") if isinstance(batch, dict) else None
        if enc is not None:
            enc = enc.astype(cfg.cdtype)

        caches_p = []
        if params["periods"]:
            def body(xc, pslices):
                ycaches = []
                for j, kind in enumerate(cfg.pattern):
                    xc, c = self._layer_prefill(pslices[j], xc, kind,
                                                positions, cache_len, enc,
                                                linear_cache=linear_cache)
                    ycaches.append(c)
                return xc, tuple(ycaches)

            x, ys = jax.lax.scan(body, x, tuple(params["periods"]))
            # ys: tuple (per pattern pos) of stacked cache trees, but the
            # per-layer dicts come back WITHOUT the leading layer axis in
            # init_cache layout -- scan already stacked them (n_full, ...)
            caches_p = list(ys)

        caches_r = []
        for r, p in enumerate(params["remainder"]):
            x, c = self._layer_prefill(p, x, cfg.pattern[r % len(cfg.pattern)],
                                       positions, cache_len, enc,
                                       linear_cache=linear_cache)
            caches_r.append(jax.tree.map(lambda a: a[None], c))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if last_pos is None:
            x_last = x[:, -1:]
        else:
            x_last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
        logits = (x_last @ params["head"].astype(cfg.cdtype)
                  ).astype(jnp.float32)
        cache = {"pos": jnp.asarray(S, jnp.int32), "periods": list(caches_p),
                 "remainder": caches_r}
        return logits, cache

    # ---- decode ----
    def _layer_decode(self, p, x, cache, kind, pos):
        """x: (B,1,dm); cache: this layer's subtree (no leading layer axis)."""
        cfg = self.cfg
        cdt = cfg.cdtype
        B = x.shape[0]
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind == RWKV:
            mix, (x_tm, state) = rwkv_time_mix(
                p["mixer"], h, cfg, x_last=cache["x_tm"],
                state=cache["state"])
            x = x + mix
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            out, x_cm = rwkv_channel_mix(p["mlp"], h2, cfg,
                                         x_last=cache["x_cm"])
            x = x + out
            return x, {"state": state, "x_tm": x_tm.astype(cdt),
                       "x_cm": x_cm.astype(cdt)}
        if kind == RGLRU:
            mix, hstate = rglru_decode(p["mixer"], h, cfg, state=cache["h"])
            new_cache = {"h": hstate}
            x = x + mix
        else:
            H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
            q = (h @ p["mixer"]["wq"].astype(cdt)).reshape(B, 1, H, hd)
            if kind == XATTN:
                if cfg.qk_norm:
                    q = head_rms_norm(q, p["mixer"]["q_norm"], cfg.norm_eps)
                out = decode_attention(q, cache["k"], cache["v"],
                                       jnp.asarray(cfg.encoder_len - 1))
                new_cache = cache
            else:
                k = (h @ p["mixer"]["wk"].astype(cdt)).reshape(B, 1, KV, hd)
                v = (h @ p["mixer"]["wv"].astype(cdt)).reshape(B, 1, KV, hd)
                if cfg.qk_norm:
                    q = head_rms_norm(q, p["mixer"]["q_norm"], cfg.norm_eps)
                    k = head_rms_norm(k, p["mixer"]["k_norm"], cfg.norm_eps)
                q = apply_rope(q, pos[None], cfg.rope_theta)
                k = apply_rope(k, pos[None], cfg.rope_theta)
                L = cache["k"].shape[1]
                slot = pos % L
                # One-hot masked write instead of dynamic_update_slice:
                # elementwise over the (possibly length-sharded) cache, so
                # a sequence-parallel cache needs no cross-shard traffic
                # for the write (a traced-index DUS on a sharded dim makes
                # GSPMD rematerialize the whole cache).
                hot = (jnp.arange(L) == slot)[None, :, None, None]
                if cfg.kv_cache_dtype == "int8":
                    qk, sk1 = _kv_quant(k)
                    qv, sv1 = _kv_quant(v)
                    ck = self._constrain_kv(jnp.where(hot, qk, cache["k"]))
                    cv = self._constrain_kv(jnp.where(hot, qv, cache["v"]))
                    sk = self._constrain_kv(
                        jnp.where(hot[..., 0], sk1, cache["k_scale"]))
                    sv = self._constrain_kv(
                        jnp.where(hot[..., 0], sv1, cache["v_scale"]))
                    new_cache = {"k": ck, "v": cv,
                                 "k_scale": sk, "v_scale": sv}
                    ak = _kv_dequant(ck, sk, cdt)
                    av = _kv_dequant(cv, sv, cdt)
                else:
                    ck = self._constrain_kv(jnp.where(hot, k, cache["k"]))
                    cv = self._constrain_kv(jnp.where(hot, v, cache["v"]))
                    new_cache = {"k": ck, "v": cv}
                    ak, av = ck, cv
                # with a ring buffer every slot is valid once filled; the
                # per-slot positional mask only matters while pos < L.
                out = decode_attention(q, ak, av,
                                       jnp.minimum(pos, L - 1),
                                       window=None)
            x = x + out.reshape(B, 1, H * hd) @ p["mixer"]["wo"].astype(cdt)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + self._mlp(p["mlp"], h2, kind)
        return x, new_cache

    def decode_step(self, params, cache, batch):
        """batch: {"tokens": (B,1)} (or {"embeds": (B,1,dm)}).

        Returns (logits (B,1,V), new cache).
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        pos = cache["pos"]

        new_periods = []
        if params["periods"]:
            # The cache rides in the scan CARRY and is updated with
            # dynamic_update_index instead of being re-emitted through ys
            # stacking: while-loop carries alias their input buffer, so
            # the donated decode cache is updated in place rather than
            # double-buffered (halves serve_step memory; EXPERIMENTS.md
            # §Perf "decode cache aliasing").
            n_full = jax.tree.leaves(params["periods"][0])[0].shape[0]

            def body(carry, inp):
                xc, caches = carry
                i, pslices = inp
                caches = list(caches)
                for j, kind in enumerate(cfg.pattern):
                    csub = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, i, 0, keepdims=False), caches[j])
                    xc, c = self._layer_decode(pslices[j], xc, csub,
                                               kind, pos)
                    caches[j] = jax.tree.map(
                        lambda full, new:
                        jax.lax.dynamic_update_index_in_dim(full, new, i, 0),
                        caches[j], c)
                return (xc, tuple(caches)), None

            (x, new_caches), _ = jax.lax.scan(
                body, (x, tuple(cache["periods"])),
                (jnp.arange(n_full), tuple(params["periods"])))
            new_periods = list(new_caches)

        new_rem = []
        for r, p in enumerate(params["remainder"]):
            csub = jax.tree.map(lambda a: a[0], cache["remainder"][r])
            x, c = self._layer_decode(p, x, csub,
                                      cfg.pattern[r % len(cfg.pattern)], pos)
            new_rem.append(jax.tree.map(lambda a: a[None], c))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["head"].astype(cfg.cdtype)).astype(jnp.float32)
        new_cache = {"pos": pos + 1, "periods": new_periods,
                     "remainder": new_rem}
        return logits, new_cache

    # ---- paged decode (continuous-batching serving) ----
    def _layer_decode_paged(self, p, x, arena, kind, bt, pos, active):
        """One-token decode against a paged KV arena.

        ``arena``: this layer's ``{"k", "v"}`` pages, each
        ``(num_pages + 1, page_size, KV, hd)`` -- the last page is the
        trash page for masked writes.  ``bt``: (B, max_pages) block
        tables mapping ``token t -> bt[b, t // page_size]``; unallocated
        entries point at the trash page.  ``pos``: (B,) per-sequence
        write positions; ``active``: (B,) bool slot-occupancy mask.
        """
        cfg = self.cfg
        cdt = cfg.cdtype
        B = x.shape[0]
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = (h @ p["mixer"]["wq"].astype(cdt)).reshape(B, 1, H, hd)
        k = (h @ p["mixer"]["wk"].astype(cdt)).reshape(B, 1, KV, hd)
        v = (h @ p["mixer"]["wv"].astype(cdt)).reshape(B, 1, KV, hd)
        if cfg.qk_norm:
            q = head_rms_norm(q, p["mixer"]["q_norm"], cfg.norm_eps)
            k = head_rms_norm(k, p["mixer"]["k_norm"], cfg.norm_eps)
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

        n_pages1, page_size = arena["k"].shape[:2]
        trash = n_pages1 - 1
        max_pages = bt.shape[1]
        slot = jnp.clip(pos // page_size, 0, max_pages - 1)
        pidx = jnp.where(active, bt[jnp.arange(B), slot], trash)
        off = pos % page_size
        ck = arena["k"].at[pidx, off].set(k[:, 0])
        cv = arena["v"].at[pidx, off].set(v[:, 0])

        # gather this batch's pages into a (B, max_pages * page_size, ...)
        # linear view; positions beyond ``pos`` (and trash-backed entries)
        # are masked inside decode_attention
        kseq = ck[bt].reshape(B, max_pages * page_size, KV, hd)
        vseq = cv[bt].reshape(B, max_pages * page_size, KV, hd)
        window = cfg.swa_window if kind == ATTN else cfg.local_window
        out = decode_attention(q, kseq, vseq, pos, window=window)
        x = x + out.reshape(B, 1, H * hd) @ p["mixer"]["wo"].astype(cdt)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + self._mlp(p["mlp"], h2, kind)
        return x, {"k": ck, "v": cv}

    def decode_step_paged(self, params, arenas, batch, block_tables,
                          lengths, active):
        """One continuous-batching decode step over the paged arenas.

        ``batch``: {"tokens": (B, 1)} last sampled token per slot;
        ``block_tables``: (B, max_pages) int32; ``lengths``: (B,) int32
        number of cached tokens per slot (= the write position of this
        step's token); ``active``: (B,) bool.  Returns
        (logits (B, 1, V), new arenas).  Only attention-like mixers are
        supported (see serve.cache.paged_kinds).
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        pos = lengths

        new_periods = []
        if params["periods"]:
            n_full = jax.tree.leaves(params["periods"][0])[0].shape[0]

            def body(carry, inp):
                xc, ars = carry
                i, pslices = inp
                ars = list(ars)
                for j, kind in enumerate(cfg.pattern):
                    sub = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, i, 0, keepdims=False), ars[j])
                    xc, sub = self._layer_decode_paged(
                        pslices[j], xc, sub, kind, block_tables, pos, active)
                    ars[j] = jax.tree.map(
                        lambda full, new:
                        jax.lax.dynamic_update_index_in_dim(full, new, i, 0),
                        ars[j], sub)
                return (xc, tuple(ars)), None

            (x, new_ars), _ = jax.lax.scan(
                body, (x, tuple(arenas["periods"])),
                (jnp.arange(n_full), tuple(params["periods"])))
            new_periods = list(new_ars)

        new_rem = []
        for r, p in enumerate(params["remainder"]):
            sub = jax.tree.map(lambda a: a[0], arenas["remainder"][r])
            x, sub = self._layer_decode_paged(
                p, x, sub, cfg.pattern[r % len(cfg.pattern)], block_tables,
                pos, active)
            new_rem.append(jax.tree.map(lambda a: a[None], sub))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["head"].astype(cfg.cdtype)).astype(jnp.float32)
        return logits, {"periods": new_periods, "remainder": new_rem}
