"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(W_r x_t)                       (recurrence gate)
    i_t = sigmoid(W_i x_t)                       (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)       (per-channel decay, in (0,1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is an elementwise linear scan -> we evaluate it with
``jax.lax.associative_scan`` (log-depth, parallel across the sequence --
the TPU-native adaptation of the paper's CUDA linear-scan kernel).
Decode is the O(1) recurrence.  We implement the gated block of Griffin
(input/output linear + the recurrence) without the temporal conv1d of the
full release; recorded in DESIGN.md §assumptions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import trunc_normal


def init_rglru(key, cfg: ModelConfig):
    dm = cfg.d_model
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype
    s = dm ** -0.5
    params = {
        "w_x": trunc_normal(ks[0], (dm, dm), s, dt),      # input projection
        "w_r": trunc_normal(ks[1], (dm, dm), s, dt),
        "w_i": trunc_normal(ks[2], (dm, dm), s, dt),
        "w_o": trunc_normal(ks[3], (dm, dm), s, dt),
        # Lambda init so that a^c in [0.9, 0.999] at r=1 (paper's init)
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(
                jnp.linspace(0.9, 0.999, dm)) / cfg.rglru_c)), dt),
    }
    logical = {"w_x": ("fsdp", "ff"), "w_r": ("fsdp", "ff"),
               "w_i": ("fsdp", "ff"), "w_o": ("ff", "fsdp"),
               "lam": ("ff",)}
    return params, logical


def _rglru_core(a, bx, h0):
    """h_t = a_t h_{t-1} + bx_t via associative scan. a,bx: (B,S,C)."""
    if h0 is not None:
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(params, x, cfg: ModelConfig, *, state=None):
    """x: (B,S,dm) -> (out, new_state (B,dm))."""
    cdt = cfg.cdtype
    xg = x @ params["w_x"].astype(cdt)
    r = jax.nn.sigmoid((x @ params["w_r"].astype(cdt)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_i"].astype(cdt)).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(
        params["lam"].astype(jnp.float32)) * r              # (B,S,dm) fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xg.astype(jnp.float32))
    h = _rglru_core(a, gated, state)
    out = (h.astype(cdt)) @ params["w_o"].astype(cdt)
    return out, h[:, -1, :]


def rglru_decode(params, x, cfg: ModelConfig, *, state):
    """One-token recurrence. x: (B,1,dm); state: (B,dm) fp32."""
    cdt = cfg.cdtype
    xg = x @ params["w_x"].astype(cdt)
    r = jax.nn.sigmoid((x @ params["w_r"].astype(cdt)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_i"].astype(cdt)).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(
        params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)[:, 0, :]
    gated = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
             * (i * xg.astype(jnp.float32)))[:, 0, :]
    h = a * state + gated
    out = (h[:, None, :].astype(cdt)) @ params["w_o"].astype(cdt)
    return out, h
