"""Model / shape configuration dataclasses.

A ``ModelConfig`` describes one architecture from the assigned pool; the
layer stack is expressed as a repeating ``pattern`` of mixer kinds so that
homogeneous runs lower to a single ``lax.scan`` (compile-time friendly at
512 devices) while hybrids (RG-LRU 1:2, VLM cross-attn every 5th) scan over
whole periods.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# mixer kinds
ATTN = "attn"        # causal self attention (GQA + RoPE, optional qk-norm/SWA)
XATTN = "xattn"      # cross attention to stub encoder states (VLM)
RWKV = "rwkv"        # RWKV-6 data-dependent-decay linear attention
RGLRU = "rglru"      # RG-LRU gated linear recurrence (recurrentgemma)
LOCAL = "local"      # sliding-window self attention (recurrentgemma 1:2)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # sequence-chunk size for the capacity-based dispatch (see models/moe.py)
    chunk: int = 512


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = (ATTN,)
    head_dim: Optional[int] = None  # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    swa_window: Optional[int] = None    # sliding window for ATTN mixers
    local_window: int = 2048            # window for LOCAL mixers
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    embed_input: str = "tokens"         # "tokens" | "embeddings" (stub frontend)
    encoder_len: int = 0                # VLM: number of stub image tokens
    rwkv_head_dim: int = 64
    rglru_c: float = 8.0                # RG-LRU decay sharpness constant
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # calibration mode: lay every layer out unrolled (no scan) so that
    # cost_analysis -- which counts a while-loop body ONCE -- measures true
    # per-layer costs; used by the roofline per-period extrapolation
    unroll: bool = False
    # "chunked" (flash-style scan; production) or "full" (materialized
    # scores; scan-free -- calibration only, so HLO cost analysis sees
    # every attention FLOP)
    attn_impl: str = "chunked"
    # gradient-accumulation microbatches for train steps: the global batch
    # is split into ``train_accum`` sequential microbatches under a
    # lax.scan so per-device activations fit HBM; clamped to the largest
    # divisor of the per-call batch in make_train_step
    train_accum: int = 8
    # chunked cross entropy: compute head matmul + log-softmax over
    # sequence chunks of this many tokens under a rematerialized scan so
    # the (B, S, vocab) fp32 logits (+ their gradient) are never fully
    # materialized; None = single full-logits pass
    loss_chunk: Optional[int] = 1024
    # activation-checkpoint policy for the layer scan:
    #   "nothing"         -- recompute everything; minimizes HBM traffic
    #     and live memory, the dominant roofline term on every train cell
    #     (measured: EXPERIMENTS.md §Perf)
    #   "save_boundaries" -- save mixer/MLP projection outputs (the
    #     post-all-reduce tensors): backward re-runs neither the forward
    #     TP collectives nor the projections (-10% wire, +18% HBM bytes)
    #   "save_dots"       -- save every matmul output (-3% FLOPs, -9%
    #     wire, +57% HBM bytes)
    remat_policy: str = "nothing"
    # decode KV-cache storage dtype: "bfloat16" (exact) or "int8"
    # (per-(token, kv-head) absmax scales stored alongside; halves the
    # cache-read HBM traffic that dominates the decode memory term)
    kv_cache_dtype: str = "bfloat16"
    # ---- roofline bookkeeping ----
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode is feasible (no full-attention mixer).

        LOCAL/SWA windows, RWKV and RG-LRU are all O(window) or O(1) per
        decoded token; XATTN attends to a short fixed encoder and is fine.
        """
        return not (ATTN in self.pattern and self.swa_window is None)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_periods(self):
        if self.unroll:
            return 0, self.n_layers
        k = len(self.pattern)
        return self.n_layers // k, self.n_layers % k


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq: int            # sequence length (train) or KV-cache length (decode)
    batch: int          # global batch
    kind: str           # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(len(cfg.pattern), 2) if len(cfg.pattern) > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        rwkv_head_dim=16,
        encoder_len=8 if cfg.encoder_len else 0,
        swa_window=16 if cfg.swa_window else None,
        local_window=16,
    )
    if cfg.moe is not None:
        # capacity_factor 4.0 => no capacity drops at smoke scale, so the
        # decode path matches the chunked forward exactly
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, chunk=8,
                              capacity_factor=4.0)
    if cfg.pattern == (RGLRU, RGLRU, ATTN):
        kw["n_layers"] = 5   # exercises the remainder (5 = 3 + 2) path
    if XATTN in cfg.pattern:
        kw["n_layers"] = len(cfg.pattern) * 2
    kw.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
