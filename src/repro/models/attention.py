"""Attention: chunked (flash-style) causal/windowed softmax attention.

The training/prefill path never materializes the (S x S) score matrix:
an outer ``lax.scan`` walks query chunks while an inner scan walks KV
chunks carrying the online-softmax state (m, l, acc).  KV chunks that are
entirely masked out (future chunks under causality, chunks beyond the
sliding window) are skipped at *runtime* with ``lax.cond`` -- on TPU this
lowers to a conditional, so the causal upper triangle costs ~0 FLOPs at
run time.  A Pallas TPU kernel with the same blocking lives in
``repro.kernels.flash`` (the pure-JAX path here is its oracle and the
dry-run/autodiff path).

GQA layout: q (B, S, H, D), k/v (B, S, KV, D) with G = H // KV query heads
per KV head, handled by reshaping q to (B, S, KV, G, D).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x, c, axis=1):
    s = x.shape[axis]
    assert s % c == 0, (s, c)
    new = x.shape[:axis] + (s // c, c) + x.shape[axis + 1:]
    return x.reshape(new)


def chunked_attention(q, k, v, *, causal=True, window=None,
                      chunk_q=512, chunk_k=512, scale=None):
    """Flash-style attention. q: (B,S,H,D); k,v: (B,Skv,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    chunk_q = min(chunk_q, S)
    chunk_k = min(chunk_k, Skv)
    nq, nk = S // chunk_q, Skv // chunk_k

    qc = _chunk(q.reshape(B, S, KV, G, D), chunk_q)      # (B,nq,Cq,KV,G,D)
    qc = jnp.moveaxis(qc, 1, 0)                          # (nq,B,Cq,KV,G,D)
    kc = jnp.moveaxis(_chunk(k, chunk_k), 1, 0)          # (nk,B,Ck,KV,D)
    vc = jnp.moveaxis(_chunk(v, chunk_k), 1, 0)

    qpos = jnp.arange(chunk_q)
    kpos = jnp.arange(chunk_k)

    def q_step(_, qi_q):
        qi, q_i = qi_q
        q_i = q_i * scale

        def kv_step(carry, kj_kv):
            kj, k_j, v_j = kj_kv
            m, l, acc = carry

            def compute(_):
                s = jnp.einsum("bckgd,bxkd->bckgx", q_i, k_j,
                               preferred_element_type=jnp.float32)
                qp = qi * chunk_q + qpos                  # (Cq,)
                kp = kj * chunk_k + kpos                  # (Ck,)
                mask = jnp.ones((chunk_q, chunk_k), bool)
                if causal:
                    mask &= qp[:, None] >= kp[None, :]
                if window is not None:
                    mask &= qp[:, None] - kp[None, :] < window
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bckgx,bxkd->bckgd", p.astype(v_j.dtype), v_j,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            needed = jnp.array(True)
            if causal:
                needed &= kj * chunk_k <= qi * chunk_q + (chunk_q - 1)
            if window is not None:
                needed &= (kj + 1) * chunk_k - 1 > qi * chunk_q - window
            m, l, acc = jax.lax.cond(needed, compute, lambda _: (m, l, acc),
                                     None)
            return (m, l, acc), None

        m0 = jnp.full((B, chunk_q, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, chunk_q, KV, G), jnp.float32)
        a0 = jnp.zeros((B, chunk_q, KV, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    # Flash-style backward: without this checkpoint, differentiating the
    # scans saves every (q-chunk x kv-chunk) fp32 score/prob block -- the
    # full S x S score matrix re-materialized per layer.  Rematerializing
    # per q-chunk keeps only O(Cq x Ck) live during the backward.
    q_step = jax.checkpoint(
        q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)    # merge chunks
    return out


def full_attention(q, k, v, *, causal=True, window=None, scale=None):
    """Reference O(S^2)-memory attention (oracle for tests)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qr = q.reshape(B, S, KV, G, D)
    s = jnp.einsum("bskgd,bxkd->bskgx", qr * scale, k,
                   preferred_element_type=jnp.float32)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgx,bxkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=None, scale=None):
    """Single-token attention against a (B, Smax, KV, D) cache.

    ``pos``: current position -- scalar int32, or a (B,) vector of
    per-sequence positions (paged / continuous-batching decode, where
    every batch slot sits at its own depth).  Entries > pos are masked.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qr = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bxkd->bkgx", qr * scale, k_cache,
                   preferred_element_type=jnp.float32)
    kp = jnp.arange(k_cache.shape[1])
    posv = jnp.broadcast_to(jnp.asarray(pos), (B,))
    mask = kp[None, :] <= posv[:, None]
    if window is not None:
        mask &= kp[None, :] > posv[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgx,bxkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)
