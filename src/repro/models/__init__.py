from .config import (ATTN, LM_SHAPES, LOCAL, RGLRU, RWKV, XATTN,
                     ModelConfig, MoEConfig, ShapeConfig, reduced)
from .transformer import Transformer
