"""RWKV-6 ("Finch") time-mix layer: linear attention with data-dependent
per-channel decay (arXiv:2404.05892), plus the squared-ReLU channel mix.

State recurrence per head (D = head dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: D x D)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(wx_t)) data-dependent, u a learned "bonus" for the
current token.  Token shift (mixing x_t with x_{t-1}) gates all five
projections, following the reference implementation (we use the simple
static mix; the low-rank dynamic mix of the full release is an
optimization, not a structural change -- noted in DESIGN.md).

Training path: ``lax.scan`` over time carrying (B, H, D, D) state --
sequential but exact; the chunked Pallas kernel in ``repro.kernels.linattn``
implements the GLA-style chunked parallel form for TPU throughput.
Decode: O(1) per token via the same recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm, trunc_normal


def init_rwkv(key, cfg: ModelConfig):
    dm = cfg.d_model
    H, D = cfg.rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    dt = cfg.pdtype
    s = dm ** -0.5
    params = {
        "w_r": trunc_normal(ks[0], (dm, dm), s, dt),
        "w_k": trunc_normal(ks[1], (dm, dm), s, dt),
        "w_v": trunc_normal(ks[2], (dm, dm), s, dt),
        "w_g": trunc_normal(ks[3], (dm, dm), s, dt),
        "w_w": trunc_normal(ks[4], (dm, dm), 0.1 * s, dt),
        "w_o": trunc_normal(ks[5], (dm, dm), s, dt),
        "u": trunc_normal(ks[6], (H, D), 0.5, dt),
        "mix": 0.5 * jnp.ones((5, dm), dt),     # token-shift mixes (r,k,v,g,w)
        "ln_x": jnp.ones((dm,), dt),            # group-norm on the head output
    }
    logical = {
        "w_r": ("fsdp", "heads"), "w_k": ("fsdp", "heads"),
        "w_v": ("fsdp", "heads"), "w_g": ("fsdp", "heads"),
        "w_w": ("fsdp", "heads"), "w_o": ("heads", "fsdp"),
        "u": ("heads", None), "mix": (None, "fsdp"), "ln_x": ("fsdp",),
    }
    return params, logical


def _projections(params, x, x_prev, cfg: ModelConfig):
    """Token-shifted r,k,v,g and log-decay lw. x: (B,S,dm), x_prev shifted."""
    cdt = cfg.cdtype
    mix = params["mix"].astype(cdt)
    B, S, dm = x.shape
    H, D = cfg.rwkv_heads, cfg.rwkv_head_dim

    def mixed(i):
        return x * mix[i] + x_prev * (1.0 - mix[i])

    r = (mixed(0) @ params["w_r"].astype(cdt)).reshape(B, S, H, D)
    k = (mixed(1) @ params["w_k"].astype(cdt)).reshape(B, S, H, D)
    v = (mixed(2) @ params["w_v"].astype(cdt)).reshape(B, S, H, D)
    g = jax.nn.silu(mixed(3) @ params["w_g"].astype(cdt))
    # data-dependent decay, in log space: log w = -exp(wx), clamped for
    # numerical safety of the chunked kernel (matches its contract).
    wx = (mixed(4) @ params["w_w"].astype(cdt)).reshape(B, S, H, D)
    logw = -jnp.exp(jnp.clip(wx.astype(jnp.float32), -20.0, 4.0))
    logw = jnp.maximum(logw, -8.0)
    return r, k, v, g, logw


def rwkv_scan(r, k, v, logw, u, state0=None):
    """Exact recurrence. r,k,v,logw: (B,S,H,D); u: (H,D).

    Returns (out (B,S,H,D) fp32, final state (B,H,D,D) fp32).
    """
    B, S, H, D = r.shape
    rt = jnp.moveaxis(r, 1, 0).astype(jnp.float32)   # (S,B,H,D)
    kt = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vt = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    wt = jnp.exp(jnp.moveaxis(logw, 1, 0))           # per-channel decay
    uf = u.astype(jnp.float32)

    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S_, inp):
        r_, k_, v_, w_ = inp
        kv = k_[..., :, None] * v_[..., None, :]      # (B,H,D,D)
        o = jnp.einsum("bhd,bhde->bhe", r_, S_ + uf[None, :, :, None] * kv)
        S_ = w_[..., :, None] * S_ + kv
        return S_, o

    state, out = jax.lax.scan(step, state0, (rt, kt, vt, wt))
    return jnp.moveaxis(out, 0, 1), state            # (B,S,H,E=D)


def rwkv_time_mix(params, x, cfg: ModelConfig, *, x_last=None, state=None):
    """Full time-mix block. x: (B,S,dm).

    ``x_last``/``state``: decode-time carries ((B,dm) previous input and
    (B,H,D,D) recurrence state).  Returns (out, (new_x_last, new_state)).
    """
    B, S, dm = x.shape
    H, D = cfg.rwkv_heads, cfg.rwkv_head_dim
    if x_last is None:
        x_last = jnp.zeros((B, dm), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, logw = _projections(params, x, x_prev, cfg)
    out, new_state = rwkv_scan(r, k, v, logw, params["u"], state)
    # per-head group norm, then output gate + projection
    out = out.reshape(B, S, H * D)
    out = rms_norm(out.reshape(B, S, H, D),
                   jnp.ones((D,), out.dtype), 1e-5).reshape(B, S, H * D)
    out = out.astype(cfg.cdtype) * params["ln_x"].astype(cfg.cdtype)
    out = (out * g) @ params["w_o"].astype(cfg.cdtype)
    return out, (x[:, -1, :], new_state)


def init_rwkv_channel_mix(key, cfg: ModelConfig):
    dm, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype
    params = {
        "w_in": trunc_normal(ks[0], (dm, dff), dm ** -0.5, dt),
        "w_out": trunc_normal(ks[1], (dff, dm), dff ** -0.5, dt),
        "mix": 0.5 * jnp.ones((dm,), dt),
    }
    logical = {"w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp"),
               "mix": ("fsdp",)}
    return params, logical


def rwkv_channel_mix(params, x, cfg: ModelConfig, *, x_last=None):
    """Squared-ReLU channel mix with token shift. Returns (out, new_x_last)."""
    B, S, dm = x.shape
    cdt = cfg.cdtype
    if x_last is None:
        x_last = jnp.zeros((B, dm), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    mix = params["mix"].astype(cdt)
    xm = x * mix + x_prev * (1.0 - mix)
    h = jnp.square(jax.nn.relu(xm @ params["w_in"].astype(cdt)))
    return h @ params["w_out"].astype(cdt), x[:, -1, :]
