"""Shared layer primitives: RMSNorm, RoPE, initializers.

Parameters are plain dicts of jnp arrays.  Every ``init_*`` function
returns ``(params, logical)`` where ``logical`` mirrors the params with a
tuple of logical-axis names per dimension (consumed by
``repro.sharding.spec_tree``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def init_linear(key, d_in, d_out, dtype, logical=("fsdp", "ff")):
    w = trunc_normal(key, (d_in, d_out), d_in ** -0.5, dtype)
    return w, tuple(logical)


def rms_norm(x, gamma, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, gamma, eps):
    """Per-head q/k norm (qwen3 style); x: (..., heads, head_dim)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
        ang = ang[None, :, None, :]                    # (1, S, 1, D/2)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs
        ang = ang[:, :, None, :]                       # (B, S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
