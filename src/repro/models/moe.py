"""Mixture-of-Experts FFN with capacity-based chunked dispatch.

TPU-native adaptation (see DESIGN.md): instead of a token sort (GPU
MegaBlocks style) we scan the sequence in fixed chunks and build a
(B, C_chunk, E, cap) one-hot dispatch tensor per chunk -- static shapes,
einsum-only (MXU friendly), and the dispatch working set stays small
enough for VMEM-blocked execution.  Capacity is enforced per (row, chunk);
overflow tokens are dropped (standard Switch-style with capacity_factor).

Expert weights layout: (E, d_model, d_ff) with d_ff sharded over "model"
(tensor parallel inside every expert) and d_model FSDP-sharded; when E is
divisible by the model axis the ``experts`` rule shards E instead
(expert parallelism) -- both handled by the logical->spec rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import trunc_normal


def init_moe(key, cfg: ModelConfig):
    E, dm, dff = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    params = {
        "router": trunc_normal(ks[0], (dm, E), dm ** -0.5, dt),
        "w_gate": trunc_normal(ks[1], (E, dm, dff), dm ** -0.5, dt),
        "w_up": trunc_normal(ks[2], (E, dm, dff), dm ** -0.5, dt),
        "w_down": trunc_normal(ks[3], (E, dff, dm), dff ** -0.5, dt),
    }
    logical = {
        "router": ("fsdp", "experts"),
        "w_gate": ("experts", "fsdp", "ff"),
        "w_up": ("experts", "fsdp", "ff"),
        "w_down": ("experts", "ff", "fsdp"),
    }
    return params, logical


def _dispatch_chunk(x, params, cfg: ModelConfig, valid=None):
    """One sequence chunk. x: (B, C, dm) -> (B, C, dm).

    ``valid``: optional (C,) bool -- padded tail tokens are excluded from
    routing so they never consume expert capacity.
    """
    moe = cfg.moe
    B, C, dm = x.shape
    E, k = moe.n_experts, moe.top_k
    cap = max(1, int(C * k / E * moe.capacity_factor))
    cdt = cfg.cdtype

    logits = jnp.einsum("bcd,de->bce", x, params["router"].astype(cdt))
    gate_logits, expert_idx = jax.lax.top_k(logits, k)        # (B, C, k)
    gates = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)

    # one-hot over experts per selection: (B, C, k, E)
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    if valid is not None:
        sel = sel * valid.astype(jnp.float32)[None, :, None, None]
    # position of each (token, selection) within its expert's capacity:
    # flatten (C, k) in priority order (token-major) and cumsum per expert.
    sel_flat = sel.reshape(B, C * k, E)
    pos = jnp.cumsum(sel_flat, axis=1) - sel_flat             # (B, C*k, E)
    pos = pos.reshape(B, C, k, E)
    in_cap = pos < cap
    pos_oh = jax.nn.one_hot(jnp.where(in_cap, pos, cap), cap,
                            dtype=jnp.float32)                # (B,C,k,E,cap)
    # combine[b,c,e,cap] = gate if token (b,c) routed to slot (e,cap)
    combine = jnp.einsum("bck,bcke,bckex->bcex",
                         gates, sel * in_cap.astype(jnp.float32), pos_oh)
    dispatch = (combine > 0).astype(cdt)                      # (B,C,E,cap)

    xe = jnp.einsum("bcex,bcd->bexd", dispatch, x)            # (B,E,cap,dm)
    wg = params["w_gate"].astype(cdt)
    wu = params["w_up"].astype(cdt)
    wd = params["w_down"].astype(cdt)
    h = jax.nn.silu(jnp.einsum("bexd,edf->bexf", xe, wg)) * \
        jnp.einsum("bexd,edf->bexf", xe, wu)
    ye = jnp.einsum("bexf,efd->bexd", h, wd)                  # (B,E,cap,dm)
    out = jnp.einsum("bcex,bexd->bcd", combine.astype(cdt), ye)
    return out


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, dm). Scans fixed-size sequence chunks through dispatch.

    With ``cfg.unroll`` (calibration mode) the chunk loop is a Python
    loop instead of a lax.scan, so HLO cost analysis counts every chunk.
    Inflating the chunk size instead (the old calibration trick) is wrong
    for MoE: capacity scales with the chunk, so the dispatch einsums are
    O(C^2) and a single S-sized chunk overstates dispatch FLOPs ~30x.
    """
    B, S, dm = x.shape
    C = min(cfg.moe.chunk, S)
    if S == C:
        return _dispatch_chunk(x, params, cfg)
    if cfg.unroll and S % C == 0:
        xs = [x[:, i * C:(i + 1) * C] for i in range(S // C)]
        return jnp.concatenate(
            [_dispatch_chunk(xc, params, cfg) for xc in xs], axis=1)
    if S % C:
        # pad the tail chunk; padded tokens are masked out of routing so
        # capacity competition matches the unpadded computation exactly
        pad = C - S % C
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Sp = S + pad
        valid = (jnp.arange(Sp) < S)
        xs = jnp.moveaxis(xp.reshape(B, Sp // C, C, dm), 1, 0)
        vs = valid.reshape(Sp // C, C)

        def stepv(_, xc_v):
            xc, vc = xc_v
            return None, _dispatch_chunk(xc, params, cfg, valid=vc)

        _, ys = jax.lax.scan(stepv, None, (xs, vs))
        return jnp.moveaxis(ys, 0, 1).reshape(B, Sp, dm)[:, :S]
    xs = jnp.moveaxis(x.reshape(B, S // C, C, dm), 1, 0)

    def step(_, xc):
        return None, _dispatch_chunk(xc, params, cfg)

    _, ys = jax.lax.scan(step, None, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, dm)
