"""Logical-axis -> PartitionSpec rules (doubly distributed sharding).

The paper's P x Q scheme generalized: the *observation* dimensions (batch,
dual variables) shard over ("pod", "data"); the *feature* dimensions
(vocab, heads, ff, experts, model-parallel contractions) shard over
"model"; remaining parameter dims are FSDP-sharded over ("pod", "data")
for ZeRO-3 style memory scaling.  Divisibility-aware: a rule silently
drops mesh axes that do not divide the dimension (e.g. mixtral's 8 experts
on a 16-wide model axis fall back to replication and the per-expert ff dim
carries the model sharding instead).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the batch/observation dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh) -> Tuple[str, ...]:
    return batch_axes(mesh)


def default_rules(mesh) -> Dict[str, Tuple[str, ...]]:
    b = batch_axes(mesh)
    return {
        "batch": b,
        "fsdp": b,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "expert_ff": ("model",),   # used when `experts` falls back
        "kv_len": ("model",),      # sequence-parallel KV cache (decode)
        "model_dim": (),           # activations keep d_model unsharded
        "seq": (),
        None: (),
    }


Rules = Dict[str, Tuple[str, ...]]


def _axes_fit(dim: int, axes: Sequence[str], mesh) -> Tuple[str, ...]:
    """Largest prefix of ``axes`` whose total size divides ``dim``."""
    out = []
    prod = 1
    for a in axes:
        size = mesh.shape[a]
        if dim % (prod * size) == 0:
            out.append(a)
            prod *= size
        else:
            break
    return tuple(out)


def logical_to_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                    mesh, rules: Optional[Rules] = None) -> P:
    """Map per-dimension logical names to a PartitionSpec.

    Divisibility fallback per dim; also guarantees no mesh axis is used
    twice in one spec (first dim wins).
    """
    rules = rules or default_rules(mesh)
    used = set()
    parts = []
    for dim, name in zip(shape, logical):
        axes = _axes_fit(dim, [a for a in rules.get(name, ()) if a not in used],
                         mesh)
        for a in axes:
            used.add(a)
        parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*parts)


def _is_logical_leaf(x):
    return isinstance(x, tuple) and (len(x) == 0 or all(
        isinstance(e, (str, type(None))) for e in x))


def spec_tree(logical_tree, param_tree, mesh, rules: Optional[Rules] = None):
    """Build a PartitionSpec pytree parallel to ``param_tree``.

    ``logical_tree`` mirrors the structure with tuples of logical axis names
    (or None) per array dimension (a tuple-of-strings leaf).
    """
    return jax.tree.map(
        lambda l, p: logical_to_spec(p.shape, l, mesh, rules),
        logical_tree, param_tree, is_leaf=_is_logical_leaf)


def constrain(x, mesh, *logical, rules: Optional[Rules] = None):
    """with_sharding_constraint by logical axis names."""
    spec = logical_to_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
