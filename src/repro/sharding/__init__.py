from .rules import (Rules, batch_axes, fsdp_axes, logical_to_spec,
                    spec_tree, constrain)
