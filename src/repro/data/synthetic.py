"""Synthetic data generators following the paper's §IV procedure.

"the x_i's and w were sampled from the [-1,1] uniform distribution;
 y_i = sgn(w^T x_i), and the sign of each y_i was randomly flipped with
 probability 0.1.  The features were standardized to have unit variance."
"""
from __future__ import annotations

import numpy as np


def make_svm_data(n: int, m: int, *, flip=0.1, seed=0, standardize=True):
    """Dense synthetic binary classification data (paper, part 1)."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1.0, 1.0, size=(n, m))
    w = rng.uniform(-1.0, 1.0, size=(m,))
    y = np.sign(X @ w)
    y[y == 0] = 1.0
    flips = rng.random(n) < flip
    y = np.where(flips, -y, y)
    if standardize:
        X = X / X.std(axis=0, keepdims=True)
    return X.astype(np.float32), y.astype(np.float32)


def make_sparse_svm_data(n: int, m: int, *, density=0.01, flip=0.1, seed=0):
    """Sparse variant used by the weak-scaling experiments (r = 1%, 5%).

    Returned dense (the block algorithms are dense-tile based on TPU; the
    sparsity only affects the spectrum / scaling behaviour, which is what
    the paper's weak-scaling experiment studies).
    """
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1.0, 1.0, size=(n, m))
    mask = rng.random((n, m)) < density
    X = X * mask
    w = rng.uniform(-1.0, 1.0, size=(m,))
    z = X @ w
    y = np.sign(z)
    y[y == 0] = 1.0
    flips = rng.random(n) < flip
    y = np.where(flips, -y, y)
    std = X.std(axis=0, keepdims=True)
    std[std == 0] = 1.0
    X = X / std
    return X.astype(np.float32), y.astype(np.float32)
