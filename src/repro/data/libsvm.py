"""Minimal LIBSVM-format text reader/writer (realsim / news20 style files).

No third-party deps; tolerant of 0- or 1-based feature indices.
"""
from __future__ import annotations

import numpy as np


def load_libsvm(path: str, n_features: int | None = None):
    """Parse a libsvm text file into dense (X, y) float32 arrays."""
    rows, cols, vals, ys = [], [], [], []
    with open(path, "r") as fh:
        for r, line in enumerate(fh):
            parts = line.split()
            if not parts:
                continue
            ys.append(float(parts[0]))
            for tok in parts[1:]:
                c, v = tok.split(":")
                rows.append(r)
                cols.append(int(c))
                vals.append(float(v))
    n = len(ys)
    if not cols:
        raise ValueError(f"{path}: no features parsed")
    base = min(cols)          # 1-based files -> shift to 0
    m = (n_features or (max(cols) - base + 1))
    X = np.zeros((n, m), dtype=np.float32)
    for r, c, v in zip(rows, cols, vals):
        X[r, c - base] = v
    y = np.asarray(ys, dtype=np.float32)
    y = np.where(y > 0, 1.0, -1.0).astype(np.float32)
    return X, y


def save_libsvm(path: str, X, y):
    with open(path, "w") as fh:
        for xi, yi in zip(np.asarray(X), np.asarray(y)):
            nz = np.nonzero(xi)[0]
            feats = " ".join(f"{j + 1}:{xi[j]:.6g}" for j in nz)
            fh.write(f"{int(yi)} {feats}\n")
