"""Minimal LIBSVM-format text reader/writer (realsim / news20 style files).

No third-party deps; tolerant of 0- or 1-based feature indices.
``load_libsvm_csr`` streams straight into :class:`~repro.data.sparse.
CSRMatrix` -- O(nnz) host memory, never a dense matrix -- which is how
news20-sized files enter the sparse block pipeline.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix


def load_libsvm_csr(path: str, n_features: int | None = None):
    """Stream a libsvm text file into (CSRMatrix, y) without densifying.

    One pass over the file accumulating flat index/value arrays; the
    dense matrix is never materialized, so peak memory is O(nnz).
    """
    indptr, cols, vals, ys = [0], [], [], []
    with open(path, "r") as fh:
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            ys.append(float(parts[0]))
            for tok in parts[1:]:
                c, v = tok.split(":")
                cols.append(int(c))
                vals.append(float(v))
            indptr.append(len(cols))
    if not cols:
        raise ValueError(f"{path}: no features parsed")
    cols = np.asarray(cols, dtype=np.int64)
    base = int(cols.min())    # 1-based files -> shift to 0
    cols -= base
    m = n_features or int(cols.max() + 1)
    y = np.asarray(ys, dtype=np.float32)
    y = np.where(y > 0, 1.0, -1.0).astype(np.float32)
    csr = CSRMatrix(indptr=np.asarray(indptr, dtype=np.int64),
                    indices=cols.astype(np.int32),
                    data=np.asarray(vals, dtype=np.float32),
                    shape=(len(ys), m))
    return csr, y


def load_libsvm(path: str, n_features: int | None = None):
    """Parse a libsvm text file into dense (X, y) float32 arrays."""
    csr, y = load_libsvm_csr(path, n_features)
    return csr.toarray(), y


def save_libsvm(path: str, X, y):
    with open(path, "w") as fh:
        for xi, yi in zip(np.asarray(X), np.asarray(y)):
            nz = np.nonzero(xi)[0]
            feats = " ".join(f"{j + 1}:{xi[j]:.6g}" for j in nz)
            fh.write(f"{int(yi)} {feats}\n")
