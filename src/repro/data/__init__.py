from .synthetic import make_svm_data, make_sparse_svm_data
from .libsvm import load_libsvm, save_libsvm
from .tokens import TokenPipeline, synthetic_token_batch
