from .synthetic import make_svm_data, make_sparse_svm_data
from .sparse import CSRMatrix, csr_from_dense, make_sparse_svm_csr
from .libsvm import load_libsvm, load_libsvm_csr, save_libsvm
from .tokens import TokenPipeline, synthetic_token_batch

__all__ = [
    "make_svm_data", "make_sparse_svm_data",
    "CSRMatrix", "csr_from_dense", "make_sparse_svm_csr",
    "load_libsvm", "load_libsvm_csr", "save_libsvm",
    "TokenPipeline", "synthetic_token_batch",
]
