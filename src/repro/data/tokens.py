"""Deterministic sharded LM token pipeline.

Production shape: every host generates (or reads) only its shard of the
global batch, determined by (step, process_index) -- no host ever
materializes the global batch.  Here the source is a seeded PRNG stream
standing in for a tokenized corpus; swapping in a real corpus reader only
changes ``_shard_tokens``.

A double-buffering prefetch thread hides host->device transfer behind the
previous step's compute (the standard input-pipeline overlap trick).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


def synthetic_token_batch(step: int, *, batch: int, seq: int, vocab: int,
                          seed: int = 0, shard: tuple[int, int] = (0, 1)):
    """Deterministic batch for global ``step``; returns this host's rows.

    shard = (shard_index, shard_count).  Row r of the global batch is
    generated independently of sharding, so re-sharding (elastic scaling)
    replays identical data.
    """
    idx, count = shard
    rows = batch // count
    lo = idx * rows
    out = np.empty((rows, seq + 1), dtype=np.int32)
    for r in range(rows):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, lo + r]))
        out[r] = rng.integers(0, vocab, size=(seq + 1,), dtype=np.int32)
    return {"tokens": out[:, :-1], "labels": out[:, 1:]}


class TokenPipeline:
    """Background prefetcher with a bounded buffer (depth 2 by default)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2,
                 sharding=None):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._sharding = sharding
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            try:
                self._q.put((step, batch), timeout=0.5)
            except queue.Full:
                continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
