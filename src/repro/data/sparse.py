"""Host-side sparse (CSR) containers for news20-scale instances.

The paper's headline experiments (news20, real-sim, the weak-scaling runs
at 1-5% density) are sparse; materializing them dense caps the
reproduction far below paper scale.  This module provides the numpy-only
CSR container the sparse execution path is built on:

  * :class:`CSRMatrix` -- indptr/indices/data triplet with just enough
    linear algebra (``X @ w``, ``X.T @ alpha``) for the solver driver's
    objective / duality-gap bookkeeping, computed with jnp scatter/gather
    so it never densifies;
  * ``csr_from_dense`` -- conversion for tests and small instances;
  * ``make_sparse_svm_csr`` -- the paper's §IV sparse synthetic generator
    emitting CSR directly (per-row index sampling), so a news20-profile
    instance costs O(nnz) host memory instead of O(n*m).

The device-side block format (padded ELL per (p, q) cell) lives in
``repro.core.partition``; this module stays numpy/host only except for
the two matvecs.  No scipy dependency (matching ``data.libsvm``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class _CSRTransposed:
    """View returned by ``CSRMatrix.T``: supports only ``.T @ alpha``."""

    csr: "CSRMatrix"

    @property
    def shape(self):
        n, m = self.csr.shape
        return (m, n)

    def __matmul__(self, alpha):
        return self.csr.rmatvec(alpha)


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed sparse rows, numpy-backed.

    ``indptr`` (n+1,) int64, ``indices`` (nnz,) int32 column ids,
    ``data`` (nnz,) float32, ``shape`` = (n, m).  Duck-types the two
    matrix products the solver driver needs (``X @ w`` and
    ``X.T @ alpha``), returning jnp arrays.
    """

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: tuple

    def __post_init__(self):
        n = self.shape[0]
        if self.indptr.shape != (n + 1,):
            raise ValueError(
                f"indptr shape {self.indptr.shape} != ({n + 1},)")
        if self.indices.shape != self.data.shape:
            raise ValueError("indices and data must have the same length")

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def density(self) -> float:
        n, m = self.shape
        return self.nnz / float(max(n * m, 1))

    def row_nnz(self) -> np.ndarray:
        """(n,) number of stored entries per row."""
        return np.diff(self.indptr).astype(np.int64)

    def row_ids(self) -> np.ndarray:
        """(nnz,) COO row index of every stored entry."""
        return np.repeat(
            np.arange(self.shape[0], dtype=np.int64), self.row_nnz())

    def _device_coo(self):
        """Cached jnp copies of (data, indices, row_ids) for the two
        matvecs -- the solve driver evaluates the objective every outer
        iteration, and at news20 scale re-repeating / re-transferring
        ~10M-entry arrays per call would dominate the bookkeeping."""
        cached = getattr(self, "_coo_cache", None)
        if cached is None:
            import jax.numpy as jnp
            cached = (jnp.asarray(self.data), jnp.asarray(self.indices),
                      jnp.asarray(self.row_ids()))
            object.__setattr__(self, "_coo_cache", cached)  # frozen dataclass
        return cached

    def toarray(self) -> np.ndarray:
        """Densify (small instances / reference solves only)."""
        n, m = self.shape
        X = np.zeros((n, m), dtype=np.float32)
        X[self.row_ids(), self.indices] = self.data
        return X

    # ---- the two products the solver driver needs -------------------------
    def matvec(self, w):
        """X @ w -> (n,) jnp array."""
        import jax.numpy as jnp
        data, indices, rows = self._device_coo()
        contrib = data * jnp.asarray(w)[indices]
        return jnp.zeros((self.shape[0],), contrib.dtype).at[rows].add(
            contrib)

    def rmatvec(self, alpha):
        """X.T @ alpha -> (m,) jnp array."""
        import jax.numpy as jnp
        data, indices, rows = self._device_coo()
        contrib = data * jnp.asarray(alpha)[rows]
        return jnp.zeros((self.shape[1],), contrib.dtype).at[indices].add(
            contrib)

    def __matmul__(self, w):
        return self.matvec(w)

    @property
    def T(self):
        return _CSRTransposed(self)


def csr_from_dense(X) -> CSRMatrix:
    """Dense (n, m) array -> :class:`CSRMatrix` (row-major nonzeros)."""
    X = np.asarray(X, dtype=np.float32)
    n, m = X.shape
    rows, cols = np.nonzero(X)
    order = np.lexsort((cols, rows))     # row-major
    rows, cols = rows[order], cols[order]
    indptr = np.zeros((n + 1,), dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(indptr=indptr, indices=cols.astype(np.int32),
                     data=X[rows, cols].astype(np.float32), shape=(n, m))


def make_sparse_svm_csr(n: int, m: int, *, density=0.01, flip=0.1, seed=0,
                        standardize=True) -> tuple:
    """Sparse synthetic SVM instance emitted directly as CSR.

    Follows the paper's §IV recipe (uniform [-1, 1] entries and planted
    ``w``, labels ``sgn(w^T x)`` with 10% flips, unit-variance columns)
    but never materializes the dense matrix: per-row nonzero counts are
    Binomial(m, density) (min 1 so every observation has a label signal)
    and standardization uses the exact column moments of the sparse
    entries (zeros included), which matches the dense generator's
    ``X / X.std(axis=0)``.

    Returns ``(CSRMatrix, y)`` with y in {-1, +1} float32.
    """
    rng = np.random.default_rng(seed)
    counts = np.maximum(rng.binomial(m, density, size=n), 1)
    indptr = np.zeros((n + 1,), dtype=np.int64)
    indptr[1:] = np.cumsum(counts)
    nnz = int(indptr[-1])
    indices = np.empty((nnz,), dtype=np.int32)
    for i in range(n):
        indices[indptr[i]:indptr[i + 1]] = np.sort(
            rng.choice(m, size=counts[i], replace=False))
    data = rng.uniform(-1.0, 1.0, size=nnz).astype(np.float32)

    w = rng.uniform(-1.0, 1.0, size=m).astype(np.float32)
    rows = np.repeat(np.arange(n, dtype=np.int64), counts)
    z = np.zeros((n,), dtype=np.float64)
    np.add.at(z, rows, data.astype(np.float64) * w[indices])
    y = np.sign(z)
    y[y == 0] = 1.0
    flips = rng.random(n) < flip
    y = np.where(flips, -y, y).astype(np.float32)

    if standardize:
        # column std over ALL n entries (zeros included), population form
        s1 = np.zeros((m,), dtype=np.float64)
        s2 = np.zeros((m,), dtype=np.float64)
        np.add.at(s1, indices, data.astype(np.float64))
        np.add.at(s2, indices, data.astype(np.float64) ** 2)
        var = s2 / n - (s1 / n) ** 2
        std = np.sqrt(np.maximum(var, 0.0))
        std[std == 0] = 1.0
        data = (data / std[indices]).astype(np.float32)

    return CSRMatrix(indptr=indptr, indices=indices, data=data,
                     shape=(n, m)), y
