"""CommSchedule: communication policy as a first-class solver axis.

The paper's three doubly distributed optimizers are all "local
sub-problem solves stitched together by cross-node reductions".  Until
Engine API v2 each engine hard-coded *when* and *how* those reductions
happened (inline ``jax.lax.psum`` calls in the shard_map cells, einsum
contractions in the simulated grid), so a new communication policy --
e.g. the Hogwild-style delayed psum of Fang & Klabjan (2018) -- meant
forking every solver.

This module makes the reduction points explicit:

  * a solver's program builder *declares* its collectives once::

        sched = (CommSchedule()
                 .pmean("dalpha", axis="model")   # step 6 dual average
                 .psum("w_contrib", axis="data")) # step 9 primal-dual map

  * its per-cell step math *executes* them by name through a
    :class:`Comm` handed in by the engine::

        a_new = a_b + comm("dalpha", dalpha) / Pn
        w_new = comm("w_contrib", contrib) / (lam * n)

  * the engine picks the executor -- :class:`SyncComm` applies every
    reduction immediately (today's behavior; works identically inside a
    named-``vmap`` grid and inside a ``shard_map`` cell, because both
    execute ``lax.psum`` over named axes), while :class:`StaleComm`
    applies reductions with bounded staleness tau: the value *returned*
    at outer step t is the reduction *computed* at step
    ``max(1, t - tau)``, carried in a fixed-size FIFO buffer that is
    part of the engine state pytree.  ``tau = 0`` short-circuits to the
    sync path, so the async engine at zero staleness reproduces the
    sync engine exactly (same computation, bit-identical iterates).

Axes are *logical* ("data" = observation partitions, "model" = feature
partitions); the engine maps them to concrete vmap axis names or mesh
axis names (possibly tuples, e.g. ("pod", "data") on a multi-pod mesh).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .util import axes_index

LOGICAL_AXES = ("data", "model")
OPS = ("psum", "pmean", "allgather")


@dataclasses.dataclass(frozen=True)
class Collective:
    """One declared reduction point of a solver program."""

    name: str
    op: str        # "psum" | "pmean" | "allgather"
    axis: str      # logical grid axis reduced over: "data" | "model"

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"collective {self.name!r}: op={self.op!r}; "
                             f"expected one of {OPS}")
        if self.axis not in LOGICAL_AXES:
            raise ValueError(f"collective {self.name!r}: axis={self.axis!r}; "
                             f"expected one of {LOGICAL_AXES}")

    @property
    def result_axis(self) -> str:
        """Logical axis the reduction *result* still varies over."""
        return "model" if self.axis == "data" else "data"


class CommSchedule:
    """Ordered declaration of a solver's named reduction points."""

    def __init__(self):
        self._points: Dict[str, Collective] = {}

    # -- declaration (chainable) --------------------------------------------
    def _add(self, name: str, op: str, axis: str) -> "CommSchedule":
        if name in self._points:
            raise ValueError(f"collective {name!r} declared twice")
        self._points[name] = Collective(name, op, axis)
        return self

    def psum(self, name: str, *, axis: str) -> "CommSchedule":
        """Declare a sum-reduction over a logical grid axis."""
        return self._add(name, "psum", axis)

    def pmean(self, name: str, *, axis: str) -> "CommSchedule":
        """Declare a mean-reduction over a logical grid axis."""
        return self._add(name, "pmean", axis)

    def allgather(self, name: str, *, axis: str) -> "CommSchedule":
        """Declare a gather over a logical grid axis: the per-cell value
        is stacked along a new leading axis of that axis's extent."""
        return self._add(name, "allgather", axis)

    # -- lookup --------------------------------------------------------------
    def __getitem__(self, name: str) -> Collective:
        try:
            return self._points[name]
        except KeyError:
            raise KeyError(
                f"reduction {name!r} is not declared in this CommSchedule "
                f"(declared: {sorted(self._points)}); declare it with "
                ".psum(name, axis=...) / .pmean(name, axis=...) in the "
                "program builder") from None

    def __contains__(self, name: str) -> bool:
        return name in self._points

    def __iter__(self):
        return iter(self._points.values())

    @property
    def names(self) -> Tuple[str, ...]:
        """The declared collective names, in declaration order."""
        return tuple(self._points)


class Comm:
    """Executor handed to a cell: runs the declared collectives.

    ``axis_map`` maps logical axes to the concrete axis names of the
    execution context (vmap axis names for the simulated grid, mesh axis
    names -- possibly tuples -- for the shard_map engines); ``sizes``
    gives the logical grid extents (P, Q) as static ints.
    """

    def __init__(self, schedule: CommSchedule, axis_map: Dict[str, tuple],
                 sizes: Dict[str, int]):
        self.schedule = schedule
        self.axis_map = {k: (v,) if isinstance(v, str) else tuple(v)
                         for k, v in axis_map.items()}
        self.sizes = dict(sizes)
        self._executed: set = set()
        #: staleness FIFO slots produced this step (only StaleComm fills it)
        self.bufs_out: Dict[str, jnp.ndarray] = {}
        #: exact payload bytes this cell put on the wire, per collective
        #: (executors that shrink the payload -- CompressedComm --
        #: record their own number; everyone else reports the
        #: uncompressed size)
        self.wire_bytes: Dict[str, int] = {}

    # -- cell-facing API -----------------------------------------------------
    def __call__(self, name: str, value):
        """Execute the declared collective ``name`` on ``value``.

        Args:
          name: a collective declared in this executor's CommSchedule.
          value: the cell's per-step payload (any array).

        Returns:
          The reduction result under this executor's policy --
          psum/pmean keep the payload shape, allgather prepends the
          axis extent; staleness executors may return a prior step's
          reduction.

        Raises:
          KeyError: when ``name`` was never declared in the schedule.
          ValueError: when the cell executes the same point twice in
            one outer step.
        """
        point = self.schedule[name]
        if name in self._executed:
            raise ValueError(f"reduction {name!r} executed twice in one "
                             "step; declare a second point instead")
        self._executed.add(name)
        out = self._exec(point, value)
        if name not in self.wire_bytes:
            v = jnp.asarray(value)
            self.wire_bytes[name] = (math.prod(v.shape)
                                     * jnp.dtype(v.dtype).itemsize)
        return out

    def axis_index(self, axis: str):
        """Collapsed linear cell index along a logical axis."""
        return axes_index(self.axis_map[axis])

    def axis_size(self, axis: str) -> int:
        """Static extent of a logical grid axis (P or Q)."""
        return self.sizes[axis]

    def finalize(self):
        """Check the schedule contract: every declared point ran once."""
        missing = set(self.schedule.names) - self._executed
        if missing:
            raise ValueError(
                f"declared reductions never executed: {sorted(missing)}; "
                "the cell must run every point of its CommSchedule exactly "
                "once per outer step")

    # -- engine-facing -------------------------------------------------------
    def _exec(self, point: Collective, value):
        raise NotImplementedError


class SyncComm(Comm):
    """Apply every reduction immediately (the paper's synchronous outer
    loop).  Works unchanged inside a named-``vmap`` grid and inside a
    ``shard_map`` cell -- both execute collectives over named axes.

    All reduction executors (this one, :class:`StaleComm`,
    :class:`OverlapComm`) funnel the *actual wire operation* through the
    :meth:`_reduce` hook, so the hierarchical two-level reduction below
    composes with every consumption policy.

    **Hierarchical topology-aware reduction** (``set_topology``): when a
    :class:`~repro.core.comm_model.Topology` with ``pods > 1`` is set,
    a psum/pmean over the pod-split logical axis is executed as a
    two-level axis split -- a full-precision psum over the intra-pod
    axes followed by a codec-compressed psum over the pod axis (the
    cheap fat link carries full floats, the expensive thin link carries
    the codec payload).  The engine expresses the pod split as real
    named axes: the logical axis must map to >= 2 concrete axes with the
    pod axis leading (e.g. ``("pod", "data")`` on a multi-pod mesh, or a
    third named-vmap level on the simulated grid).  A stateful cross-pod
    codec carries its error-feedback residual per (cell, collective) in
    ``hier_ef_in``/``hier_ef_out`` -- threaded through the engine state
    exactly like :class:`CompressedComm`'s residuals, and *distinct*
    from them (a per-collective policy codec compresses the cell
    payload before any reduction; the topology codec compresses the
    intra-pod partial sum).
    """

    #: two-level reduction disabled until ``set_topology`` is called
    topology = None

    def set_topology(self, topology, codec, ef: Optional[dict] = None):
        """Enable hierarchical reduction over ``topology.axis``.

        ``codec`` is the cross-pod codec instance; ``ef`` maps
        collective name -> this cell's error-feedback residual (required
        for stateful codecs, allocated by the engine against the
        intra-pod partial-sum aval == the per-cell payload aval)."""
        self.topology = topology
        self._hier_codec = codec
        self.hier_ef_in = dict(ef or {})
        #: updated residuals, harvested by the engine after the cell runs
        self.hier_ef_out: Dict[str, jnp.ndarray] = {}

    def _reduce(self, point: Collective, value):
        """The wire operation: fresh reduction of this step's value."""
        axes = self.axis_map[point.axis]
        topo = self.topology
        if (topo is not None and topo.pods > 1 and point.axis == topo.axis
                and point.op != "allgather"):
            return self._reduce_hierarchical(point, value, axes)
        if point.op == "psum":
            return jax.lax.psum(value, axes)
        if point.op == "pmean":
            return jax.lax.pmean(value, axes)
        return jax.lax.all_gather(value, axes)

    def _reduce_hierarchical(self, point: Collective, value, axes):
        if len(axes) < 2:
            raise ValueError(
                f"hierarchical reduction over {point.axis!r} needs a "
                f"two-level axis split (pod axis + intra-pod axes); the "
                f"engine mapped it to {axes!r}. Build the program with a "
                "pod-split mesh/grid (topology=...) end to end.")
        pod_axes, inner_axes = axes[:1], axes[1:]
        part = jnp.asarray(jax.lax.psum(value, inner_axes))
        codec = self._hier_codec
        if codec.stateful:
            try:
                err = self.hier_ef_in[point.name]
            except KeyError:
                raise KeyError(
                    f"no cross-pod error-feedback residual for reduction "
                    f"{point.name!r}; the engine allocates one per "
                    "pod-split collective at build time") from None
            deq, new_err = codec.apply(part, err)
            self.hier_ef_out[point.name] = new_err
        else:
            deq, _ = codec.apply(part)
        out = jax.lax.psum(jnp.asarray(deq).astype(part.dtype), pod_axes)
        if point.op == "pmean":
            out = out / self.sizes[point.axis]
        return out

    def _exec(self, point: Collective, value):
        return self._reduce(point, value)


class LocalComm(Comm):
    """Collective-free executor for per-phase wall-clock attribution.

    Every declared point is executed CELL-LOCALLY: psum/pmean return the
    cell's own contribution unchanged and allgather broadcasts it to the
    gathered shape -- same aval as the real reduction, zero bytes on the
    wire.  The numerics are wrong on purpose; a program built with this
    executor (``EngineProgram.local_step``) is only ever *timed*, never
    consumed: the difference between stepping the real program and
    stepping this one isolates the communication cost
    (:func:`repro.obs.phases.calibrate_phases`).
    """

    def _exec(self, point: Collective, value):
        if point.op == "allgather":
            value = jnp.asarray(value)
            return jnp.broadcast_to(
                value[None], (self.sizes[point.axis],) + value.shape)
        return value


class ShapeProbeComm(Comm):
    """Collective-free executor that records each point's per-cell result
    aval (and, optionally, its per-cell *payload* aval -- the input the
    cell hands to ``comm``, which is what travels the wire and what an
    error-feedback residual must match).  Used once at build time (under
    ``jax.eval_shape``, OUTSIDE any mesh/vmap axis context) so the
    engines can allocate staleness rings / EF buffers and price the
    wire before the first step.  psum/pmean preserve the per-cell
    shape; allgather prepends the axis extent.
    """

    def __init__(self, schedule, axis_map, sizes, record: dict,
                 payloads: Optional[dict] = None):
        super().__init__(schedule, axis_map, sizes)
        self._record = record
        self._payloads = payloads if payloads is not None else {}

    def axis_index(self, axis: str):
        # no axis context under eval_shape; any in-range index has the
        # right aval (indices only feed PRNG folds / slice starts)
        return jnp.zeros((), jnp.int32)

    def _exec(self, point, value):
        value = jnp.asarray(value)
        self._payloads[point.name] = jax.ShapeDtypeStruct(
            value.shape, value.dtype)
        if point.op == "allgather":
            out = jnp.broadcast_to(
                value[None], (self.sizes[point.axis],) + value.shape)
        else:
            out = value
        self._record[point.name] = jax.ShapeDtypeStruct(out.shape, out.dtype)
        return out


class StaleComm(SyncComm):
    """Bounded-staleness executor (the async engine's policy).

    The reduction result *applied* at outer step t is the one *computed*
    at step ``max(1, t - tau)``.  Each point carries a ``(tau, ...)``
    FIFO ring in the engine state: slot ``(t-1) % tau`` holds the
    reduction of step ``t - tau``, which is read just before the fresh
    value overwrites it.

    **Warm-up semantics (pinned by tests/test_comm.py):** at t = 1 every
    ring slot is seeded with the *first* reduction, so the first ``tau``
    steps consume the reduction of step ``max(1, t - tau)`` -- i.e.
    steps 1..tau+1 all consume step 1's value, never zeros from
    initialization and never a partially-filled ring.  This is the same
    contract the overlap engine needs: during warm-up there is nothing
    in flight to await, so the dispatch of step 1 is the only value
    available.

    The fresh collective still executes every step -- on real hardware
    the reduction would be launched asynchronously and *consumed* tau
    steps later; semantically (and for convergence studies, which is
    what this engine is for) only the consumption delay matters.

    ``tau = 0`` never touches a buffer and returns the fresh value, so
    the async engine at zero staleness is the sync engine, bit for bit.

    ``wire_bytes`` accounting is **additive, not policy-dependent**: the
    ring only re-times consumption, every step still puts exactly one
    payload per declared point on the wire, so sync / stale / overlap
    report identical byte totals for the identity codec (tested).
    """

    def __init__(self, schedule, axis_map, sizes, *, tau: int, t,
                 bufs: Optional[dict] = None):
        super().__init__(schedule, axis_map, sizes)
        if tau < 0:
            raise ValueError(f"staleness tau={tau} must be >= 0")
        self.tau = int(tau)
        self.t = t                         # traced outer-iteration counter
        self.bufs_in = bufs or {}

    def _exec(self, point, value):
        # the wire op goes through the _reduce hook so the hierarchical
        # two-level reduction composes with the staleness ring
        fresh = self._reduce(point, value)
        if self.tau == 0:
            return fresh
        try:
            buf = self.bufs_in[point.name]   # (tau, *cell result shape)
        except KeyError:
            raise KeyError(
                f"no staleness buffer for reduction {point.name!r}; the "
                "async engine allocates one per declared point at build "
                "time -- was the schedule changed after program "
                "construction?") from None
        slot = (self.t - 1) % self.tau
        stale = jax.lax.dynamic_index_in_dim(buf, slot, 0, keepdims=False)
        first = self.t == 1
        stale = jnp.where(first, fresh, stale)
        updated = jax.lax.dynamic_update_index_in_dim(
            buf, fresh.astype(buf.dtype), slot, 0)
        seeded = jnp.broadcast_to(fresh, buf.shape).astype(buf.dtype)
        self.bufs_out[point.name] = jnp.where(first, seeded, updated)
        return stale

    def finalize(self):
        super().finalize()
        if self.tau and set(self.bufs_out) != set(self.schedule.names):
            raise ValueError("staleness buffers out of sync with schedule")


class OverlapComm(StaleComm):
    """Communication-overlap executor (the overlap engine's policy).

    Same consumption contract as :class:`StaleComm` -- the value applied
    at step t is the reduction *dispatched* at step ``max(1, t - tau)``
    -- but the engine built around it actually lets the wire overlap
    the local solve:

      * inside the jitted step the ring slots are the *reduction
        in-flight buffers*: the fresh collective's result is written to
        the slot that will be consumed tau steps later and nothing
        downstream of this step's local solve depends on it, so XLA's
        latency-hiding scheduler is free to run the collective
        concurrently with the cell-local SDCA/SVRG kernels of steps
        t..t+tau.  The engine donates the ring buffers to the step
        (double-buffered slots, no defensive copy) to keep that window
        open on accelerator backends;
      * on the host path the driver never calls ``block_until_ready``
        on the rings between steps -- only the iterate substate is
        synced at observation points (``EngineProgram.sync_of``), so
        dispatch returns a future and the await happens tau steps
        later when the slot is next read.

    Because consumption timing is identical to :class:`StaleComm`, the
    overlap engine's trajectories match the async engine at equal tau
    (and the sync engine bit-for-bit at tau = 0): overlap changes
    *wall-clock*, never numerics.  Error-feedback residuals of a
    composed :class:`CompressedComm` live with the **dispatch** step by
    construction -- the codec encodes the payload before ``_reduce``
    ever sees it, so the residual written to the engine state at step t
    is the one produced by the payload dispatched at step t.
    """

    #: engines key off this to enable donation + selective host sync
    overlap = True


def hier_ef_names(schedule: CommSchedule, topology) -> Tuple[str, ...]:
    """Names of collectives that need a cross-pod error-feedback
    residual under ``topology``: the psum/pmean points over the
    pod-split axis, when the cross-pod codec is stateful."""
    if topology is None or topology.pods <= 1:
        return ()
    from .compress import get_codec
    if not get_codec(topology.codec).stateful:
        return ()
    return tuple(p.name for p in schedule
                 if p.axis == topology.axis and p.op != "allgather")
