"""Convex losses for the ERM objective (1) of Nathan & Klabjan (2016).

    min_w  F(w) = (1/n) sum_i f_i(w^T x_i) + lambda ||w||^2

Every loss provides:
  * ``value(z, y)``      -- f_i(z) parametrized by the label y
  * ``grad(z, y)``       -- df/dz (a subgradient for hinge)
  * ``conj(a, y)``       -- the convex conjugate phi_i*(-a) used by the dual
                            objective (2); +inf outside the dual feasible box
                            is encoded by ``dual_bounds``.
  * ``dual_bounds(y)``   -- feasible interval for the dual variable alpha_i
  * ``sdca_delta(...)``  -- the (approximate) maximizer of the *local* D3CA
                            objective of Algorithm 2 step 3 (scaled by 1/Q):
        max_d  (1/Q) * (-phi*(-(alpha+d))) - (lam*n/2) ||w + d*x/(lam n)||^2
    closed form for hinge / squared, a few Newton steps for logistic.

All functions are elementwise and jit/vmap-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    name: str
    value: Callable
    grad: Callable
    conj: Callable
    dual_bounds: Callable
    sdca_delta: Callable

    def objective(self, X, y, w, lam, mask=None, n=None):
        """Primal objective F(w); `mask` marks real (non-padded) rows."""
        z = X @ w
        vals = self.value(z, y)
        if mask is not None:
            vals = vals * mask
        n_eff = n if n is not None else (mask.sum() if mask is not None else X.shape[0])
        # NOTE: the paper writes lam*||w||^2 in eq. (1) but its dual (2),
        # primal-dual map (3) and the SDCA closed form are all derived under
        # the standard (lam/2)*||w||^2 convention -- we use the latter
        # consistently (recorded in DESIGN.md §4).
        return vals.sum() / n_eff + 0.5 * lam * jnp.sum(w * w)

    def dual_objective(self, X, y, alpha, lam, mask=None, n=None):
        """Dual objective D(alpha) of eq. (2)."""
        if mask is not None:
            alpha = alpha * mask
        n_eff = n if n is not None else (mask.sum() if mask is not None else X.shape[0])
        v = X.T @ alpha / (lam * n_eff)
        conj_term = self.conj(alpha, y)
        if mask is not None:
            conj_term = conj_term * mask
        return -conj_term.sum() / n_eff - lam / 2.0 * jnp.sum(v * v)


# ----------------------------------------------------------------------------
# hinge: f(z) = max(0, 1 - y z);  phi*(-a) = -a y, feasible iff a*y in [0, 1]
# ----------------------------------------------------------------------------

def _hinge_value(z, y):
    return jnp.maximum(0.0, 1.0 - y * z)


def _hinge_grad(z, y):
    return jnp.where(y * z < 1.0, -y, 0.0)


def _hinge_conj(a, y):
    # phi*(-a) = -a*y  on the feasible box (0 <= a*y <= 1)
    return -a * y


def _hinge_bounds(y):
    lo = jnp.where(y > 0, 0.0, -1.0)
    hi = jnp.where(y > 0, 1.0, 0.0)
    return lo, hi


def _hinge_sdca_delta(alpha, x_sq, zloc, y, lam, n, Q, beta=None):
    """Closed-form local maximizer for hinge (see DESIGN.md §4).

    d/dD [ (1/Q)(alpha+D) y - zloc*D - D^2 ||x||^2/(2 lam n) ] = 0
      =>  D = (y/Q - zloc) * lam*n / ||x||^2,  then clip so that
          (alpha + D) * y in [0, 1].
    ``beta`` (paper's step-size variant) replaces ||x||^2 when given.
    """
    denom = x_sq if beta is None else beta
    denom = jnp.maximum(denom, 1e-12)
    d = (y / Q - zloc) * lam * n / denom
    lo, hi = _hinge_bounds(y)
    return jnp.clip(alpha + d, lo, hi) - alpha


# ----------------------------------------------------------------------------
# squared: f(z) = (z - y)^2 ; phi*(-a) = -a y + a^2 / 4  (unconstrained)
# ----------------------------------------------------------------------------

def _sq_value(z, y):
    return (z - y) ** 2


def _sq_grad(z, y):
    return 2.0 * (z - y)


def _sq_conj(a, y):
    return -a * y + a * a / 4.0


def _sq_bounds(y):
    big = jnp.full_like(y, jnp.inf)
    return -big, big


def _sq_sdca_delta(alpha, x_sq, zloc, y, lam, n, Q, beta=None):
    # d/dD [ (1/Q)((alpha+D) y - (alpha+D)^2/4) - zloc*D - D^2 ||x||^2/(2 lam n) ]
    #  = y/Q - (alpha+D)/(2Q) - zloc - D ||x||^2/(lam n) = 0
    denom_x = x_sq if beta is None else beta
    num = y / Q - alpha / (2.0 * Q) - zloc
    den = 1.0 / (2.0 * Q) + denom_x / (lam * n)
    return num / jnp.maximum(den, 1e-12)


# ----------------------------------------------------------------------------
# logistic: f(z) = log(1 + exp(-y z))
# phi*(-a): with t = a*y in (0,1):  t log t + (1-t) log(1-t)
# ----------------------------------------------------------------------------

def _log_value(z, y):
    return jnp.logaddexp(0.0, -y * z)


def _log_grad(z, y):
    return -y * jax.nn.sigmoid(-y * z)


def _xlogx(t):
    return jnp.where(t > 0, t * jnp.log(jnp.maximum(t, 1e-30)), 0.0)


def _log_conj(a, y):
    t = jnp.clip(a * y, 0.0, 1.0)
    return _xlogx(t) + _xlogx(1.0 - t)


def _log_bounds(y):
    lo = jnp.where(y > 0, 0.0, -1.0)
    hi = jnp.where(y > 0, 1.0, 0.0)
    return lo, hi


def _log_sdca_delta(alpha, x_sq, zloc, y, lam, n, Q, beta=None, newton_iters=8):
    """Newton on g(D) = (1/Q)(-phi*'(-(a+D))) - zloc - D q  with
    q = ||x||^2/(lam n).  Parametrize t = (alpha+D) y in (0,1):
      -d/dD phi*(-(alpha+D)) = y * ( -log(t/(1-t)) )' ... worked out below.
    phi*(-(a)) = t log t + (1-t)log(1-t), t = a y  =>
      d/da phi*(-(a)) = y (log t - log(1-t))
    local obj'(D) = -(1/Q) y log(t/(1-t)) - zloc - D q = 0, t=(a+D)y
    """
    denom_x = x_sq if beta is None else beta
    q = jnp.maximum(denom_x, 1e-12) / (lam * n)
    eps = 1e-6
    # padded rows carry y = 0; dividing by y would poison the masked-out
    # delta with NaN (0 * inf), so divide by a harmless stand-in there
    safe_y = jnp.where(y == 0, 1.0, y)

    def body(D, _):
        t = jnp.clip((alpha + D) * y, eps, 1.0 - eps)
        g = -(1.0 / Q) * y * (jnp.log(t) - jnp.log1p(-t)) - zloc - D * q
        # g'(D) = -(1/Q) * y^2 * (1/t + 1/(1-t)) - q   (y^2 == 1)
        gp = -(1.0 / Q) * (1.0 / t + 1.0 / (1.0 - t)) - q
        D_new = D - g / gp
        # project back so that (alpha + D) y stays inside (0, 1)
        t_new = jnp.clip((alpha + D_new) * y, eps, 1.0 - eps)
        D_new = t_new / safe_y - alpha
        return D_new, None

    D0 = jnp.zeros_like(alpha)
    # start strictly inside the box
    t0 = jnp.clip((alpha + D0) * y, eps, 1.0 - eps)
    D0 = t0 / safe_y - alpha
    D, _ = jax.lax.scan(body, D0, None, length=newton_iters)
    return D


hinge = Loss("hinge", _hinge_value, _hinge_grad, _hinge_conj, _hinge_bounds,
             _hinge_sdca_delta)
squared = Loss("squared", _sq_value, _sq_grad, _sq_conj, _sq_bounds,
               _sq_sdca_delta)
logistic = Loss("logistic", _log_value, _log_grad, _log_conj, _log_bounds,
                _log_sdca_delta)

LOSSES = {fn.name: fn for fn in (hinge, squared, logistic)}


def get_loss(name: str) -> Loss:
    return LOSSES[name]
