"""Cell-local solvers shared by the simulated grid and shard_map executions.

Each function sees exactly the data one worker of the P x Q grid owns:
``x`` of shape (n_p, m_q), labels/mask (n_p,), and the relevant slices of
the primal/dual vectors.  They are pure and jit/vmap/shard_map friendly.

Both take a ``backend`` knob ("ref" | "pallas"):

  * ``backend="ref"`` runs the pure-jnp lax.scan implementation below;
  * ``backend="pallas"`` dispatches to the Pallas TPU kernels in
    ``repro.kernels.sdca`` / ``repro.kernels.svrg`` (interpret mode on
    CPU, real kernels on TPU).  The coordinate order is drawn from the
    same PRNG key either way, so the two backends agree to float
    tolerance.  The kernels support hinge and squared losses; logistic
    raises (use backend="ref").

The knob is threaded end-to-end from the solver API
(``repro.core.solver``) through both engines, so the kernels run inside
the vmap grid and inside each shard_map cell alike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .losses import Loss

PALLAS_LOSSES = ("hinge", "squared")


def _check_pallas_loss(loss: Loss):
    if loss.name not in PALLAS_LOSSES:
        raise NotImplementedError(
            f"local_backend='pallas' supports losses {PALLAS_LOSSES}, not "
            f"{loss.name!r}; use local_backend='ref' for {loss.name}")


def _interpret() -> bool:
    from repro.kernels import default_interpret
    return default_interpret()


# ----------------------------------------------------------------------------
# Local SDCA (Algorithm 2): one epoch of randomized dual coordinate ascent on
# the local block, with the conjugate term scaled by 1/Q.
# ----------------------------------------------------------------------------

def local_sdca(loss: Loss, x, y, mask, alpha0, w0, *, lam, n, Q,
               steps, key, step_mode: str = "exact", beta=None,
               backend: str = "ref"):
    """Run ``steps`` SDCA coordinate updates on the local block.

    Args:
      x: (n_p, m_q) local data block.
      y, mask: (n_p,) labels and row-validity mask.
      alpha0: (n_p,) local view of the shared dual block alpha_[p, .].
      w0: (m_q,) local view of the shared primal block w_[., q].
      lam, n: global regularization and *global* observation count.
      Q: number of feature partitions (scales the conjugate by 1/Q).
      steps: number of coordinate updates (H in Algorithm 2).
      key: PRNG key for the coordinate order (shared across q so every
        feature block visits the same observation sequence, matching the
        paper's per-partition sampling).
      step_mode: "exact" uses ||x_i||^2; "beta" uses the paper's step-size
        parameter ``beta`` (they use beta = lam / t).
      backend: "ref" (pure jnp) | "pallas" (TPU kernel; interpret on CPU).

    Returns:
      delta_alpha: (n_p,) accumulated dual change of this cell.
    """
    n_p = x.shape[0]
    idx = jax.random.randint(key, (steps,), 0, n_p)
    use_beta = step_mode == "beta"

    if backend == "pallas":
        _check_pallas_loss(loss)
        from repro.kernels.sdca import sdca_epoch_pallas
        dalpha, _ = sdca_epoch_pallas(
            x, y, mask, alpha0, w0, idx, lam=lam, n=n, Q=Q, loss=loss.name,
            beta=(beta if use_beta else None), interpret=_interpret())
        return dalpha
    if backend != "ref":
        raise ValueError(f"unknown local backend {backend!r}")

    x_sq = jnp.sum(x * x, axis=1)  # (n_p,)

    def body(carry, i):
        w, dalpha = carry
        xi = x[i]
        zloc = xi @ w                     # local contribution to x_i . w
        a_i = alpha0[i] + dalpha[i]
        d = loss.sdca_delta(a_i, x_sq[i], zloc, y[i], lam, n, Q,
                            beta=(beta if use_beta else None))
        d = d * mask[i]                   # padded rows never move
        w = w + (d / (lam * n)) * xi
        dalpha = dalpha.at[i].add(d)
        return (w, dalpha), None

    (w_fin, dalpha), _ = jax.lax.scan(body, (w0, jnp.zeros_like(alpha0)), idx)
    del w_fin  # D3CA recomputes w from the primal-dual map (step 9)
    return dalpha


# ----------------------------------------------------------------------------
# Local RADiSA inner loop (Algorithm 3 steps 6-10): L SVRG steps on the
# assigned sub-block of coordinates.
# ----------------------------------------------------------------------------

def local_svrg(loss: Loss, x_sub, y, mask, z_anchor, w_anchor_sub, mu_sub,
               *, lam, L, eta, key, lo=None, backend: str = "ref"):
    """L SVRG steps on one feature sub-block.

    The stochastic partial gradient uses the anchor inner products
    ``z_anchor[j] = x_j^T w_tilde`` (computed once, doubly distributed) and
    corrects locally:  x_j^T w  ~=  z_anchor[j] + x_j[sub]^T (w - w_tilde[sub]).

    Args:
      x_sub: (n_p, m_sub) columns of the assigned sub-block -- OR, when
        ``lo`` is given, the full (n_p, m_q) block from which each sampled
        ROW's ``[lo:lo+m_sub]`` columns are sliced inside the loop.
        Slicing the block before the loop reads pathologically: XLA fuses
        the loop-invariant column slice into the per-step row gather, so
        every inner step re-reads the whole sub-block (104.9 MB/step
        measured; EXPERIMENTS.md §Perf cell 3).  Row-first gather then a
        column slice of ONE row keeps the step at ~KB.
      z_anchor: (n_p,) full inner products at the anchor point w_tilde.
      w_anchor_sub: (m_sub,) anchor coordinates of the sub-block.
      mu_sub: (m_sub,) coordinates of the full anchor gradient of F
        (includes the 2*lam*w_tilde term).
      eta: learning rate eta_t.
      backend: "ref" (pure jnp) | "pallas" (TPU kernel; interpret on CPU).

    Returns:
      w_sub: (m_sub,) updated sub-block.
    """
    n_p = x_sub.shape[0]
    m_sub = w_anchor_sub.shape[0]
    idx = jax.random.randint(key, (L,), 0, n_p)

    if backend == "pallas":
        _check_pallas_loss(loss)
        from repro.kernels.svrg import svrg_inner_pallas
        if lo is None:
            x_k = x_sub
        else:
            # The kernel gathers one (1, m_sub) row per step straight out
            # of this slice via scalar-prefetched DMA, so the fused
            # column-slice pathology of the jnp path does not apply: the
            # slice is materialized once per outer iteration, not once
            # per inner step.
            x_k = jax.lax.dynamic_slice(x_sub, (0, lo), (n_p, m_sub))
        return svrg_inner_pallas(x_k, y, mask, z_anchor, w_anchor_sub,
                                 mu_sub, idx, lam=lam, eta=eta,
                                 loss=loss.name, interpret=_interpret())
    if backend != "ref":
        raise ValueError(f"unknown local backend {backend!r}")

    def body(w, j):
        if lo is None:
            xj = x_sub[j]
        else:
            xj = jax.lax.dynamic_slice(x_sub[j], (lo,), (m_sub,))
        corr = xj @ (w - w_anchor_sub)
        z = z_anchor[j] + corr
        g_new = loss.grad(z, y[j])
        g_old = loss.grad(z_anchor[j], y[j])
        # SVRG direction on the sub-block; the regularizer is corrected from
        # the anchor to the current point exactly (it is quadratic).
        g = (g_new - g_old) * xj * mask[j] + mu_sub \
            + lam * (w - w_anchor_sub)
        return w - eta * g, None

    w_fin, _ = jax.lax.scan(body, w_anchor_sub, idx)
    return w_fin


# ----------------------------------------------------------------------------
# Sparse-cell variants: the block is a padded-ELL pair (cols, vals) of shape
# (n_p, k) with block-local column ids; k ~ max row nnz, so a cell's memory
# and per-step work scale with the nonzero count instead of m_q.  Padding
# slots carry (col=0, val=0): gathers read w[0] harmlessly and scatters add
# zero, so they are inert.  Same PRNG draw as the dense variants, so sparse
# and dense runs agree to float tolerance on identical data.
# ----------------------------------------------------------------------------

def local_sdca_sparse(loss: Loss, cols, vals, y, mask, alpha0, w0, *, lam, n,
                      Q, steps, key, step_mode: str = "exact", beta=None,
                      backend: str = "ref"):
    """Sparse-cell version of :func:`local_sdca`.

    Args:
      cols, vals: (n_p, k) padded-ELL local block (block-local columns).
      w0: (m_q,) dense local view of the shared primal block.
      Everything else as in :func:`local_sdca`.

    Returns:
      delta_alpha: (n_p,) accumulated dual change of this cell.
    """
    n_p = cols.shape[0]
    idx = jax.random.randint(key, (steps,), 0, n_p)
    use_beta = step_mode == "beta"

    if backend == "pallas":
        _check_pallas_loss(loss)
        from repro.kernels.sdca import sdca_epoch_sparse_pallas
        dalpha, _ = sdca_epoch_sparse_pallas(
            cols, vals, y, mask, alpha0, w0, idx, lam=lam, n=n, Q=Q,
            loss=loss.name, beta=(beta if use_beta else None),
            interpret=_interpret())
        return dalpha
    if backend != "ref":
        raise ValueError(f"unknown local backend {backend!r}")

    x_sq = jnp.sum(vals * vals, axis=1)  # (n_p,)

    def body(carry, i):
        w, dalpha = carry
        ci, vi = cols[i], vals[i]
        zloc = jnp.sum(vi * w[ci])        # local contribution to x_i . w
        a_i = alpha0[i] + dalpha[i]
        d = loss.sdca_delta(a_i, x_sq[i], zloc, y[i], lam, n, Q,
                            beta=(beta if use_beta else None))
        d = d * mask[i]                   # padded rows never move
        w = w.at[ci].add((d / (lam * n)) * vi)
        dalpha = dalpha.at[i].add(d)
        return (w, dalpha), None

    (w_fin, dalpha), _ = jax.lax.scan(body, (w0, jnp.zeros_like(alpha0)), idx)
    del w_fin  # D3CA recomputes w from the primal-dual map (step 9)
    return dalpha


def local_svrg_sparse(loss: Loss, cols, vals, y, mask, z_anchor,
                      w_anchor_sub, mu_sub, *, lam, L, eta, key, lo=None,
                      backend: str = "ref"):
    """Sparse-cell version of :func:`local_svrg`.

    The cell always receives the FULL feature block as (n_p, k) ELL; the
    assigned sub-block window ``[lo, lo + m_sub)`` (``lo`` may be a
    traced scalar -- it follows the per-iteration permutation) is
    selected by masking the in-window entries of each sampled row.
    ``lo=None`` means the window is the whole block (RADiSA-avg).

    Returns:
      w_sub: (m_sub,) updated sub-block iterate.
    """
    n_p = cols.shape[0]
    m_sub = w_anchor_sub.shape[0]
    idx = jax.random.randint(key, (L,), 0, n_p)
    lo = 0 if lo is None else lo

    if backend == "pallas":
        _check_pallas_loss(loss)
        from repro.kernels.svrg import svrg_inner_sparse_pallas
        return svrg_inner_sparse_pallas(
            cols, vals, y, mask, z_anchor, w_anchor_sub, mu_sub, idx,
            lam=lam, eta=eta, lo=lo, loss=loss.name, interpret=_interpret())
    if backend != "ref":
        raise ValueError(f"unknown local backend {backend!r}")

    def body(w, j):
        ci, vi = cols[j], vals[j]
        rel = ci - lo
        sel = ((rel >= 0) & (rel < m_sub)).astype(vi.dtype)
        relc = jnp.clip(rel, 0, m_sub - 1)
        diff = w - w_anchor_sub
        corr = jnp.sum(vi * sel * diff[relc])   # x_j[window] @ (w - wa)
        z = z_anchor[j] + corr
        gdiff = (loss.grad(z, y[j]) - loss.grad(z_anchor[j], y[j])) * mask[j]
        g_sparse = jnp.zeros((m_sub,), vi.dtype).at[relc].add(
            gdiff * vi * sel)
        g = g_sparse + mu_sub + lam * diff
        return w - eta * g, None

    w_fin, _ = jax.lax.scan(body, w_anchor_sub, idx)
    return w_fin
