"""Small shared helpers for the shard_map engines."""
from __future__ import annotations

import jax


def pvary(x, axes):
    """Mark ``x`` as varying over the given manual mesh axes.

    shard_map in recent JAX tracks which mesh axes each value varies over;
    inputs that are replicated along an axis must be explicitly promoted
    before being mixed with values that vary along it inside lax control
    flow.  Uses ``jax.lax.pcast`` (new name) with ``pvary`` fallback.
    """
    axes = tuple(axes)
    if not axes:
        return x
    try:
        return jax.lax.pcast(x, axes, to="varying")
    except TypeError:
        return jax.lax.pvary(x, axes)


def as_axes(axis) -> tuple:
    """Normalize an axis-name-or-tuple to a tuple of axis names."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axes_size(mesh, axis) -> int:
    """Product of mesh sizes over one axis name or a tuple of names."""
    s = 1
    for a in as_axes(axis):
        s *= mesh.shape[a]
    return s


def axes_index(axis):
    """Collapsed linear index over one or several manual mesh axes
    (row-major in the given order), usable inside shard_map."""
    axes = as_axes(axis)
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx
