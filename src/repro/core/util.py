"""Small shared helpers for the shard_map engines."""
from __future__ import annotations

import jax


def pvary(x, axes):
    """Mark ``x`` as varying over the given manual mesh axes.

    shard_map in recent JAX tracks which mesh axes each value varies over;
    inputs that are replicated along an axis must be explicitly promoted
    before being mixed with values that vary along it inside lax control
    flow.  Uses ``jax.lax.pcast`` (new name) with ``pvary`` fallback; on
    older JAX (no varying-manual-axes tracking, shard_map runs with
    replication checking off) it is the identity.
    """
    axes = tuple(axes)
    if not axes:
        return x
    if hasattr(jax.lax, "pcast"):
        try:
            return jax.lax.pcast(x, axes, to="varying")
        except TypeError:
            pass
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    return x


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with vma checking off; falls back to
    ``jax.experimental.shard_map`` (check_rep=False) on jax <= 0.4.x."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def as_axes(axis) -> tuple:
    """Normalize an axis-name-or-tuple to a tuple of axis names."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axes_size(mesh, axis) -> int:
    """Product of mesh sizes over one axis name or a tuple of names."""
    s = 1
    for a in as_axes(axis):
        s *= mesh.shape[a]
    return s


def axes_index(axis):
    """Collapsed linear index over one or several manual mesh axes
    (row-major in the given order), usable inside shard_map."""
    axes = as_axes(axis)
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx
