"""D3CA -- Doubly Distributed Dual Coordinate Ascent (Algorithm 1).

The cell-local solver is ``local.local_sdca`` (pure jnp or the Pallas
SDCA kernel, selected by ``local_backend``).  Since Engine API v2 the
algorithm contributes ONE :class:`~repro.core.engines.CellProgram` --
the per-cell step math plus a CommSchedule declaring its two
reductions::

    CommSchedule().pmean("dalpha", axis="model")   # step 6 dual average
                  .psum("w_contrib", axis="data")  # step 9 primal-dual map

The generic executors in ``repro.core.engines`` run that single program
under every engine:

  * ``d3ca_simulated_program``  -- named-vmap grid on one device;
  * ``d3ca_shard_map_program``  -- a ``shard_map`` step over a
    (data=P, model=Q) mesh; ``staleness=tau`` turns the same program
    into the bounded-staleness async engine (tau = 0 is bit-identical
    to the sync path).

``d3ca_simulated`` / ``d3ca_distributed`` are thin compatibility
wrappers; the outer loop lives once in ``engines.drive`` /
``solver.Solver.solve``.  The engines are tested to agree to float
tolerance (tests/test_distributed.py, tests/test_solver.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .comm import CommSchedule
from .engines import (CellProgram, EngineProgram, SparseShardMapData,
                      cached_build, drive_with_callback, grid_bind_state,
                      grid_program, mesh_local_step, mesh_program,
                      mesh_step_fn, overlap_donates)
from .local import local_sdca, local_sdca_sparse
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_scatter_add)


@dataclasses.dataclass(frozen=True)
class D3CAConfig:
    lam: float = 1e-2
    local_steps: Optional[int] = None   # H; default = one local epoch (n_p)
    step_mode: str = "exact"            # "exact" | "beta" (paper's lam/t)
    outer_iters: int = 20
    seed: int = 0


def d3ca_schedule() -> CommSchedule:
    """D3CA's two reduction points, as named in the paper."""
    return (CommSchedule()
            .pmean("dalpha", axis="model")
            .psum("w_contrib", axis="data"))


def d3ca_cell_program(loss: Loss, cfg: D3CAConfig, *, n: int, n_p: int,
                      m_q: Optional[int] = None, sparse: bool = False,
                      local_backend: str = "ref",
                      gated: bool = False,
                      per_problem: bool = False) -> CellProgram:
    """The ONE D3CA program every engine executes.

    Per-cell data: ``(key0, x_b[, vals_b], y_b, mask_b[, gate_b])`` -- an
    (n_p, m_q) dense block or an (n_p, k) padded-ELL cols/vals pair.
    Per-cell state: ``(alpha_b (n_p,), w_b (m_q,))``.

    ``gated=True`` appends a per-row activity gate ``gate_b (n_p,)`` to
    the data tuple: the local SDCA epoch masks its coordinate updates by
    ``mask_b * gate_b``, so rows gated off never move their dual, while
    the step-9 primal-dual map still sums EVERY row's alpha (the model
    stays exact for the whole dataset).  A gate of all ones is
    bit-identical to the ungated program.  This is the incremental
    online-update path: warm-started passes touch only the cells whose
    row partition received new observations.

    ``per_problem=True`` appends runtime scalars ``(lam_v, n_v)`` to the
    data tuple and uses them in place of ``cfg.lam`` / ``n`` everywhere;
    this is the fleet path, where the tenant vmap feeds each tenant its
    own regularizer and sample count through the same traced program.
    """
    lam = cfg.lam
    steps = cfg.local_steps or n_p
    if sparse and m_q is None:
        raise ValueError("sparse D3CA cells need m_q for the scatter-add")

    def cell(comm, t, data, state):
        if per_problem:
            *data, lam_t, n_t = data
        else:
            lam_t, n_t = lam, n
        if sparse:
            key0, cols_b, vals_b, y_b, mask_b, *rest = data
            x_parts = (cols_b, vals_b)
            local = local_sdca_sparse
        else:
            key0, x_b, y_b, mask_b, *rest = data
            x_parts = (x_b,)
            local = local_sdca
        step_mask = mask_b * rest[0] if gated else mask_b
        a_b, w_b = state
        Pn = comm.axis_size("data")
        Qn = comm.axis_size("model")
        beta = lam_t / t
        key_t = jax.random.fold_in(key0, t)
        p = comm.axis_index("data")
        key_p = jax.random.fold_in(key_t, p)   # coordinate order per p
        dalpha = local(loss, *x_parts, y_b, step_mask, a_b, w_b,
                       lam=lam_t, n=n_t, Q=Qn, steps=steps, key=key_p,
                       step_mode=cfg.step_mode, beta=beta,
                       backend=local_backend)
        # step 6: alpha_[p,.] += (1/P) mean_q dalpha[p, q]
        a_new = a_b + comm("dalpha", dalpha) / Pn
        # step 9: w_[., q] = (1/(lam n)) sum_p alpha_[p,q]^T x_[p,q]
        am = a_new * mask_b
        contrib = (ell_scatter_add(m_q, cols_b, vals_b, am) if sparse
                   else am @ x_b)
        w_new = comm("w_contrib", contrib) / (lam_t * n_t)
        return a_new, w_new

    x_specs = ((("data", "model"), ("data", "model")) if sparse
               else (("data", "model"),))
    gate_specs = ((("data",),) if gated else ())
    pp_specs = (((), ()) if per_problem else ())
    data_specs = ((),) + x_specs + (("data",), ("data",)) + gate_specs \
        + pp_specs
    state_specs = (("data",), ("model",))
    return CellProgram(d3ca_schedule(), cell, data_specs, state_specs)


# ----------------------------------------------------------------------------
# simulated grid engine
# ----------------------------------------------------------------------------

def d3ca_simulated_program(loss: Loss, data: DoublyPartitioned,
                           cfg: D3CAConfig, *, local_backend: str = "ref",
                           w0=None, alpha0=None,
                           compression=None, topology=None,
                           row_gate=None, cache=None) -> EngineProgram:
    """Named-vmap grid engine.  State: (alpha (P, n_p), w_blocks (Q, m_q)).

    ``data`` may be a dense :class:`DoublyPartitioned` or a sparse
    :class:`SparseDoublyPartitioned` (padded-ELL cells); the cell
    program is the same one the mesh engines run.  ``compression`` (a
    CompressionPolicy) routes both collectives through their codecs and
    adds the error-feedback residuals to the engine state.
    ``row_gate`` ((n,) of 0/1) builds the gated incremental program:
    dual updates are restricted to gated-on rows (see
    :func:`d3ca_cell_program`)."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    cellprog = d3ca_cell_program(loss, cfg, n=data.n, n_p=data.n_p,
                                 m_q=data.m_q, sparse=sparse,
                                 local_backend=local_backend,
                                 gated=row_gate is not None)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (data.cols, data.vals) if sparse else (data.x_blocks,)
    gate_parts = (() if row_gate is None
                  else (data.alpha_to_blocks(jnp.asarray(row_gate)),))
    gdata = (key0, *x_parts, data.y_blocks, data.mask, *gate_parts)
    step = cached_build(cache, "step",
                        lambda: grid_program(cellprog, Pn, Qn,
                                             compression=compression,
                                             topology=topology))

    alpha_init = (jnp.zeros((Pn, data.n_p)) if alpha0 is None
                  else data.alpha_to_blocks(jnp.asarray(alpha0)))
    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    state0 = (alpha_init, w_init)
    full0, unwrap, acct = grid_bind_state(cellprog, gdata, state0,
                                          Pn=Pn, Qn=Qn,
                                          compression=compression,
                                          topology=topology)
    local = cached_build(cache, "local",
                         lambda: grid_program(cellprog, Pn, Qn,
                                              comm_local=True))
    wrapped = full0 is not state0
    return EngineProgram(
        state=full0,
        step=lambda t, s: step(t, gdata, s),
        w_of=lambda s: data.w_from_blocks(unwrap(s)[1]),
        alpha_of=lambda s: data.alpha_from_blocks(unwrap(s)[0] * data.mask),
        comm_bytes=acct,
        local_step=lambda t, s: local(t, gdata, unwrap(s)),
        ef_of=(lambda s: s[1]) if wrapped else None)


def d3ca_simulated(loss_name: str, data: DoublyPartitioned, cfg: D3CAConfig,
                   callback=None, local_backend: str = "ref"):
    """Run D3CA on the block grid with vmap-over-cells. Returns (w, alpha)."""
    prog = d3ca_simulated_program(get_loss(loss_name), data, cfg,
                                  local_backend=local_backend)
    state = drive_with_callback(prog, cfg.outer_iters, callback,
                                pass_alpha=True)
    return prog.w_of(state), prog.alpha_of(state)


# ----------------------------------------------------------------------------
# mesh engines (shard_map sync + bounded-staleness async)
# ----------------------------------------------------------------------------

def make_d3ca_step(loss: Loss, mesh, cfg: D3CAConfig, *, n: int, n_p: int,
                   data_axis: str = "data", model_axis: str = "model",
                   local_backend: str = "ref"):
    """Build the jitted distributed D3CA outer step (sync reductions).

    Array layouts (global shapes; sharding in parens):
      x:      (n, m)    (data, model)   -- block x_[p,q] per device
      y,mask: (n,)      (data,)
      alpha:  (n,)      (data,)         -- replicated over model
      w:      (m,)      (model,)        -- replicated over data
    """
    cellprog = d3ca_cell_program(loss, cfg, n=n, n_p=n_p,
                                 local_backend=local_backend)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(t, key0, x, y, mask, alpha, w):
        (a_new, w_new), _ = run(t, (key0, x, y, mask), (alpha, w), {})
        return a_new, w_new

    return jax.jit(step, static_argnums=())


def make_d3ca_step_sparse(loss: Loss, mesh, cfg: D3CAConfig, *, n: int,
                          n_p: int, m_q: int, data_axis: str = "data",
                          model_axis: str = "model",
                          local_backend: str = "ref"):
    """Sparse-cell variant of :func:`make_d3ca_step`.

    The data block per device is the padded-ELL pair cols/vals
    (n_p, k) with block-local column ids; the primal-dual map of step 9
    becomes a scatter-add into the local w block before the psum.
    """
    cellprog = d3ca_cell_program(loss, cfg, n=n, n_p=n_p, m_q=m_q,
                                 sparse=True, local_backend=local_backend)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(t, key0, cols, vals, y, mask, alpha, w):
        (a_new, w_new), _ = run(t, (key0, cols, vals, y, mask),
                                (alpha, w), {})
        return a_new, w_new

    return jax.jit(step, static_argnums=())


def d3ca_shard_map_program(loss: Loss, sdata, cfg: D3CAConfig,
                           *, local_backend: str = "ref",
                           w0=None, alpha0=None, staleness: int = 0,
                           compression=None, overlap: bool = False,
                           topology=None, row_gate=None,
                           cache=None) -> EngineProgram:
    """Mesh engine.  State: ((alpha (n_pad,), w (m_pad,)), comm_state),
    all sharded (comm_state carries staleness rings and/or EF
    residuals).  ``sdata`` is a :class:`ShardMapData` or
    :class:`SparseShardMapData`; ``staleness=tau > 0`` selects the
    bounded-staleness async policy (tau = 0 is the sync engine);
    ``compression`` routes both collectives through their codecs;
    ``overlap=True`` dispatches reductions into donated ring slots and
    awaits them tau steps later (the overlap engine); ``topology``
    enables the hierarchical two-level reduction (pod-split mesh);
    ``row_gate`` ((n,) of 0/1) builds the gated incremental program
    (see :func:`d3ca_cell_program`)."""
    sparse = isinstance(sdata, SparseShardMapData)
    cellprog = d3ca_cell_program(
        loss, cfg, n=sdata.n, n_p=sdata.n_p,
        m_q=sdata.m_q if sparse else None, sparse=sparse,
        local_backend=local_backend, gated=row_gate is not None)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (sdata.cols, sdata.vals) if sparse else (sdata.x,)
    gate_parts = (() if row_gate is None
                  else (sdata.pad_alpha(jnp.asarray(row_gate)),))
    mdata = (key0, *x_parts, sdata.y, sdata.mask, *gate_parts)
    alpha_init = (sdata.zeros_data() if alpha0 is None
                  else sdata.pad_alpha(alpha0))
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    step, comm0, acct = cached_build(
        cache, "step",
        lambda: mesh_program(
            cellprog, sdata.mesh, mdata, (alpha_init, w_init),
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            staleness=staleness, compression=compression,
            overlap=overlap, topology=topology))
    local = cached_build(
        cache, "local",
        lambda: mesh_local_step(cellprog, sdata.mesh,
                                data_axis=sdata.data_axis,
                                model_axis=sdata.model_axis))
    is_overlap = bool(overlap) and staleness > 0
    return EngineProgram(
        state=((alpha_init, w_init), comm0),
        step=lambda t, s: step(t, mdata, s),
        w_of=lambda s: s[0][1][: sdata.m],
        alpha_of=lambda s: s[0][0][: sdata.n],
        comm_bytes=acct,
        local_step=lambda t, s: local(t, mdata, s[0]),
        ef_of=(lambda s: s[1]["ef"]) if "ef" in comm0 else None,
        staleness=staleness, overlap=is_overlap,
        sync_of=(lambda s: s[0]) if is_overlap else None,
        donated=is_overlap and overlap_donates())


def d3ca_distributed(loss_name: str, mesh, x, y, mask, cfg: D3CAConfig,
                     callback=None, local_backend: str = "ref"):
    """Convenience driver for the shard_map engine (single-controller).

    ``x``/``y``/``mask`` must already be padded so the mesh divides both
    axes (the unified ``Solver`` API does this automatically)."""
    loss = get_loss(loss_name)
    n, m = x.shape
    Pn = mesh.shape["data"]
    step = make_d3ca_step(loss, mesh, cfg, n=n, n_p=n // Pn,
                          local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    prog = EngineProgram(
        state=(jnp.zeros((n,)), jnp.zeros((m,))),
        step=lambda t, s: step(t, key0, x, y, mask, *s),
        w_of=lambda s: s[1],
        alpha_of=lambda s: s[0])
    state = drive_with_callback(prog, cfg.outer_iters, callback,
                                pass_alpha=True)
    return state[1], state[0]
