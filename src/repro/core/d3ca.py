"""D3CA -- Doubly Distributed Dual Coordinate Ascent (Algorithm 1).

The cell-local solver is ``local.local_sdca`` (pure jnp or the Pallas
SDCA kernel, selected by ``local_backend``).  The two engines are exposed
as :class:`~repro.core.engines.EngineProgram` builders consumed by the
unified solver framework (``repro.core.solver``):

  * ``d3ca_simulated_program``  -- the P x Q grid as leading array axes,
    cells under ``vmap``; one device.
  * ``d3ca_shard_map_program``  -- a ``shard_map`` step over a
    (data=P, model=Q) mesh: each device owns one (n_p, m_q) block; the
    dual average of step 6 is a ``pmean`` over the "model" axis and the
    primal-dual map of step 9 is a ``psum`` over the "data" axis.  This
    is the production path and what the multi-pod dry-run lowers.

``d3ca_simulated`` / ``d3ca_distributed`` are thin compatibility wrappers
over the programs; the outer loop lives once in ``engines.drive`` /
``solver.Solver.solve``.  The engines are tested to agree to float
tolerance (tests/test_distributed.py, tests/test_solver.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engines import (EngineProgram, SparseShardMapData,
                      drive_with_callback)
from .local import local_sdca, local_sdca_sparse
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_scatter_add)
from .util import pvary, shard_map


@dataclasses.dataclass(frozen=True)
class D3CAConfig:
    lam: float = 1e-2
    local_steps: Optional[int] = None   # H; default = one local epoch (n_p)
    step_mode: str = "exact"            # "exact" | "beta" (paper's lam/t)
    outer_iters: int = 20
    seed: int = 0


# ----------------------------------------------------------------------------
# simulated grid engine
# ----------------------------------------------------------------------------

def d3ca_simulated_program(loss: Loss, data: DoublyPartitioned,
                           cfg: D3CAConfig, *, local_backend: str = "ref",
                           w0=None, alpha0=None) -> EngineProgram:
    """vmap-over-cells engine.  State: (alpha (P, n_p), w_blocks (Q, m_q)).

    ``data`` may be a dense :class:`DoublyPartitioned` or a sparse
    :class:`SparseDoublyPartitioned` (padded-ELL cells); the update rules
    are identical, only the cell-local solver and the primal-dual map
    switch between dense einsum and gather/scatter forms."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    n, m_q, lam = data.n, data.m_q, cfg.lam
    steps = cfg.local_steps or data.n_p
    key0 = jax.random.PRNGKey(cfg.seed)

    if sparse:
        local = partial(local_sdca_sparse, loss, lam=lam, n=n, Q=Qn,
                        steps=steps, backend=local_backend)
    else:
        local = partial(local_sdca, loss, lam=lam, n=n, Q=Qn, steps=steps,
                        backend=local_backend)

    @jax.jit
    def outer(t, state):
        alpha, w_blocks = state
        beta = lam / t
        key_t = jax.random.fold_in(key0, t)

        def cell(p, q):
            key_p = jax.random.fold_in(key_t, p)  # coordinate order per p
            x_cell = ((data.cols[p, q], data.vals[p, q]) if sparse
                      else (data.x_blocks[p, q],))
            return local(*x_cell, data.y_blocks[p], data.mask[p],
                         alpha[p], w_blocks[q], key=key_p,
                         step_mode=cfg.step_mode, beta=beta)

        dalpha = jax.vmap(lambda p: jax.vmap(lambda q: cell(p, q))(
            jnp.arange(Qn)))(jnp.arange(Pn))     # (P, Q, n_p)

        # step 6: alpha_[p,.] += (1/(P*Q)) sum_q dalpha[p, q]
        alpha = alpha + dalpha.sum(axis=1) / (Pn * Qn)
        # step 9: w_[., q] = (1/(lam n)) sum_p alpha_[p,q]^T x_[p,q]
        am = alpha * data.mask
        if sparse:
            def col_block(cols_q, vals_q):   # (P, n_p, k) each
                def one(cols_pq, vals_pq, a_p):
                    return ell_scatter_add(m_q, cols_pq, vals_pq, a_p)
                return jax.vmap(one)(cols_q, vals_q, am).sum(axis=0)
            w_blocks = jax.vmap(col_block, in_axes=(1, 1))(
                data.cols, data.vals) / (lam * n)
        else:
            w_blocks = jnp.einsum("pn,pqnm->qm", am,
                                  data.x_blocks) / (lam * n)
        return alpha, w_blocks

    alpha_init = (jnp.zeros((Pn, data.n_p)) if alpha0 is None
                  else data.alpha_to_blocks(jnp.asarray(alpha0)))
    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    return EngineProgram(
        state=(alpha_init, w_init),
        step=outer,
        w_of=lambda s: data.w_from_blocks(s[1]),
        alpha_of=lambda s: data.alpha_from_blocks(s[0] * data.mask))


def d3ca_simulated(loss_name: str, data: DoublyPartitioned, cfg: D3CAConfig,
                   callback=None, local_backend: str = "ref"):
    """Run D3CA on the block grid with vmap-over-cells. Returns (w, alpha)."""
    prog = d3ca_simulated_program(get_loss(loss_name), data, cfg,
                                  local_backend=local_backend)
    state = drive_with_callback(prog, cfg.outer_iters, callback,
                                pass_alpha=True)
    return prog.w_of(state), prog.alpha_of(state)


# ----------------------------------------------------------------------------
# shard_map engine (production): one cell per device on a (data, model) mesh
# ----------------------------------------------------------------------------

def make_d3ca_step(loss: Loss, mesh, cfg: D3CAConfig, *, n: int, n_p: int,
                   data_axis: str = "data", model_axis: str = "model",
                   local_backend: str = "ref"):
    """Build the jitted distributed D3CA outer step.

    Array layouts (global shapes; sharding in parens):
      x:      (n, m)    (data, model)   -- block x_[p,q] per device
      y,mask: (n,)      (data,)
      alpha:  (n,)      (data,)         -- replicated over model
      w:      (m,)      (model,)        -- replicated over data
    """
    from .util import as_axes, axes_index, axes_size
    lam = cfg.lam
    daxes = as_axes(data_axis)
    Qn = axes_size(mesh, model_axis)
    Pn = axes_size(mesh, data_axis)
    steps = cfg.local_steps or n_p

    def step(t, key0, x, y, mask, alpha, w):
        beta = lam / t
        key_t = jax.random.fold_in(key0, t)

        def cell(x_b, y_b, mask_b, a_b, w_b):
            # promote partially-replicated operands to fully varying
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            a_b = pvary(a_b, (model_axis,))
            w_b = pvary(w_b, daxes)
            p = axes_index(data_axis)
            key_p = jax.random.fold_in(key_t, p)
            dalpha = local_sdca(loss, x_b, y_b, mask_b, a_b, w_b,
                                lam=lam, n=n, Q=Qn, steps=steps, key=key_p,
                                step_mode=cfg.step_mode, beta=beta,
                                backend=local_backend)
            # step 6: average the dual deltas of the Q feature blocks
            a_new = a_b + jax.lax.pmean(dalpha, model_axis) / Pn
            # step 9: primal-dual map, reduced over observation partitions
            w_new = jax.lax.psum((a_new * mask_b) @ x_b, data_axis) / (lam * n)
            return a_new, w_new

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                      P(data_axis), P(model_axis)),
            out_specs=(P(data_axis), P(model_axis)),
        )(x, y, mask, alpha, w)

    return jax.jit(step, static_argnums=())


def make_d3ca_step_sparse(loss: Loss, mesh, cfg: D3CAConfig, *, n: int,
                          n_p: int, m_q: int, data_axis: str = "data",
                          model_axis: str = "model",
                          local_backend: str = "ref"):
    """Sparse-cell variant of :func:`make_d3ca_step`.

    The data block per device is the padded-ELL pair cols/vals
    (n_p, k) with block-local column ids; the primal-dual map of step 9
    becomes a scatter-add into the local w block before the psum.
    """
    from .util import as_axes, axes_index, axes_size
    lam = cfg.lam
    daxes = as_axes(data_axis)
    Qn = axes_size(mesh, model_axis)
    Pn = axes_size(mesh, data_axis)
    steps = cfg.local_steps or n_p

    def step(t, key0, cols, vals, y, mask, alpha, w):
        beta = lam / t
        key_t = jax.random.fold_in(key0, t)

        def cell(cols_b, vals_b, y_b, mask_b, a_b, w_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            a_b = pvary(a_b, (model_axis,))
            w_b = pvary(w_b, daxes)
            p = axes_index(data_axis)
            key_p = jax.random.fold_in(key_t, p)
            dalpha = local_sdca_sparse(
                loss, cols_b, vals_b, y_b, mask_b, a_b, w_b,
                lam=lam, n=n, Q=Qn, steps=steps, key=key_p,
                step_mode=cfg.step_mode, beta=beta, backend=local_backend)
            # step 6: average the dual deltas of the Q feature blocks
            a_new = a_b + jax.lax.pmean(dalpha, model_axis) / Pn
            # step 9: primal-dual map -- scatter-add the cell's
            # contribution, then reduce over observation partitions
            contrib = ell_scatter_add(m_q, cols_b, vals_b, a_new * mask_b)
            w_new = jax.lax.psum(contrib, data_axis) / (lam * n)
            return a_new, w_new

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                      P(data_axis), P(data_axis), P(data_axis),
                      P(model_axis)),
            out_specs=(P(data_axis), P(model_axis)),
        )(cols, vals, y, mask, alpha, w)

    return jax.jit(step, static_argnums=())


def d3ca_shard_map_program(loss: Loss, sdata, cfg: D3CAConfig,
                           *, local_backend: str = "ref",
                           w0=None, alpha0=None) -> EngineProgram:
    """shard_map engine.  State: (alpha (n_pad,), w (m_pad,)) sharded.
    ``sdata`` is a :class:`ShardMapData` or :class:`SparseShardMapData`."""
    key0 = jax.random.PRNGKey(cfg.seed)
    if isinstance(sdata, SparseShardMapData):
        step = make_d3ca_step_sparse(
            loss, sdata.mesh, cfg, n=sdata.n, n_p=sdata.n_p, m_q=sdata.m_q,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            local_backend=local_backend)

        def run(t, s):
            return step(t, key0, sdata.cols, sdata.vals, sdata.y,
                        sdata.mask, *s)
    else:
        step = make_d3ca_step(loss, sdata.mesh, cfg, n=sdata.n,
                              n_p=sdata.n_p, data_axis=sdata.data_axis,
                              model_axis=sdata.model_axis,
                              local_backend=local_backend)

        def run(t, s):
            return step(t, key0, sdata.x, sdata.y, sdata.mask, *s)
    alpha_init = (sdata.zeros_data() if alpha0 is None
                  else sdata.pad_alpha(alpha0))
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    return EngineProgram(
        state=(alpha_init, w_init),
        step=run,
        w_of=lambda s: s[1][: sdata.m],
        alpha_of=lambda s: s[0][: sdata.n])


def d3ca_distributed(loss_name: str, mesh, x, y, mask, cfg: D3CAConfig,
                     callback=None, local_backend: str = "ref"):
    """Convenience driver for the shard_map engine (single-controller).

    ``x``/``y``/``mask`` must already be padded so the mesh divides both
    axes (the unified ``Solver`` API does this automatically)."""
    loss = get_loss(loss_name)
    n, m = x.shape
    Pn = mesh.shape["data"]
    step = make_d3ca_step(loss, mesh, cfg, n=n, n_p=n // Pn,
                          local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    prog = EngineProgram(
        state=(jnp.zeros((n,)), jnp.zeros((m,))),
        step=lambda t, s: step(t, key0, x, y, mask, *s),
        w_of=lambda s: s[1],
        alpha_of=lambda s: s[0])
    state = drive_with_callback(prog, cfg.outer_iters, callback,
                                pass_alpha=True)
    return state[1], state[0]
