"""RADiSA -- RAndom Distributed Stochastic Algorithm (Algorithm 3).

Primal SGD x CD hybrid with SVRG variance reduction in the doubly
distributed setting.  The cell-local inner loop is ``local.local_svrg``
(pure jnp or the Pallas SVRG kernel, selected by ``local_backend``).
Since Engine API v2 the algorithm is ONE
:class:`~repro.core.engines.CellProgram` whose CommSchedule names the
paper's communication pattern (per outer iteration)::

    CommSchedule().psum("z", axis="model")     # 1. anchor pass: row inner
                                               #    products need every
                                               #    feature block
                  .psum("grad", axis="data")   # 2. full gradient: column
                                               #    blocks need every
                                               #    observation partition
                  # 3. L local SVRG steps -- NO communication
                  .psum("dw", axis="data")     # 4. concatenate disjoint
                                               #    sub-block deltas
                  # (variant="avg" declares pmean("w_avg") instead of "dw")

``variant="avg"`` implements RADiSA-avg: sub-blocks fully overlap (every
cell updates the whole local feature block) and solutions are averaged.

RADiSA pre-splits each feature block into P sub-blocks, so P must divide
m_q.  The builders fail loudly instead of silently truncating feature
columns; the unified ``Solver`` API pads the feature dimension to a
multiple of P*Q up front for every engine, so the constraint never binds
there.  ``radisa_simulated`` repartitions with inert zero-column padding
when handed a non-dividing grid directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .comm import CommSchedule
from .engines import (CellProgram, EngineProgram, SparseShardMapData,
                      cached_build, drive_with_callback, grid_bind_state,
                      grid_program, mesh_local_step, mesh_program,
                      mesh_step_fn, overlap_donates)
from .local import local_svrg, local_svrg_sparse
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_gather, ell_scatter_add)


@dataclasses.dataclass(frozen=True)
class RADiSAConfig:
    lam: float = 1e-3
    L: Optional[int] = None          # batch size (inner steps); default n_p
    gamma: float = 1.0               # step size constant
    outer_iters: int = 20
    variant: str = "block"           # "block" | "avg"
    seed: int = 0

    def eta(self, t):
        # paper: eta_t = gamma / (1 + sqrt(t - 1))
        return self.gamma / (1.0 + jnp.sqrt(jnp.maximum(t - 1.0, 0.0)))


def radisa_schedule(variant: str = "block") -> CommSchedule:
    """RADiSA's reduction points; the recombine op depends on the
    variant (disjoint sub-block deltas vs full-block average)."""
    sched = (CommSchedule()
             .psum("z", axis="model")
             .psum("grad", axis="data"))
    if variant == "avg":
        return sched.pmean("w_avg", axis="data")
    return sched.psum("dw", axis="data")


def _check_subblocks(m_q: int, Pn: int, avg: bool):
    if not avg and m_q % Pn:
        raise ValueError(
            f"RADiSA pre-splits each feature block into P={Pn} sub-blocks, "
            f"but P does not divide m_q={m_q}; truncating would silently "
            f"drop the trailing {m_q % Pn} feature columns of every block. "
            "Pad the feature dimension to a multiple of P*Q first -- the "
            "unified Solver API does this via "
            "partition(..., m_multiple=P*Q) / prepare_shard_map(..., "
            "m_multiple=P*Q) -- or use variant='avg'.")


def radisa_cell_program(loss: Loss, cfg: RADiSAConfig, *, n: int, n_p: int,
                        m_q: int, sparse: bool = False,
                        local_backend: str = "ref",
                        per_problem: bool = False) -> CellProgram:
    """The ONE RADiSA program every engine executes.

    Per-cell data: ``(key0, x_b[, vals_b], y_b, mask_b)``; per-cell
    state: ``w_b (m_q,)``.  The sub-block window of the sparse cell is
    selected inside the local solver by masking entry columns (an ELL
    row cannot be column-sliced).  ``per_problem=True`` appends runtime
    ``(lam_v, n_v)`` scalars to the data tuple (the fleet path)."""
    lam = cfg.lam
    L = cfg.L or n_p
    avg = cfg.variant == "avg"

    def cell(comm, t, data, state):
        if per_problem:
            *data, lam_t, n_t = data
        else:
            lam_t, n_t = lam, n
        if sparse:
            key0, cols_b, vals_b, y_b, mask_b = data
            x_parts = (cols_b, vals_b)
            local = local_svrg_sparse
        else:
            key0, x_b, y_b, mask_b = data
            x_parts = (x_b,)
            local = local_svrg
        w_b = state
        Pn = comm.axis_size("data")
        Qn = comm.axis_size("model")
        m_sub = m_q if avg else m_q // Pn
        eta = cfg.eta(t)
        key_t = jax.random.fold_in(key0, t)
        # (1) anchor inner products, reduced across feature blocks
        z_local = (ell_gather(w_b, cols_b, vals_b) if sparse
                   else x_b @ w_b)
        z = comm("z", z_local)                               # (n_p,)
        # (2) full gradient of F at the anchor, reduced across rows
        gz = loss.grad(z, y_b) * mask_b
        gcol = (ell_scatter_add(m_q, cols_b, vals_b, gz) if sparse
                else gz @ x_b)
        mu = comm("grad", gcol) / n_t + lam_t * w_b          # (m_q,)
        # (3) sub-block assignment (shared permutation) + local SVRG
        perm = jax.random.permutation(jax.random.fold_in(key_t, 0), Pn)
        p = comm.axis_index("data")
        q = comm.axis_index("model")
        key_pq = jax.random.fold_in(jax.random.fold_in(key_t, 1),
                                    p * Qn + q)
        s = perm[p]                                   # assigned sub-block
        lo = s * m_sub
        if avg:
            lo_arg, w_anchor, mu_sub = None, w_b, mu
        else:
            # NOTE: the sub-block columns are sliced per sampled ROW
            # inside local_svrg (lo=...), never as a (n_p, m_sub)
            # block -- see local_svrg's docstring for why.
            lo_arg = lo
            w_anchor = jax.lax.dynamic_slice(w_b, (lo,), (m_sub,))
            mu_sub = jax.lax.dynamic_slice(mu, (lo,), (m_sub,))
        w_new = local(loss, *x_parts, y_b, mask_b, z, w_anchor, mu_sub,
                      lam=lam_t, L=L, eta=eta, key=key_pq, lo=lo_arg,
                      backend=local_backend)
        # (4) recombine
        if avg:
            # RADiSA-avg: average the P overlapping solutions per block
            return comm("w_avg", w_new)
        delta = jnp.zeros_like(w_b)
        delta = jax.lax.dynamic_update_slice(delta, w_new - w_anchor, (lo,))
        return w_b + comm("dw", delta)

    x_specs = ((("data", "model"), ("data", "model")) if sparse
               else (("data", "model"),))
    pp_specs = (((), ()) if per_problem else ())
    data_specs = ((),) + x_specs + (("data",), ("data",)) + pp_specs
    state_specs = ("model",)
    return CellProgram(radisa_schedule(cfg.variant), cell, data_specs,
                       state_specs)


# ----------------------------------------------------------------------------
# simulated grid engine
# ----------------------------------------------------------------------------

def radisa_simulated_program(loss: Loss, data: DoublyPartitioned,
                             cfg: RADiSAConfig, *,
                             local_backend: str = "ref",
                             w0=None, compression=None,
                             topology=None, cache=None) -> EngineProgram:
    """Named-vmap grid engine.  State: w_blocks (Q, m_q).

    Requires P | m_q (pre-pad with ``partition(..., m_multiple=P*Q)``).
    ``data`` may be dense (:class:`DoublyPartitioned`) or sparse
    (:class:`SparseDoublyPartitioned`, padded-ELL cells);
    ``compression`` routes the anchor/grad/recombine collectives
    through their policy codecs."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    _check_subblocks(data.m_q, Pn, cfg.variant == "avg")
    cellprog = radisa_cell_program(loss, cfg, n=data.n, n_p=data.n_p,
                                   m_q=data.m_q, sparse=sparse,
                                   local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (data.cols, data.vals) if sparse else (data.x_blocks,)
    gdata = (key0, *x_parts, data.y_blocks, data.mask)
    step = cached_build(cache, "step",
                        lambda: grid_program(cellprog, Pn, Qn,
                                             compression=compression,
                                             topology=topology))

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    full0, unwrap, acct = grid_bind_state(cellprog, gdata, w_init,
                                          Pn=Pn, Qn=Qn,
                                          compression=compression,
                                          topology=topology)
    local = cached_build(cache, "local",
                         lambda: grid_program(cellprog, Pn, Qn,
                                              comm_local=True))
    wrapped = full0 is not w_init
    return EngineProgram(
        state=full0,
        step=lambda t, s: step(t, gdata, s),
        w_of=lambda s: data.w_from_blocks(unwrap(s)),
        comm_bytes=acct,
        local_step=lambda t, s: local(t, gdata, unwrap(s)),
        ef_of=(lambda s: s[1]) if wrapped else None)


def radisa_simulated(loss_name: str, data: DoublyPartitioned,
                     cfg: RADiSAConfig, callback=None,
                     local_backend: str = "ref"):
    loss = get_loss(loss_name)
    Pn, Qn = data.P, data.Q
    if data.m_q % Pn and cfg.variant != "avg":
        # RADiSA pre-splits each feature block into P sub-blocks; repartition
        # with extra (inert, all-zero) column padding so that P | m_q.
        from .partition import partition as _partition
        X, y = data.dense()
        padded = _partition(X, y, Pn, Qn, m_multiple=Pn * Qn)
        true_m = data.m

        def unpad_cb(t, w):
            if callback is not None:
                callback(t, w[:true_m])

        w = radisa_simulated(loss_name, padded, cfg,
                             callback=unpad_cb if callback else None,
                             local_backend=local_backend)
        return w[:true_m]

    prog = radisa_simulated_program(loss, data, cfg,
                                    local_backend=local_backend)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ----------------------------------------------------------------------------
# mesh engines (shard_map sync + bounded-staleness async)
# ----------------------------------------------------------------------------

def make_radisa_step(loss: Loss, mesh, cfg: RADiSAConfig, *, n: int, n_p: int,
                     m_q: int, data_axis: str = "data",
                     model_axis: str = "model",
                     local_backend: str = "ref"):
    """Distributed RADiSA outer step (sync reductions).

    Layouts: x (n, m) sharded (data, model); y/mask (n,) (data,);
    w (m,) (model,) replicated over data.
    """
    from .util import axes_size
    Pn = axes_size(mesh, data_axis)
    _check_subblocks(m_q, Pn, cfg.variant == "avg")
    cellprog = radisa_cell_program(loss, cfg, n=n, n_p=n_p, m_q=m_q,
                                   local_backend=local_backend)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(t, key0, x, y, mask, w):
        w_new, _ = run(t, (key0, x, y, mask), w, {})
        return w_new

    return jax.jit(step)


def make_radisa_step_sparse(loss: Loss, mesh, cfg: RADiSAConfig, *, n: int,
                            n_p: int, m_q: int, data_axis: str = "data",
                            model_axis: str = "model",
                            local_backend: str = "ref"):
    """Sparse-cell variant of :func:`make_radisa_step`: the anchor pass
    becomes a gather-matvec (rows) and a scatter-add (columns)."""
    from .util import axes_size
    Pn = axes_size(mesh, data_axis)
    _check_subblocks(m_q, Pn, cfg.variant == "avg")
    cellprog = radisa_cell_program(loss, cfg, n=n, n_p=n_p, m_q=m_q,
                                   sparse=True, local_backend=local_backend)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(t, key0, cols, vals, y, mask, w):
        w_new, _ = run(t, (key0, cols, vals, y, mask), w, {})
        return w_new

    return jax.jit(step)


def radisa_shard_map_program(loss: Loss, sdata, cfg: RADiSAConfig, *,
                             local_backend: str = "ref",
                             w0=None, staleness: int = 0,
                             compression=None, overlap: bool = False,
                             topology=None, cache=None) -> EngineProgram:
    """Mesh engine.  State: (w (m_pad,) sharded over model, comm_state).
    ``sdata`` is a :class:`ShardMapData` or :class:`SparseShardMapData`;
    ``staleness=tau > 0`` selects the bounded-staleness async policy;
    ``compression`` routes the declared collectives through codecs;
    ``overlap``/``topology`` select the overlap engine's donated ring
    dispatch and the hierarchical pod-split reduction."""
    from .util import axes_size
    sparse = isinstance(sdata, SparseShardMapData)
    Pn = axes_size(sdata.mesh, sdata.data_axis)
    _check_subblocks(sdata.m_q, Pn, cfg.variant == "avg")
    cellprog = radisa_cell_program(
        loss, cfg, n=sdata.n, n_p=sdata.n_p, m_q=sdata.m_q, sparse=sparse,
        local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (sdata.cols, sdata.vals) if sparse else (sdata.x,)
    mdata = (key0, *x_parts, sdata.y, sdata.mask)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    step, comm0, acct = cached_build(
        cache, "step",
        lambda: mesh_program(
            cellprog, sdata.mesh, mdata, w_init,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            staleness=staleness, compression=compression,
            overlap=overlap, topology=topology))
    local = cached_build(
        cache, "local",
        lambda: mesh_local_step(cellprog, sdata.mesh,
                                data_axis=sdata.data_axis,
                                model_axis=sdata.model_axis))
    is_overlap = bool(overlap) and staleness > 0
    return EngineProgram(
        state=(w_init, comm0),
        step=lambda t, s: step(t, mdata, s),
        w_of=lambda s: s[0][: sdata.m],
        comm_bytes=acct,
        local_step=lambda t, s: local(t, mdata, s[0]),
        ef_of=(lambda s: s[1]["ef"]) if "ef" in comm0 else None,
        staleness=staleness, overlap=is_overlap,
        sync_of=(lambda s: s[0]) if is_overlap else None,
        donated=is_overlap and overlap_donates())


def radisa_distributed(loss_name: str, mesh, x, y, mask, cfg: RADiSAConfig,
                       callback=None, local_backend: str = "ref"):
    loss = get_loss(loss_name)
    n, m = x.shape
    Pn, Qn = mesh.shape["data"], mesh.shape["model"]
    step = make_radisa_step(loss, mesh, cfg, n=n, n_p=n // Pn, m_q=m // Qn,
                            local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    prog = EngineProgram(
        state=jnp.zeros((m,)),
        step=lambda t, w: step(t, key0, x, y, mask, w),
        w_of=lambda w: w)
    return drive_with_callback(prog, cfg.outer_iters, callback)
