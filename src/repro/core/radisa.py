"""RADiSA -- RAndom Distributed Stochastic Algorithm (Algorithm 3).

Primal SGD x CD hybrid with SVRG variance reduction in the doubly
distributed setting.  The cell-local inner loop is ``local.local_svrg``
(pure jnp or the Pallas SVRG kernel, selected by ``local_backend``); the
engines mirror ``d3ca.py`` and are exposed as ``EngineProgram`` builders
for the unified solver framework.

Communication pattern (per outer iteration):
  1. anchor pass: z = X w_tilde        -> psum over "model" (row inner
     products need every feature block)
  2. full gradient mu_tilde            -> psum over "data" (column blocks
     need every observation partition)
  3. L local SVRG steps on the assigned sub-block -- NO communication
  4. concatenate sub-blocks            -> psum of disjoint deltas over "data"

``variant="avg"`` implements RADiSA-avg: sub-blocks fully overlap (every
cell updates the whole local feature block) and solutions are averaged.

RADiSA pre-splits each feature block into P sub-blocks, so P must divide
m_q.  The simulated engine repartitions with inert zero-column padding
when it does not; ``make_radisa_step`` fails loudly instead (the data is
already laid out across devices -- see the ValueError below).  The
unified ``Solver`` API pads the feature dimension to a multiple of P*Q
up front for BOTH engines, so the constraint never binds there.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engines import (EngineProgram, SparseShardMapData,
                      drive_with_callback)
from .local import local_svrg, local_svrg_sparse
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_gather, ell_scatter_add, subblock_slices)
from .util import pvary, shard_map


@dataclasses.dataclass(frozen=True)
class RADiSAConfig:
    lam: float = 1e-3
    L: Optional[int] = None          # batch size (inner steps); default n_p
    gamma: float = 1.0               # step size constant
    outer_iters: int = 20
    variant: str = "block"           # "block" | "avg"
    seed: int = 0

    def eta(self, t):
        # paper: eta_t = gamma / (1 + sqrt(t - 1))
        return self.gamma / (1.0 + jnp.sqrt(jnp.maximum(t - 1.0, 0.0)))


def _anchor_quantities(loss: Loss, data: DoublyPartitioned, w_blocks, lam):
    """z = X w_tilde (P, n_p) and mu = grad F(w_tilde) (Q, m_q), simulated."""
    z = jnp.einsum("pqnm,qm->pn", data.x_blocks, w_blocks)
    gz = loss.grad(z, data.y_blocks) * data.mask          # (P, n_p)
    mu = jnp.einsum("pn,pqnm->qm", gz, data.x_blocks) / data.n \
        + lam * w_blocks
    return z, mu


def _anchor_quantities_sparse(loss: Loss, data: SparseDoublyPartitioned,
                              w_blocks, lam):
    """Sparse-cell anchor pass: the row inner products become per-row
    gathers of w and the column gradient a scatter-add over rows."""
    m_q = data.m_q

    def z_block(cols_q, vals_q, w_q):    # (P, n_p, k), (P, n_p, k), (m_q,)
        return ell_gather(w_q, cols_q, vals_q)            # (P, n_p)
    z = jax.vmap(z_block, in_axes=(1, 1, 0))(
        data.cols, data.vals, w_blocks).sum(axis=0)       # (P, n_p)
    gz = loss.grad(z, data.y_blocks) * data.mask          # (P, n_p)

    def mu_block(cols_q, vals_q):
        def one(cols_pq, vals_pq, g_p):
            return ell_scatter_add(m_q, cols_pq, vals_pq, g_p)
        return jax.vmap(one)(cols_q, vals_q, gz).sum(axis=0)
    mu = jax.vmap(mu_block, in_axes=(1, 1))(data.cols, data.vals) / data.n \
        + lam * w_blocks
    return z, mu


# ----------------------------------------------------------------------------
# simulated grid engine
# ----------------------------------------------------------------------------

def radisa_simulated_program(loss: Loss, data: DoublyPartitioned,
                             cfg: RADiSAConfig, *,
                             local_backend: str = "ref",
                             w0=None) -> EngineProgram:
    """vmap-over-cells engine.  State: w_blocks (Q, m_q).

    Requires P | m_q (pre-pad with ``partition(..., m_multiple=P*Q)``).
    ``data`` may be dense (:class:`DoublyPartitioned`) or sparse
    (:class:`SparseDoublyPartitioned`, padded-ELL cells)."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    lam = cfg.lam
    L = cfg.L or data.n_p
    m_sub = subblock_slices(data.m_q, Pn)
    key0 = jax.random.PRNGKey(cfg.seed)
    local = local_svrg_sparse if sparse else local_svrg

    @jax.jit
    def outer(t, w_blocks):
        eta = cfg.eta(t)
        key_t = jax.random.fold_in(key0, t)
        if sparse:
            z, mu = _anchor_quantities_sparse(loss, data, w_blocks, lam)
        else:
            z, mu = _anchor_quantities(loss, data, w_blocks, lam)
        # step 5: non-overlapping random sub-block exchange, shared perm
        perm = jax.random.permutation(jax.random.fold_in(key_t, 0), Pn)
        key_cells = jax.random.fold_in(key_t, 1)

        def cell(p, q):
            key_pq = jax.random.fold_in(key_cells, p * Qn + q)
            s = perm[p]                                   # assigned sub-block
            lo = s * m_sub
            w_anchor = jax.lax.dynamic_slice(w_blocks[q], (lo,), (m_sub,))
            mu_sub = jax.lax.dynamic_slice(mu[q], (lo,), (m_sub,))
            lo_arg = lo
            if cfg.variant == "avg":
                lo_arg, w_anchor, mu_sub = None, w_blocks[q], mu[q]
            x_cell = ((data.cols[p, q], data.vals[p, q]) if sparse
                      else (data.x_blocks[p, q],))
            w_new = local(loss, *x_cell, data.y_blocks[p],
                          data.mask[p], z[p], w_anchor, mu_sub,
                          lam=lam, L=L, eta=eta, key=key_pq, lo=lo_arg,
                          backend=local_backend)
            return w_new

        w_cells = jax.vmap(lambda p: jax.vmap(lambda q: cell(p, q))(
            jnp.arange(Qn)))(jnp.arange(Pn))              # (P, Q, m_sub|m_q)

        if cfg.variant == "avg":
            # RADiSA-avg: average the P overlapping solutions per block
            return w_cells.mean(axis=0)                   # (Q, m_q)

        # step 12: concatenate -- scatter each cell's sub-block back
        def place(q):
            blk = jnp.zeros((data.m_q,))
            def body(blk, p):
                lo = perm[p] * m_sub
                return jax.lax.dynamic_update_slice(blk, w_cells[p, q], (lo,)), None
            blk, _ = jax.lax.scan(body, blk, jnp.arange(Pn))
            return blk
        return jax.vmap(place)(jnp.arange(Qn))

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    return EngineProgram(
        state=w_init,
        step=outer,
        w_of=data.w_from_blocks)


def radisa_simulated(loss_name: str, data: DoublyPartitioned,
                     cfg: RADiSAConfig, callback=None,
                     local_backend: str = "ref"):
    loss = get_loss(loss_name)
    Pn, Qn = data.P, data.Q
    if data.m_q % Pn:
        # RADiSA pre-splits each feature block into P sub-blocks; repartition
        # with extra (inert, all-zero) column padding so that P | m_q.
        from .partition import partition as _partition
        X, y = data.dense()
        padded = _partition(X, y, Pn, Qn, m_multiple=Pn * Qn)
        true_m = data.m

        def unpad_cb(t, w):
            if callback is not None:
                callback(t, w[:true_m])

        w = radisa_simulated(loss_name, padded, cfg,
                             callback=unpad_cb if callback else None,
                             local_backend=local_backend)
        return w[:true_m]

    prog = radisa_simulated_program(loss, data, cfg,
                                    local_backend=local_backend)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ----------------------------------------------------------------------------
# shard_map engine (production)
# ----------------------------------------------------------------------------

def make_radisa_step(loss: Loss, mesh, cfg: RADiSAConfig, *, n: int, n_p: int,
                     m_q: int, data_axis: str = "data",
                     model_axis: str = "model",
                     local_backend: str = "ref"):
    """Distributed RADiSA outer step.

    Layouts: x (n, m) sharded (data, model); y/mask (n,) (data,);
    w (m,) (model,) replicated over data.
    """
    from .util import as_axes, axes_index, axes_size
    lam = cfg.lam
    daxes = as_axes(data_axis)
    Pn, Qn = axes_size(mesh, data_axis), axes_size(mesh, model_axis)
    L = cfg.L or n_p
    avg = cfg.variant == "avg"
    if not avg and m_q % Pn:
        raise ValueError(
            f"RADiSA pre-splits each feature block into P={Pn} sub-blocks, "
            f"but P does not divide m_q={m_q}; truncating would silently "
            f"drop the trailing {m_q % Pn} feature columns of every block. "
            "Pad the feature dimension to a multiple of P*Q first (the "
            "unified Solver API and radisa_simulated do this), or use "
            "variant='avg'.")
    m_sub = m_q // Pn

    def step(t, key0, x, y, mask, w):
        eta = cfg.eta(t)
        key_t = jax.random.fold_in(key0, t)

        def cell(x_b, y_b, mask_b, w_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            w_b = pvary(w_b, daxes)
            p = axes_index(data_axis)
            q = axes_index(model_axis)
            # (1) anchor inner products, reduced across feature blocks
            z = jax.lax.psum(x_b @ w_b, model_axis)            # (n_p,)
            # (2) full gradient of F at the anchor, reduced across rows
            gz = loss.grad(z, y_b) * mask_b
            mu = jax.lax.psum(gz @ x_b, data_axis) / n + lam * w_b
            # (3) sub-block assignment (shared permutation) + local SVRG
            perm = jax.random.permutation(jax.random.fold_in(key_t, 0), Pn)
            key_pq = jax.random.fold_in(jax.random.fold_in(key_t, 1),
                                        p * Qn + q)
            s = perm[p]
            lo = s * m_sub
            if avg:
                lo_arg, w_anchor, mu_sub = None, w_b, mu
            else:
                # NOTE: the sub-block columns are sliced per sampled ROW
                # inside local_svrg (lo=...), never as a (n_p, m_sub)
                # block -- see local_svrg's docstring for why.
                lo_arg = lo
                w_anchor = jax.lax.dynamic_slice(w_b, (lo,), (m_sub,))
                mu_sub = jax.lax.dynamic_slice(mu, (lo,), (m_sub,))
            w_new = local_svrg(loss, x_b, y_b, mask_b, z, w_anchor, mu_sub,
                               lam=lam, L=L, eta=eta, key=key_pq, lo=lo_arg,
                               backend=local_backend)
            # (4) recombine
            if avg:
                return jax.lax.pmean(w_new, data_axis)
            delta = jnp.zeros_like(w_b)
            delta = jax.lax.dynamic_update_slice(delta, w_new - w_anchor, (lo,))
            return w_b + jax.lax.psum(delta, data_axis)

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                      P(model_axis)),
            out_specs=P(model_axis),
        )(x, y, mask, w)

    return jax.jit(step)


def make_radisa_step_sparse(loss: Loss, mesh, cfg: RADiSAConfig, *, n: int,
                            n_p: int, m_q: int, data_axis: str = "data",
                            model_axis: str = "model",
                            local_backend: str = "ref"):
    """Sparse-cell variant of :func:`make_radisa_step`.

    The device-local block is the padded-ELL pair cols/vals (n_p, k)
    with block-local column ids; the anchor pass becomes a gather-matvec
    (rows) and a scatter-add (columns), and the sub-block window is
    selected inside the local solver by masking entry columns (the ELL
    row cannot be column-sliced).
    """
    from .util import as_axes, axes_index, axes_size
    lam = cfg.lam
    daxes = as_axes(data_axis)
    Pn, Qn = axes_size(mesh, data_axis), axes_size(mesh, model_axis)
    L = cfg.L or n_p
    avg = cfg.variant == "avg"
    if not avg and m_q % Pn:
        raise ValueError(
            f"RADiSA pre-splits each feature block into P={Pn} sub-blocks, "
            f"but P does not divide m_q={m_q}; truncating would silently "
            f"drop the trailing {m_q % Pn} feature columns of every block. "
            "Pad the feature dimension to a multiple of P*Q first (the "
            "unified Solver API does this), or use variant='avg'.")
    m_sub = m_q // Pn

    def step(t, key0, cols, vals, y, mask, w):
        eta = cfg.eta(t)
        key_t = jax.random.fold_in(key0, t)

        def cell(cols_b, vals_b, y_b, mask_b, w_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            w_b = pvary(w_b, daxes)
            p = axes_index(data_axis)
            q = axes_index(model_axis)
            # (1) anchor inner products: per-row gather of the local w
            # block, reduced across feature blocks
            z = jax.lax.psum(ell_gather(w_b, cols_b, vals_b), model_axis)
            # (2) full anchor gradient: scatter-add over the cell's
            # entries, reduced across observation partitions
            gz = loss.grad(z, y_b) * mask_b
            mu = jax.lax.psum(ell_scatter_add(m_q, cols_b, vals_b, gz),
                              data_axis) / n + lam * w_b
            # (3) sub-block assignment (shared permutation) + local SVRG
            perm = jax.random.permutation(jax.random.fold_in(key_t, 0), Pn)
            key_pq = jax.random.fold_in(jax.random.fold_in(key_t, 1),
                                        p * Qn + q)
            s = perm[p]
            lo = s * m_sub
            if avg:
                lo_arg, w_anchor, mu_sub = None, w_b, mu
            else:
                lo_arg = lo
                w_anchor = jax.lax.dynamic_slice(w_b, (lo,), (m_sub,))
                mu_sub = jax.lax.dynamic_slice(mu, (lo,), (m_sub,))
            w_new = local_svrg_sparse(
                loss, cols_b, vals_b, y_b, mask_b, z, w_anchor, mu_sub,
                lam=lam, L=L, eta=eta, key=key_pq, lo=lo_arg,
                backend=local_backend)
            # (4) recombine
            if avg:
                return jax.lax.pmean(w_new, data_axis)
            delta = jnp.zeros_like(w_b)
            delta = jax.lax.dynamic_update_slice(delta, w_new - w_anchor,
                                                 (lo,))
            return w_b + jax.lax.psum(delta, data_axis)

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                      P(data_axis), P(data_axis), P(model_axis)),
            out_specs=P(model_axis),
        )(cols, vals, y, mask, w)

    return jax.jit(step)


def radisa_shard_map_program(loss: Loss, sdata, cfg: RADiSAConfig, *,
                             local_backend: str = "ref",
                             w0=None) -> EngineProgram:
    """shard_map engine.  State: w (m_pad,) sharded over the model axis.
    ``sdata`` is a :class:`ShardMapData` or :class:`SparseShardMapData`."""
    key0 = jax.random.PRNGKey(cfg.seed)
    if isinstance(sdata, SparseShardMapData):
        step = make_radisa_step_sparse(
            loss, sdata.mesh, cfg, n=sdata.n, n_p=sdata.n_p, m_q=sdata.m_q,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            local_backend=local_backend)

        def run(t, w):
            return step(t, key0, sdata.cols, sdata.vals, sdata.y,
                        sdata.mask, w)
    else:
        step = make_radisa_step(loss, sdata.mesh, cfg, n=sdata.n,
                                n_p=sdata.n_p, m_q=sdata.m_q,
                                data_axis=sdata.data_axis,
                                model_axis=sdata.model_axis,
                                local_backend=local_backend)

        def run(t, w):
            return step(t, key0, sdata.x, sdata.y, sdata.mask, w)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    return EngineProgram(
        state=w_init,
        step=run,
        w_of=lambda w: w[: sdata.m])


def radisa_distributed(loss_name: str, mesh, x, y, mask, cfg: RADiSAConfig,
                       callback=None, local_backend: str = "ref"):
    loss = get_loss(loss_name)
    n, m = x.shape
    Pn, Qn = mesh.shape["data"], mesh.shape["model"]
    step = make_radisa_step(loss, mesh, cfg, n=n, n_p=n // Pn, m_q=m // Qn,
                            local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    prog = EngineProgram(
        state=jnp.zeros((m,)),
        step=lambda t, w: step(t, key0, x, y, mask, w),
        w_of=lambda w: w)
    return drive_with_callback(prog, cfg.outer_iters, callback)
