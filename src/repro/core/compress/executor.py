"""CompressedComm: a Comm executor that compresses collective payloads.

Wraps any inner :class:`~repro.core.comm.Comm` (``SyncComm`` for the
grid/sync engines, ``StaleComm`` for the bounded-staleness async
engine), so compression composes with every communication policy: the
cell's contribution is encoded/decoded by the collective's codec
*before* the inner executor reduces it, and the async engine's
staleness rings then carry the reduction of dequantized values --
exactly the order a real bandwidth-saving all-reduce would impose
(quantize, put on the wire, reduce, delay consumption).

Error feedback: each stateful codec's residual enters through ``ef``
(one per-cell f32 buffer per compressed collective, sliced out of the
engine state pytree the same way the staleness rings are) and the
updated residuals come back out via :attr:`CompressedComm.ef_out`.

Wire accounting: every Comm executor records the exact payload bytes it
put on the wire per collective in ``.wire_bytes`` (the base class
records the uncompressed size; this class overrides it with the codec's
payload size).  :func:`wire_accounting` computes the same numbers
statically from a schedule + payload avals -- that is what the engines
attach to ``EngineProgram.comm_bytes`` and what surfaces in Solver
history and the BENCH emitters.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax.numpy as jnp

from ..comm import Comm, CommSchedule
from .codecs import IdentityCodec
from .policy import CompressionPolicy


class CompressedComm(Comm):
    """Compress each declared collective's payload per its policy codec,
    then delegate the actual reduction to the wrapped executor."""

    def __init__(self, inner: Comm, policy: CompressionPolicy,
                 ef: Optional[dict] = None):
        super().__init__(inner.schedule, inner.axis_map, inner.sizes)
        self.inner = inner
        self.policy = policy
        self.ef_in = dict(ef or {})
        #: updated error-feedback residuals, one per stateful collective
        self.ef_out: Dict[str, jnp.ndarray] = {}
        # staleness FIFO slots are produced by the inner executor; share
        # the dict object so the engine reads them off either comm
        self.bufs_out = inner.bufs_out

    # cell-facing index/size queries go to the inner executor (the
    # ShapeProbeComm override of axis_index must win under eval_shape)
    def axis_index(self, axis: str):
        return self.inner.axis_index(axis)

    def axis_size(self, axis: str) -> int:
        return self.inner.axis_size(axis)

    def _exec(self, point, value):
        codec = self.policy.codec_for(point.name)
        value = jnp.asarray(value)
        self.wire_bytes[point.name] = codec.payload_nbytes(
            value.shape, value.dtype)
        if codec.stateful:
            err = self.ef_in.get(point.name)
            if err is None:
                # build-time probing runs without buffers; a zero
                # residual has the right aval
                err = jnp.zeros(value.shape, jnp.float32)
            deq, new_err = codec.apply(value, err)
            self.ef_out[point.name] = new_err
            deq = deq.astype(value.dtype)
        else:
            deq, _ = codec.apply(value)
        return self.inner._exec(point, deq)

    def finalize(self):
        super().finalize()
        # run the inner executor's own contract checks (e.g. StaleComm's
        # buffer bookkeeping) against the points executed through us
        self.inner._executed = set(self._executed)
        self.inner.finalize()
        missing = (set(self.policy.stateful_names(self.schedule))
                   - set(self.ef_out))
        if missing:
            raise ValueError(
                f"error-feedback residuals never produced for compressed "
                f"collectives {sorted(missing)}")


# ---------------------------------------------------------------------------
# exact bytes-on-wire accounting
# ---------------------------------------------------------------------------

def wire_accounting(schedule: CommSchedule, payload_avals: dict,
                    sizes: dict,
                    policy: Optional[CompressionPolicy] = None) -> dict:
    """Exact per-step wire cost of one outer iteration.

    Every cell of the P x Q grid contributes one payload to each
    declared collective per step (psum/pmean/allgather alike), so a
    collective moves ``P * Q * payload_bytes`` per step; the codec
    decides the payload layout.  ``payload_avals`` maps collective name
    to the per-cell *input* aval (what the cell hands to ``comm``);
    ``sizes`` holds the logical grid extents.  Returns::

        {"collectives": {name: {op, axis, codec, payload_bytes_per_cell,
                                uncompressed_bytes_per_cell, cells,
                                bytes_per_step,
                                uncompressed_bytes_per_step}},
         "bytes_per_step": ...,            # sum over collectives
         "uncompressed_bytes_per_step": ...,
         "compression": <policy spec or None>}

    With no policy (or the identity codec) ``bytes_per_step`` equals
    ``uncompressed_bytes_per_step`` exactly -- the accounting invariant
    pinned in tests/test_compress.py.
    """
    identity = IdentityCodec()
    cells = int(sizes["data"]) * int(sizes["model"])
    per = {}
    total = 0
    total_raw = 0
    for point in schedule:
        aval = payload_avals[point.name]
        codec = policy.codec_for(point.name) if policy is not None \
            else identity
        raw = math.prod(aval.shape) * jnp.dtype(aval.dtype).itemsize
        comp = codec.payload_nbytes(aval.shape, aval.dtype)
        per[point.name] = {
            "op": point.op, "axis": point.axis, "codec": codec.name,
            # per-cell payload aval (what one cell hands to ``comm``);
            # telemetry microbenchmarks each codec on it
            # (repro.obs.phases.bench_codecs)
            "payload_shape": tuple(int(d) for d in aval.shape),
            "payload_dtype": str(jnp.dtype(aval.dtype).name),
            "payload_bytes_per_cell": int(comp),
            "uncompressed_bytes_per_cell": int(raw),
            "cells": cells,
            "bytes_per_step": int(comp) * cells,
            "uncompressed_bytes_per_step": int(raw) * cells,
        }
        total += int(comp) * cells
        total_raw += int(raw) * cells
    return {"collectives": per,
            "bytes_per_step": total,
            "uncompressed_bytes_per_step": total_raw,
            "compression": policy.spec if policy is not None else None}
