"""Compression codecs for cross-cell reductions.

A *codec* turns the per-cell payload of one declared collective (see
``repro.core.comm.CommSchedule``) into a smaller wire representation and
back.  The solvers never see the codec: the
:class:`~repro.core.compress.executor.CompressedComm` executor encodes
the cell's contribution, immediately decodes it, and hands the (lossy)
result to the underlying ``SyncComm``/``StaleComm`` -- which is exactly
what a bandwidth-saving all-reduce does semantically, since the
reduction itself operates on dequantized values.

Lossy codecs carry **error feedback** (Seide et al. 2014, Karimireddy et
al. 2019): the quantization residual of step t is added to the payload
of step t+1, so the *accumulated* communicated signal tracks the true
accumulated signal and convergence is preserved.  The residual is one
float32 buffer per (cell, collective), carried in the engine state
pytree next to the async engine's staleness rings.

Codecs:

  * ``identity``  -- no-op; ``apply`` returns the input array object
    unchanged, so an identity-codec run is bit-identical to an
    uncompressed one (this is tested, and is what makes the subsystem a
    safe refactor);
  * ``int8``      -- per-collective symmetric quantization to int8 with
    one float32 scale (max-abs / 127), ~4x fewer wire bytes than f32;
  * ``fp8``       -- simulated float8 (e4m3) cast with one float32
    scale; same 1-byte payload as int8, different error profile;
  * ``topk:FRAC`` -- magnitude top-k sparsification: the largest
    ``ceil(FRAC * size)`` entries travel as (value, index) pairs.

``payload_nbytes`` is exact arithmetic over the payload layout (no
tracing), so the wire accounting of
:func:`~repro.core.compress.executor.wire_accounting` is exact: the
identity codec reports precisely the uncompressed payload bytes.

This module also absorbs the tree-level int8 helpers that used to live
in ``repro.optim.compression`` (now a deprecation shim):
:func:`init_error` / :func:`compress` / :func:`decompress` keep their
exact legacy numerics, reimplemented over :class:`Int8Codec`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

FP8_E4M3_MAX = 448.0
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)


class Codec:
    """One compression scheme for a collective's per-cell payload.

    ``encode(value) -> payload`` (tuple of arrays, the wire format),
    ``decode(payload, shape) -> value``-shaped dequantized array, and
    ``apply(value, err)`` fuses encode/decode with error feedback:
    returns ``(dequantized, new_err)`` where ``new_err`` is ``None`` for
    stateless codecs.  ``payload_nbytes(shape, dtype)`` is the exact
    wire size of one cell's payload, computed arithmetically.
    """

    name: str = "?"
    #: True when the codec is lossy and carries an error-feedback
    #: residual (one f32 buffer per cell per collective)
    stateful: bool = False

    def encode(self, value):
        raise NotImplementedError

    def decode(self, payload, shape):
        raise NotImplementedError

    def payload_nbytes(self, shape, dtype) -> int:
        raise NotImplementedError

    def init_state(self, shape):
        """Zero error-feedback residual for one cell's payload."""
        return jnp.zeros(shape, jnp.float32)

    def apply(self, value, err=None):
        if not self.stateful:
            return self.decode(self.encode(value), value.shape), None
        t = value.astype(jnp.float32) + (0.0 if err is None else err)
        deq = self.decode(self.encode(t), value.shape)
        return deq, t - deq

    def __repr__(self):
        return f"<codec {self.name}>"


class IdentityCodec(Codec):
    """Exact passthrough; reports the uncompressed payload bytes."""

    name = "identity"
    stateful = False

    def encode(self, value):
        return (value,)

    def decode(self, payload, shape):
        return payload[0]

    def apply(self, value, err=None):
        # return the input array OBJECT: an identity-codec run produces
        # the same jaxpr as an uncompressed run (bit-identical iterates)
        return value, None

    def payload_nbytes(self, shape, dtype) -> int:
        return math.prod(shape) * jnp.dtype(dtype).itemsize


class Int8Codec(Codec):
    """Symmetric per-collective int8 quantization with one f32 scale.

    ``scale = max|t| / 127 + 1e-12`` (the exact formula of the legacy
    ``repro.optim.compression`` module, kept so the shim round-trips
    bit-for-bit); wire payload is ``size`` int8 values + 4 scale bytes.
    """

    name = "int8"
    stateful = True

    def encode(self, value):
        t = value.astype(jnp.float32)
        scale = jnp.max(jnp.abs(t)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)

    def decode(self, payload, shape):
        q, scale = payload
        return q.astype(jnp.float32) * scale

    def payload_nbytes(self, shape, dtype) -> int:
        return math.prod(shape) * 1 + 4          # int8 payload + f32 scale


class Fp8Codec(Codec):
    """Simulated fp8 (e4m3) quantization with one f32 scale.

    Values are scaled into the e4m3 dynamic range, cast to
    ``jnp.float8_e4m3fn`` and back -- the cast is the quantizer, so the
    error profile is fp8's (relative, not absolute like int8's).  Wire
    payload is ``size`` fp8 bytes + 4 scale bytes.
    """

    name = "fp8"
    stateful = True

    def __init__(self):
        if _FP8_DTYPE is None:
            raise NotImplementedError(
                "codec 'fp8' needs jnp.float8_e4m3fn, which this jax "
                "build does not provide; use 'int8' instead")

    def encode(self, value):
        t = value.astype(jnp.float32)
        scale = jnp.max(jnp.abs(t)) / FP8_E4M3_MAX + 1e-12
        return (t / scale).astype(_FP8_DTYPE), scale.astype(jnp.float32)

    def decode(self, payload, shape):
        q, scale = payload
        return q.astype(jnp.float32) * scale

    def payload_nbytes(self, shape, dtype) -> int:
        return math.prod(shape) * 1 + 4          # fp8 payload + f32 scale


class TopKCodec(Codec):
    """Magnitude top-k sparsification: keep the ``ceil(frac * size)``
    largest-|.| entries, zero the rest.  Wire payload is k (value,
    int32 index) pairs; everything dropped lands in the error-feedback
    residual and travels on a later step."""

    stateful = True

    def __init__(self, frac: float = 0.1):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk fraction must be in (0, 1], got {frac}")
        self.frac = float(frac)

    @property
    def name(self) -> str:
        return f"topk:{self.frac:g}"

    def k_of(self, size: int) -> int:
        return max(1, min(size, int(math.ceil(self.frac * size))))

    def encode(self, value):
        flat = value.astype(jnp.float32).ravel()
        k = self.k_of(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return flat[idx], idx.astype(jnp.int32)

    def decode(self, payload, shape):
        vals, idx = payload
        size = math.prod(shape)
        return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)

    def payload_nbytes(self, shape, dtype) -> int:
        # encode always emits f32 values (+ int32 indices), whatever the
        # input dtype, so the wire cost is 8 bytes per kept entry
        k = self.k_of(math.prod(shape))
        return k * (4 + 4)


# ---------------------------------------------------------------------------
# codec registry
# ---------------------------------------------------------------------------

_FACTORIES = {
    "identity": IdentityCodec,
    "none": IdentityCodec,       # accepted spelling in policy specs
    "int8": Int8Codec,
    "fp8": Fp8Codec,
}


def available_codecs():
    return sorted(_FACTORIES) + ["topk:FRAC"]


def get_codec(spec) -> Codec:
    """Codec instance from a spec string: ``identity`` / ``none`` /
    ``int8`` / ``fp8`` / ``topk`` / ``topk:0.25``."""
    if isinstance(spec, Codec):
        return spec
    s = str(spec).strip().lower()
    if s.startswith("topk"):
        rest = s[len("topk"):]
        if rest in ("", ":"):
            return TopKCodec()
        return TopKCodec(float(rest.lstrip(":")))
    try:
        return _FACTORIES[s]()
    except KeyError:
        raise ValueError(f"unknown codec {spec!r}; available: "
                         f"{available_codecs()}") from None


# ---------------------------------------------------------------------------
# legacy tree-level helpers (ex repro.optim.compression)
# ---------------------------------------------------------------------------

_INT8 = Int8Codec()


def init_error(params):
    """Zero error-feedback residual tree matching ``params``."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress(grads, error):
    """Int8-with-error-feedback over a pytree.
    Returns ``(int8 tree, scale tree, new error tree)``."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = _INT8.encode(t)
        return q, s, t - _INT8.decode((q, s), t.shape)

    out = jax.tree.map(one, grads, error)
    is_rec = lambda x: isinstance(x, tuple)  # noqa: E731
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=is_rec)
    ss = jax.tree.map(lambda t: t[1], out, is_leaf=is_rec)
    es = jax.tree.map(lambda t: t[2], out, is_leaf=is_rec)
    return qs, ss, es


def decompress(qs, ss):
    """Inverse of :func:`compress` (without the residual)."""
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, ss)
