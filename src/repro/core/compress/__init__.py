"""Compressed-communication subsystem for the doubly distributed solvers.

Three pieces, composable with every engine:

  * :mod:`~repro.core.compress.codecs` -- identity / int8 / simulated
    fp8 / top-k payload codecs with error feedback;
  * :mod:`~repro.core.compress.policy` -- ``CompressionPolicy`` mapping
    CommSchedule collective *names* to codecs (validated against each
    solver's declared schedule at build time);
  * :mod:`~repro.core.compress.executor` -- the ``CompressedComm``
    executor (wraps ``SyncComm``/``StaleComm``) plus exact
    bytes-on-wire accounting (``wire_accounting``).

End to end: ``get_solver("d3ca")(compression="int8")`` -- see the README
section "Compressed reductions".  This package absorbs the old
``repro.optim.compression`` module (now a deprecation shim over the
tree-level helpers re-exported here).
"""
from .codecs import (Codec, Fp8Codec, IdentityCodec, Int8Codec, TopKCodec,
                     available_codecs, compress, decompress, get_codec,
                     init_error)
from .executor import CompressedComm, wire_accounting
from .policy import (CompressionPolicy, CompressionSchedule, as_compression,
                     as_policy, identity_policy)

__all__ = [
    "Codec", "Fp8Codec", "IdentityCodec", "Int8Codec", "TopKCodec",
    "available_codecs", "get_codec",
    "compress", "decompress", "init_error",
    "CompressedComm", "wire_accounting",
    "CompressionPolicy", "CompressionSchedule", "as_compression",
    "as_policy", "identity_policy",
]
