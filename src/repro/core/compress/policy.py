"""CompressionPolicy: which codec runs on which named collective.

A policy maps the *names* a solver's
:class:`~repro.core.comm.CommSchedule` declares to
:class:`~repro.core.compress.codecs.Codec` instances, with a default
codec for every name not mentioned.  Because collectives are named, a
policy can compress the big vector reductions while leaving the
numerically delicate ones exact::

    # compress D3CA's primal-dual map, keep the dual average exact
    CompressionPolicy.from_spec("w_contrib=int8,dalpha=identity")

    # one codec for every declared collective
    CompressionPolicy.from_spec("int8")

    # mixed: default int8, but ADMM's ridge rhs stays exact
    CompressionPolicy.from_spec("int8,rhs=identity")

Policies are validated against each solver's declared schedule at
program-build time (:meth:`CompressionPolicy.validate`): naming a
collective the solver never declares is a loud error listing what IS
declared, so a typo cannot silently leave a reduction uncompressed.
"""
from __future__ import annotations

from typing import Dict, Optional

from .codecs import Codec, IdentityCodec, get_codec


class CompressionPolicy:
    """Per-collective codec assignment with a default."""

    def __init__(self, default="identity",
                 per_collective: Optional[Dict[str, object]] = None):
        self.default: Codec = get_codec(default)
        self.per_collective: Dict[str, Codec] = {
            name: get_codec(c) for name, c in (per_collective or {}).items()}

    # -- construction --------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "CompressionPolicy":
        """Parse ``"int8"`` / ``"topk:0.1"`` / ``"dw=int8,z=identity"`` /
        ``"int8,rhs=identity"`` (bare entry = default codec)."""
        default = "identity"
        per: Dict[str, str] = {}
        seen_default = False
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            if "=" in part:
                name, codec = part.split("=", 1)
                name, codec = name.strip(), codec.strip()
                if not name or not codec:
                    raise ValueError(f"malformed policy entry {part!r} in "
                                     f"spec {spec!r}")
                if name in per:
                    raise ValueError(f"collective {name!r} assigned twice "
                                     f"in spec {spec!r}")
                per[name] = codec
            else:
                if seen_default:
                    raise ValueError(f"two default codecs in spec {spec!r}")
                default, seen_default = part, True
        return cls(default=default, per_collective=per)

    # -- lookup --------------------------------------------------------------
    def codec_for(self, name: str) -> Codec:
        return self.per_collective.get(name, self.default)

    def stateful_names(self, schedule) -> tuple:
        """Names of the schedule's collectives whose codec carries an
        error-feedback residual."""
        return tuple(p.name for p in schedule
                     if self.codec_for(p.name).stateful)

    @property
    def spec(self) -> str:
        """Canonical round-trippable spec string."""
        parts = [self.default.name]
        parts += [f"{n}={c.name}"
                  for n, c in sorted(self.per_collective.items())]
        return ",".join(parts)

    # -- build-time contract -------------------------------------------------
    def validate(self, schedule) -> "CompressionPolicy":
        """Every explicitly named collective must be declared by the
        solver's CommSchedule."""
        unknown = sorted(set(self.per_collective) - set(schedule.names))
        if unknown:
            raise ValueError(
                f"compression policy names collectives {unknown} that this "
                f"solver's CommSchedule never declares "
                f"(declared: {sorted(schedule.names)}); fix the policy spec "
                "or drop the entry")
        return self

    def __repr__(self):
        return f"CompressionPolicy({self.spec!r})"


def as_policy(compression) -> Optional[CompressionPolicy]:
    """Normalize the user-facing ``compression=`` knob.

    ``None`` means *no compression machinery at all* (the engines build
    the exact PR-4 program); a policy whose codecs are all identity
    still routes through :class:`CompressedComm` but is bit-identical by
    construction.  Accepts a policy, a spec string, a codec name, or a
    ``{collective: codec}`` dict (dict entries may include a
    ``"default"`` key).
    """
    if compression is None:
        return None
    if isinstance(compression, CompressionPolicy):
        return compression
    if isinstance(compression, dict):
        per = dict(compression)
        default = per.pop("default", "identity")
        return CompressionPolicy(default=default, per_collective=per)
    if isinstance(compression, Codec):
        return CompressionPolicy(default=compression)
    return CompressionPolicy.from_spec(str(compression))


def identity_policy() -> CompressionPolicy:
    return CompressionPolicy(default=IdentityCodec())
