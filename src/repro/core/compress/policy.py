"""CompressionPolicy: which codec runs on which named collective.

A policy maps the *names* a solver's
:class:`~repro.core.comm.CommSchedule` declares to
:class:`~repro.core.compress.codecs.Codec` instances, with a default
codec for every name not mentioned.  Because collectives are named, a
policy can compress the big vector reductions while leaving the
numerically delicate ones exact::

    # compress D3CA's primal-dual map, keep the dual average exact
    CompressionPolicy.from_spec("w_contrib=int8,dalpha=identity")

    # one codec for every declared collective
    CompressionPolicy.from_spec("int8")

    # mixed: default int8, but ADMM's ridge rhs stays exact
    CompressionPolicy.from_spec("int8,rhs=identity")

Policies are validated against each solver's declared schedule at
program-build time (:meth:`CompressionPolicy.validate`): naming a
collective the solver never declares is a loud error listing what IS
declared, so a typo cannot silently leave a reduction uncompressed.
"""
from __future__ import annotations

from typing import Dict, Optional

from .codecs import Codec, IdentityCodec, get_codec


class CompressionPolicy:
    """Per-collective codec assignment with a default."""

    def __init__(self, default="identity",
                 per_collective: Optional[Dict[str, object]] = None):
        self.default: Codec = get_codec(default)
        self.per_collective: Dict[str, Codec] = {
            name: get_codec(c) for name, c in (per_collective or {}).items()}

    # -- construction --------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "CompressionPolicy":
        """Parse ``"int8"`` / ``"topk:0.1"`` / ``"dw=int8,z=identity"`` /
        ``"int8,rhs=identity"`` (bare entry = default codec)."""
        default = "identity"
        per: Dict[str, str] = {}
        seen_default = False
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            if "=" in part:
                name, codec = part.split("=", 1)
                name, codec = name.strip(), codec.strip()
                if not name or not codec:
                    raise ValueError(f"malformed policy entry {part!r} in "
                                     f"spec {spec!r}")
                if name in per:
                    raise ValueError(f"collective {name!r} assigned twice "
                                     f"in spec {spec!r}")
                per[name] = codec
            else:
                if seen_default:
                    raise ValueError(f"two default codecs in spec {spec!r}")
                default, seen_default = part, True
        return cls(default=default, per_collective=per)

    # -- lookup --------------------------------------------------------------
    def codec_for(self, name: str) -> Codec:
        return self.per_collective.get(name, self.default)

    def stateful_names(self, schedule) -> tuple:
        """Names of the schedule's collectives whose codec carries an
        error-feedback residual."""
        return tuple(p.name for p in schedule
                     if self.codec_for(p.name).stateful)

    @property
    def spec(self) -> str:
        """Canonical round-trippable spec string."""
        parts = [self.default.name]
        parts += [f"{n}={c.name}"
                  for n, c in sorted(self.per_collective.items())]
        return ",".join(parts)

    # -- build-time contract -------------------------------------------------
    def validate(self, schedule) -> "CompressionPolicy":
        """Every explicitly named collective must be declared by the
        solver's CommSchedule."""
        unknown = sorted(set(self.per_collective) - set(schedule.names))
        if unknown:
            raise ValueError(
                f"compression policy names collectives {unknown} that this "
                f"solver's CommSchedule never declares "
                f"(declared: {sorted(schedule.names)}); fix the policy spec "
                "or drop the entry")
        return self

    def __repr__(self):
        return f"CompressionPolicy({self.spec!r})"


class CompressionSchedule:
    """Adaptive per-collective codec switching: a sequence of
    :class:`CompressionPolicy` stages advanced by observed convergence.

    The CoCoA-style story: aggressive sparsification (top-k) buys the
    most wire early, when updates are large and redundant; near
    convergence the iterates need the denser signal, so the schedule
    falls back to a gentler codec (int8).  The driver watches the
    ``rel_opt`` slope in solver history (objective decrease when no
    ``f_star`` is known) and advances to the next stage when progress
    per iteration flattens below ``slope_tol`` decades/iter over a
    ``window``-iteration lookback.  Stage switches happen between outer
    steps at the host level -- each stage is a fresh program build warm
    started from the current iterates, since a codec cannot change
    inside a compiled step.

    Spec grammar (``@``-separated options after the ``->`` stage
    chain)::

        adaptive                              # topk:0.25 -> int8
        adaptive:topk:0.1->int8               # explicit stages
        adaptive:topk:0.25->int8->identity@slope=0.02@window=4
    """

    DEFAULT_STAGES = ("topk:0.25", "int8")

    def __init__(self, stages=None, *, slope_tol: float = 0.05,
                 window: int = 3):
        stages = tuple(stages) if stages else self.DEFAULT_STAGES
        self.stages = tuple(as_policy(s) for s in stages)
        if any(s is None for s in self.stages):
            raise ValueError("CompressionSchedule stages must be policies")
        self.slope_tol = float(slope_tol)
        self.window = int(window)
        if self.window < 1:
            raise ValueError(f"window={window} must be >= 1")
        if self.slope_tol < 0:
            raise ValueError(f"slope_tol={slope_tol} must be >= 0")

    @classmethod
    def from_spec(cls, spec: str) -> "CompressionSchedule":
        text = str(spec).strip()
        head, *opts = text.split("@")
        head = head.strip()
        if not (head == "adaptive" or head.startswith("adaptive:")):
            raise ValueError(f"bad adaptive spec {spec!r}: expected "
                             "'adaptive[:stage->stage...][@slope=..]'")
        body = head[len("adaptive"):].lstrip(":")
        stages = [s.strip() for s in body.split("->") if s.strip()] or None
        kw = {}
        for opt in opts:
            key, _, val = opt.strip().partition("=")
            if key == "slope":
                kw["slope_tol"] = float(val)
            elif key == "window":
                kw["window"] = int(val)
            else:
                raise ValueError(f"unknown adaptive option {opt!r} in "
                                 f"spec {spec!r} (know: slope, window)")
        return cls(stages, **kw)

    @property
    def spec(self) -> str:
        chain = "->".join(s.spec for s in self.stages)
        return (f"adaptive:{chain}@slope={self.slope_tol:g}"
                f"@window={self.window}")

    def validate(self, schedule) -> "CompressionSchedule":
        for s in self.stages:
            s.validate(schedule)
        return self

    def should_advance(self, values) -> bool:
        """True when the convergence metric (smaller = better, e.g.
        rel_opt) has flattened: its log10 decrease per iteration over
        the last ``window`` iterations fell below ``slope_tol``."""
        import math
        if len(values) < self.window + 1:
            return False
        a = max(float(values[-1 - self.window]), 1e-12)
        b = max(float(values[-1]), 1e-12)
        slope = (math.log10(a) - math.log10(b)) / self.window
        return slope < self.slope_tol

    def __repr__(self):
        return f"CompressionSchedule({self.spec!r})"


def as_compression(compression):
    """Normalize the ``compression=`` knob including adaptive schedules:
    returns ``None``, a :class:`CompressionPolicy`, or a
    :class:`CompressionSchedule` (``"adaptive..."`` specs)."""
    if isinstance(compression, CompressionSchedule):
        return compression
    if isinstance(compression, str) \
            and compression.strip().startswith("adaptive"):
        return CompressionSchedule.from_spec(compression)
    return as_policy(compression)


def as_policy(compression) -> Optional[CompressionPolicy]:
    """Normalize the user-facing ``compression=`` knob.

    ``None`` means *no compression machinery at all* (the engines build
    the exact PR-4 program); a policy whose codecs are all identity
    still routes through :class:`CompressedComm` but is bit-identical by
    construction.  Accepts a policy, a spec string, a codec name, or a
    ``{collective: codec}`` dict (dict entries may include a
    ``"default"`` key).
    """
    if compression is None:
        return None
    if isinstance(compression, CompressionPolicy):
        return compression
    if isinstance(compression, dict):
        per = dict(compression)
        default = per.pop("default", "identity")
        return CompressionPolicy(default=default, per_collective=per)
    if isinstance(compression, Codec):
        return CompressionPolicy(default=compression)
    return CompressionPolicy.from_spec(str(compression))


def identity_policy() -> CompressionPolicy:
    return CompressionPolicy(default=IdentityCodec())
