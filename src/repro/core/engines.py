"""Engine adapters for the unified solver framework (``repro.core.solver``).

An *engine* is how the P x Q block grid of the paper is executed:

  * ``"simulated"``  -- the grid is materialized as leading array axes of a
    :class:`~repro.core.partition.DoublyPartitioned` and cells run under
    ``vmap`` on one device (correctness tests, paper-figure benchmarks);
  * ``"shard_map"``  -- a (data=P, model=Q) device mesh where each device
    owns one (n_p, m_q) block in HBM and the paper's reductions are mesh
    collectives (the production path).

Each algorithm contributes one :class:`EngineProgram` per engine -- the
initial state, a jitted outer step, and extractors for the global primal
(and dual) iterates.  Everything else (the outer loop, history, early
stopping, warm starts) lives once in the shared driver.

Both engines pad the feature dimension to a multiple of P*Q (columns of
zeros are inert under every update rule), so a cell sees bit-identical
blocks regardless of engine and the two executions agree to float
tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .partition import _ceil_to
from .util import as_axes, axes_size


@dataclasses.dataclass
class EngineProgram:
    """One algorithm bound to one engine: state + step + extractors."""

    state: Any                                    # initial state pytree
    step: Callable[[int, Any], Any]               # (t, state) -> state
    w_of: Callable[[Any], jnp.ndarray]            # state -> global w (m,)
    alpha_of: Optional[Callable[[Any], jnp.ndarray]] = None  # -> alpha (n,)


def drive(prog: EngineProgram, outer_iters: int, observe=None):
    """Run the outer loop.  ``observe(t, state) -> bool`` is called after
    every step; returning True stops early.  Returns
    (final state, iterations run, stopped_early)."""
    state = prog.state
    done = 0
    for t in range(1, outer_iters + 1):
        state = prog.step(t, state)
        done = t
        if observe is not None and observe(t, state):
            return state, done, True
    return state, done, False


def drive_with_callback(prog: EngineProgram, outer_iters: int, callback=None,
                        pass_alpha: bool = False):
    """Driver for the legacy ``*_simulated`` / ``*_distributed`` wrappers:
    relay each iterate to ``callback(t, w[, alpha])``, ignoring its return
    value (legacy callbacks never early-stop).  Returns the final state."""
    observe = None
    if callback is not None:
        def observe(t, state):
            if pass_alpha:
                callback(t, prog.w_of(state), prog.alpha_of(state))
            else:
                callback(t, prog.w_of(state))
            return False
    state, _, _ = drive(prog, outer_iters, observe)
    return state


# ---------------------------------------------------------------------------
# shard_map data preparation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardMapData:
    """Padded global arrays placed on a (data=P, model=Q) mesh."""

    mesh: Any
    x: jnp.ndarray          # (n_pad, m_pad)  sharded (data, model)
    y: jnp.ndarray          # (n_pad,)        sharded (data,)
    mask: jnp.ndarray       # (n_pad,)        sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.x.shape[0]

    @property
    def m_pad(self) -> int:
        return self.x.shape[1]

    @property
    def n_p(self) -> int:
        return self.x.shape[0] // self.P

    @property
    def m_q(self) -> int:
        return self.x.shape[1] // self.Q

    def put(self, arr, spec):
        """device_put onto this mesh with the given PartitionSpec."""
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


@dataclasses.dataclass(frozen=True)
class SparseShardMapData:
    """Padded-ELL global arrays placed on a (data=P, model=Q) mesh.

    The (n_pad, Q*k) ``cols``/``vals`` arrays are sharded
    (data, model): device (p, q) holds exactly the (n_p, k) ELL cell of
    block (p, q), with block-LOCAL column ids in [0, m_q).  Device
    memory for the data block is O(n_p * k) ~ O(nnz), not O(n_p * m_q).
    """

    mesh: Any
    cols: jnp.ndarray       # (n_pad, Q*k) int32  sharded (data, model)
    vals: jnp.ndarray       # (n_pad, Q*k) f32    sharded (data, model)
    y: jnp.ndarray          # (n_pad,)            sharded (data,)
    mask: jnp.ndarray       # (n_pad,)            sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    m_q: int                # padded feature-block width (m_pad = Q * m_q)
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.cols.shape[0]

    @property
    def m_pad(self) -> int:
        return self.Q * self.m_q

    @property
    def n_p(self) -> int:
        return self.cols.shape[0] // self.P

    @property
    def k(self) -> int:
        return self.cols.shape[1] // self.Q

    def put(self, arr, spec):
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


def prepare_shard_map_sparse(mesh, X, y, *, data_axis="data",
                             model_axis="model",
                             m_multiple: int | None = None,
                             k_multiple: int = 8) -> SparseShardMapData:
    """Sparse analogue of :func:`prepare_shard_map`.

    ``X`` is a :class:`~repro.data.sparse.CSRMatrix` (or a dense array,
    converted).  Padding matches ``partition_sparse`` bit-for-bit, so a
    shard_map cell sees the same ELL block as the simulated grid's cell.
    """
    from repro.data.sparse import CSRMatrix, csr_from_dense
    from .partition import _ceil_to as ceil_to, _ell_blocks
    if not isinstance(X, CSRMatrix):
        X = csr_from_dense(np.asarray(X))
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    m_pad = ceil_to(m, m_multiple or Qn)
    cols, vals, y_blocks, mask_blocks = _ell_blocks(
        X, y, Pn, Qn, m_pad, k_multiple)
    _, _, n_p, k = cols.shape
    # (P, Q, n_p, k) -> (P*n_p, Q*k): block (p, q) lands at the
    # [p*n_p:(p+1)*n_p, q*k:(q+1)*k] tile, which the (data, model)
    # sharding assigns to device (p, q)
    cols_g = cols.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    vals_g = vals.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return SparseShardMapData(
        mesh=mesh,
        cols=put(jnp.asarray(cols_g), P(daxes, model_axis)),
        vals=put(jnp.asarray(vals_g), P(daxes, model_axis)),
        y=put(jnp.asarray(y_blocks.reshape(-1)), P(daxes)),
        mask=put(jnp.asarray(mask_blocks.reshape(-1)), P(daxes)),
        n=n, m=m, m_q=m_pad // Qn, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)


def _putter(mesh):
    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh, spec))
    return put


def prepare_shard_map(mesh, X, y, *, data_axis="data", model_axis="model",
                      m_multiple: int | None = None) -> ShardMapData:
    """Pad (X, y) so the mesh divides both axes and place the shards.

    The padding rule is identical to ``partition(..., m_multiple=P*Q)``,
    so a shard_map cell sees the same (n_p, m_q) block as the simulated
    grid's cell (p, q)."""
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    n_pad = _ceil_to(n, Pn)
    m_pad = _ceil_to(m, m_multiple or Qn)
    Xp = np.zeros((n_pad, m_pad), np.float32)
    Xp[:n, :m] = np.asarray(X, np.float32)
    yp = np.zeros((n_pad,), np.float32)
    yp[:n] = np.asarray(y, np.float32)
    maskp = np.zeros((n_pad,), np.float32)
    maskp[:n] = 1.0
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return ShardMapData(
        mesh=mesh,
        x=put(jnp.asarray(Xp), P(daxes, model_axis)),
        y=put(jnp.asarray(yp), P(daxes)),
        mask=put(jnp.asarray(maskp), P(daxes)),
        n=n, m=m, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)
