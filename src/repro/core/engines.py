"""Engine executors for the unified solver framework (``repro.core.solver``).

An *engine* is how the P x Q block grid of the paper is executed.  Since
Engine API v2 each solver contributes ONE :class:`CellProgram` -- its
per-cell step math plus a :class:`~repro.core.comm.CommSchedule`
declaring every cross-cell reduction as a named collective -- and the
engines here execute that single program three ways:

  * ``"simulated"``  -- :func:`grid_program`: the grid is the leading
    axes of blocked arrays and cells run under nested *named* ``vmap``
    on one device; the declared collectives become vmap-axis reductions
    (correctness tests, paper-figure benchmarks);
  * ``"shard_map"``  -- :func:`mesh_program`: a (data=P, model=Q) device
    mesh where each device owns one (n_p, m_q) block in HBM and the
    collectives are mesh reductions, applied synchronously (the
    production path);
  * ``"async"``      -- :func:`mesh_program` with ``staleness=tau``: the
    same mesh execution under a :class:`~repro.core.comm.StaleComm`,
    which applies every declared reduction with bounded staleness tau
    via FIFO buffers carried in the engine state.  ``tau = 0``
    reproduces ``"shard_map"`` exactly (same jaxpr).

Orthogonally to the engine choice, a
:class:`~repro.core.compress.CompressionPolicy` (``compression=``)
routes every declared collective's payload through a codec with error
feedback (:class:`~repro.core.compress.CompressedComm` wraps the
sync/stale executor), and every binding reports exact bytes-on-wire
via :func:`comm_accounting` (``EngineProgram.comm_bytes``).

The executors produce an :class:`EngineProgram` -- initial state, jitted
outer step, extractors for the global primal (and dual) iterates.
Everything else (the outer loop, history, early stopping, warm starts)
lives once in the shared driver.

All engines pad the feature dimension to a multiple of P*Q (columns of
zeros are inert under every update rule), so a cell sees bit-identical
blocks regardless of engine and the executions agree to float
tolerance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .comm import (CommSchedule, LocalComm, OverlapComm, ShapeProbeComm,
                   StaleComm, SyncComm, hier_ef_names)
from .comm_model import hierarchical_accounting
from .compress import CompressedComm, get_codec, wire_accounting
from .partition import _ceil_to
from .util import as_axes, axes_size, pvary, shard_map


@dataclasses.dataclass
class EngineProgram:
    """One algorithm bound to one engine: state + step + extractors.

    The uniform handle ``Solver.program`` returns and ``drive`` runs.

    Attributes:
      state: the initial engine-state pytree (blocked iterates plus any
        communication state -- staleness rings, EF residuals).
      step: jitted ``(t, state) -> state`` advancing one outer
        iteration; ``t`` is the 1-based iteration counter.
      w_of: ``state -> (m,)`` -- the assembled global primal iterate
        (trimmed of any grid padding).
      alpha_of: ``state -> (n,)`` global dual, or None for primal-only
        solvers.

    The remaining fields are engine metadata the driver and telemetry
    key off (documented inline below).
    """

    state: Any
    step: Callable[[int, Any], Any]
    w_of: Callable[[Any], jnp.ndarray]
    alpha_of: Optional[Callable[[Any], jnp.ndarray]] = None
    #: exact per-step wire accounting of the program's declared
    #: collectives (see ``repro.core.compress.wire_accounting``); None
    #: for programs built outside the generic executors
    comm_bytes: Optional[dict] = None
    #: same cell program with every collective executed cell-locally
    #: (:class:`~repro.core.comm.LocalComm`); jitted lazily, so it costs
    #: nothing unless phase attribution times it.  Numerically wrong by
    #: design -- timing only (see ``repro.obs.phases``)
    local_step: Optional[Callable[[int, Any], Any]] = None
    #: state -> {collective: error-feedback residual array} when the
    #: compression policy carries stateful codecs (telemetry reads the
    #: per-iteration EF norms off it); None otherwise
    ef_of: Optional[Callable[[Any], dict]] = None
    #: consumption delay tau the program was built with (0 = sync)
    staleness: int = 0
    #: True for the overlap engine: reductions are dispatched into
    #: double-buffered ring slots and awaited tau steps later, so the
    #: driver must not block on in-flight comm state between steps
    overlap: bool = False
    #: state -> the substate that must be device-complete at an
    #: observation point (the iterate substate, EXCLUDING in-flight
    #: reduction slots).  None means block on the whole state -- the
    #: overlap engine sets this so ``drive`` keeps the dispatch window
    #: open on the host path
    sync_of: Optional[Callable[[Any], Any]] = None
    #: True when ``step`` donates its state argument (overlap engine on
    #: non-CPU backends): callers that re-step from a saved state must
    #: copy it first (see ``repro.obs.phases.calibrate_phases``)
    donated: bool = False


def drive(prog: EngineProgram, outer_iters: int, observe=None, *,
          tracer=None, on_step=None, monitor=None):
    """Run the outer loop.  ``observe(t, state) -> bool`` is called after
    every step; returning True stops early.  Returns
    (final state, iterations run, stopped_early).

    Telemetry (all optional, default off -- the untimed loop is
    bit-identical to the pre-telemetry driver and adds no syncs):

      * ``tracer`` -- a :class:`repro.obs.trace.Tracer`; each iteration
        becomes an ``outer_iter`` span with ``step`` / ``observe``
        children, and the step blocks on its device result so the span
        measures real device wall-clock;
      * ``on_step(t, t_begin, step_s)`` -- fires after every timed step
        (the solver driver uses it to synthesize per-collective
        attribution spans and feed per-iter phase fields into history);
      * ``monitor`` -- a :class:`repro.obs.health.HealthMonitor`; its
        rate-limited ``poll()`` runs once per iteration (a clock read
        when not due -- health rules only *read* the registry, so the
        iterates are untouched).
    """
    tracing = tracer is not None and getattr(tracer, "enabled", False)
    state = prog.state
    done = 0
    # The overlap engine's contract: never block on in-flight reduction
    # slots between steps -- only the iterate substate is synced, so a
    # dispatched collective stays a future until the slot is read tau
    # steps later.  sync_of is None for every other engine (block on
    # the whole state, the pre-overlap behavior).
    sync = prog.sync_of if prog.sync_of is not None else (lambda s: s)
    if not tracing and on_step is None:
        for t in range(1, outer_iters + 1):
            state = prog.step(t, state)
            done = t
            if monitor is not None:
                monitor.poll()
            if observe is not None and observe(t, state):
                return state, done, True
        return state, done, False

    if tracing:
        tr, clock = tracer, tracer.clock
    else:
        from repro.obs.trace import NULL_TRACER
        tr, clock = NULL_TRACER, time.perf_counter
    for t in range(1, outer_iters + 1):
        with tr.span("outer_iter", iter=t):
            with tr.span("step", iter=t):
                # t0 taken INSIDE the span so the attribution spans
                # on_step synthesizes at t0 nest within it
                t0 = clock()
                state = prog.step(t, state)
                jax.block_until_ready(sync(state))
                step_s = clock() - t0
            if on_step is not None:
                on_step(t, t0, step_s)
            done = t
            if monitor is not None:
                monitor.poll()
            if observe is not None:
                with tr.span("observe", iter=t):
                    stop = observe(t, state)
                if stop:
                    return state, done, True
    return state, done, False


def drive_with_callback(prog: EngineProgram, outer_iters: int, callback=None,
                        pass_alpha: bool = False):
    """Driver for the legacy ``*_simulated`` / ``*_distributed`` wrappers:
    relay each iterate to ``callback(t, w[, alpha])``, ignoring its return
    value (legacy callbacks never early-stop).  Returns the final state."""
    observe = None
    if callback is not None:
        def observe(t, state):
            if pass_alpha:
                callback(t, prog.w_of(state), prog.alpha_of(state))
            else:
                callback(t, prog.w_of(state))
            return False
    state, _, _ = drive(prog, outer_iters, observe)
    return state


# ---------------------------------------------------------------------------
# shard_map data preparation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardMapData:
    """Padded global arrays placed on a (data=P, model=Q) mesh."""

    mesh: Any
    x: jnp.ndarray          # (n_pad, m_pad)  sharded (data, model)
    y: jnp.ndarray          # (n_pad,)        sharded (data,)
    mask: jnp.ndarray       # (n_pad,)        sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.x.shape[0]

    @property
    def m_pad(self) -> int:
        return self.x.shape[1]

    @property
    def n_p(self) -> int:
        return self.x.shape[0] // self.P

    @property
    def m_q(self) -> int:
        return self.x.shape[1] // self.Q

    def put(self, arr, spec):
        """device_put onto this mesh with the given PartitionSpec."""
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


@dataclasses.dataclass(frozen=True)
class SparseShardMapData:
    """Padded-ELL global arrays placed on a (data=P, model=Q) mesh.

    The (n_pad, Q*k) ``cols``/``vals`` arrays are sharded
    (data, model): device (p, q) holds exactly the (n_p, k) ELL cell of
    block (p, q), with block-LOCAL column ids in [0, m_q).  Device
    memory for the data block is O(n_p * k) ~ O(nnz), not O(n_p * m_q).
    """

    mesh: Any
    cols: jnp.ndarray       # (n_pad, Q*k) int32  sharded (data, model)
    vals: jnp.ndarray       # (n_pad, Q*k) f32    sharded (data, model)
    y: jnp.ndarray          # (n_pad,)            sharded (data,)
    mask: jnp.ndarray       # (n_pad,)            sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    m_q: int                # padded feature-block width (m_pad = Q * m_q)
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.cols.shape[0]

    @property
    def m_pad(self) -> int:
        return self.Q * self.m_q

    @property
    def n_p(self) -> int:
        return self.cols.shape[0] // self.P

    @property
    def k(self) -> int:
        return self.cols.shape[1] // self.Q

    def put(self, arr, spec):
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


def prepare_shard_map_sparse(mesh, X, y, *, data_axis="data",
                             model_axis="model",
                             m_multiple: int | None = None,
                             k_multiple: int = 8) -> SparseShardMapData:
    """Sparse analogue of :func:`prepare_shard_map`.

    ``X`` is a :class:`~repro.data.sparse.CSRMatrix` (or a dense array,
    converted).  Padding matches ``partition_sparse`` bit-for-bit, so a
    shard_map cell sees the same ELL block as the simulated grid's cell.
    """
    from repro.data.sparse import CSRMatrix, csr_from_dense
    from .partition import _ceil_to as ceil_to, _ell_blocks
    if not isinstance(X, CSRMatrix):
        X = csr_from_dense(np.asarray(X))
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    m_pad = ceil_to(m, m_multiple or Qn)
    cols, vals, y_blocks, mask_blocks = _ell_blocks(
        X, y, Pn, Qn, m_pad, k_multiple)
    _, _, n_p, k = cols.shape
    # (P, Q, n_p, k) -> (P*n_p, Q*k): block (p, q) lands at the
    # [p*n_p:(p+1)*n_p, q*k:(q+1)*k] tile, which the (data, model)
    # sharding assigns to device (p, q)
    cols_g = cols.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    vals_g = vals.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return SparseShardMapData(
        mesh=mesh,
        cols=put(jnp.asarray(cols_g), P(daxes, model_axis)),
        vals=put(jnp.asarray(vals_g), P(daxes, model_axis)),
        y=put(jnp.asarray(y_blocks.reshape(-1)), P(daxes)),
        mask=put(jnp.asarray(mask_blocks.reshape(-1)), P(daxes)),
        n=n, m=m, m_q=m_pad // Qn, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)


def _putter(mesh):
    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh, spec))
    return put


# ---------------------------------------------------------------------------
# Engine API v2: one CellProgram per solver, executed by generic engines
# ---------------------------------------------------------------------------

#: a *dim-spec* annotates one operand: a tuple over its leading array
#: dims naming the logical grid axis each dim is split over ("data",
#: "model", or None for unsplit dims); trailing dims are unsplit.  The
#: same spec drives the shard_map PartitionSpec, the grid engine's vmap
#: in_axes, and which axes an input must be pvary-promoted over.
DimSpec = Tuple[Optional[str], ...]


def _is_dimspec(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def _spec_leaves(specs):
    return jax.tree_util.tree_leaves(specs, is_leaf=_is_dimspec)


@dataclasses.dataclass(frozen=True)
class CellProgram:
    """One solver's per-cell step math plus its communication contract.

    ``cell(comm, t, data, state) -> state`` operates on PER-CELL arrays
    (the (n_p, m_q) block a device owns) and performs every cross-cell
    reduction through the :class:`~repro.core.comm.Comm` it is handed --
    never via inline ``lax.psum``.  ``data_specs`` / ``state_specs`` are
    pytrees matching ``data`` / ``state`` whose leaves are dim-specs
    (see :data:`DimSpec`).  One CellProgram serves every engine.
    """

    schedule: CommSchedule
    cell: Callable[..., Any]
    data_specs: Any
    state_specs: Any


# -- grid engine (named vmap on one device) ---------------------------------

_GRID_DATA, _GRID_MODEL = "grid_data", "grid_model"
_GRID_POD = "grid_pod"

#: grid-engine error-feedback dict key prefix for the cross-pod
#: (topology) codec residuals -- keeps them distinct from a
#: CompressionPolicy residual on the same collective name inside the
#: single blocked ``ef`` operand
_POD_EF = "pod:"


def _norm_topology(topology):
    """None | spec | Topology -> Topology with pods > 1, else None."""
    if topology is None:
        return None
    from .comm_model import Topology
    topo = Topology.from_spec(topology)
    if topo.pods <= 1:
        return None
    if topo.axis != "data":
        raise ValueError(f"topology splits axis {topo.axis!r}; the engines "
                         "only pod-split the 'data' axis")
    return topo


def _split_pods(tree, specs, G):
    """Blocked layout -> pod-split blocked layout: every leaf whose
    dim-spec names 'data' splits its leading P block axis into
    (G, P // G).  Pods are contiguous index ranges, matching the
    mesh engines' ("pod", "data") axis order and ``axes_index``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for leaf, ds in zip(leaves, _spec_leaves(specs)):
        if "data" in ds:
            leaf = leaf.reshape((G, leaf.shape[0] // G) + leaf.shape[1:])
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _merge_pods(tree):
    """Collapse the (G, P // G) leading axes every vmap output carries
    back into one P axis (all out leaves are stacked over all levels)."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.reshape((-1,) + leaf.shape[2:]), tree)


def _drop_replicas(out, state_specs):
    """Collectives replicate results along the reduced axis exactly
    (every cell sees the same psum), so dropping replicas is exact."""
    leaves, treedef = jax.tree_util.tree_flatten(out)
    spec_leaves = _spec_leaves(state_specs)
    kept = []
    for leaf, ds in zip(leaves, spec_leaves):
        if "data" not in ds:
            leaf = leaf[0]
            if "model" not in ds:
                leaf = leaf[0]
        elif "model" not in ds:
            leaf = leaf[:, 0]
        kept.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, kept)


def cached_build(cache, key, build):
    """Memoize ``build()`` under ``key`` in ``cache`` (a plain dict owned
    by the caller); ``cache=None`` just calls ``build()``.

    The program builders use this to reuse their jitted step callables
    across repeated builds with constant shapes (the online update loop,
    the fleet's sequential baseline): a reused ``jax.jit`` object hits
    the compiled-executable cache instead of re-tracing from scratch.
    """
    if cache is None:
        return build()
    if key not in cache:
        cache[key] = build()
    return cache[key]


def grid_program(cellprog: CellProgram, Pn: int, Qn: int, *,
                 compression=None, comm_local: bool = False,
                 topology=None):
    """Named-``vmap`` executor: the P x Q grid is the leading block axes
    of the operands and the declared collectives run as vmap-axis
    reductions.  Returns a jitted ``step(t, data, state) -> state``
    where ``data``/``state`` are BLOCKED pytrees: each leaf carries one
    leading block axis per logical axis in its dim-spec, in
    (data, model) order, with the per-cell extent left in place (so a
    cell sees exactly the array a shard_map device would own).

    With ``compression`` (a validated
    :class:`~repro.core.compress.CompressionPolicy`) the step signature
    becomes ``step(t, data, (state, ef)) -> (state, ef)``: every
    collective payload runs through its codec under a
    :class:`~repro.core.compress.CompressedComm`, and ``ef`` maps each
    compressed collective to its (P, Q, *payload) error-feedback
    residuals (allocate with :func:`grid_comm_state`).  ``None`` builds
    the exact uncompressed program.

    ``comm_local=True`` substitutes :class:`~repro.core.comm.LocalComm`
    for the sync executor: every collective runs cell-locally, same
    avals, zero reduction work.  Timing-only (``EngineProgram.
    local_step``); incompatible with ``compression`` (a local program's
    wire cost is zero by construction).

    ``topology`` (a :class:`~repro.core.comm_model.Topology` or spec
    string with ``pods > 1``) pod-splits the data axis as a THIRD named
    vmap level, so psums over "data" execute hierarchically (intra-pod
    full precision, cross-pod through the topology codec).  The step
    then always takes the ``(state, ef)`` full state (cross-pod EF
    residuals ride in ``ef`` under ``"pod:"``-prefixed keys) and the
    blocked operand layout is unchanged -- pods are contiguous P-index
    ranges reshaped inside the step.
    """
    topo = _norm_topology(topology)
    if comm_local:
        topo = None            # the local twin runs no reductions at all
    axis_map = {"data": (_GRID_DATA,), "model": (_GRID_MODEL,)}
    G = 1
    if topo is not None:
        G = topo.pods
        if Pn % G:
            raise ValueError(f"topology pods={G} does not divide P={Pn}")
        axis_map = {"data": (_GRID_POD, _GRID_DATA),
                    "model": (_GRID_MODEL,)}
    sizes = {"data": Pn, "model": Qn}
    sched = cellprog.schedule
    policy = compression
    if comm_local and policy is not None:
        raise ValueError("comm_local measures the collective-free step; "
                         "it cannot compose with a compression policy")
    if policy is not None:
        policy.validate(sched)
    comm_cls = LocalComm if comm_local else SyncComm
    hier_codec = get_codec(topo.codec) if topo is not None else None

    def in_axes(specs, axis):
        return jax.tree_util.tree_map(
            lambda ds: 0 if axis in ds else None, specs,
            is_leaf=_is_dimspec)

    if policy is None and topo is None:
        def one_cell(t, d, s):
            comm = comm_cls(sched, axis_map, sizes)
            out = cellprog.cell(comm, t, d, s)
            comm.finalize()
            return out

        inner = jax.vmap(one_cell,
                         in_axes=(None, in_axes(cellprog.data_specs, "model"),
                                  in_axes(cellprog.state_specs, "model")),
                         axis_name=_GRID_MODEL)
        outer = jax.vmap(inner,
                         in_axes=(None, in_axes(cellprog.data_specs, "data"),
                                  in_axes(cellprog.state_specs, "data")),
                         axis_name=_GRID_DATA)

        def step(t, data, state):
            out = outer(t, data, state)     # every leaf gains (P, Q) leading
            return _drop_replicas(out, cellprog.state_specs)

        return jax.jit(step)

    def one_cell_c(t, d, s, ef):
        inner = SyncComm(sched, axis_map, sizes)
        if topo is not None:
            inner.set_topology(
                topo, hier_codec,
                ef={k[len(_POD_EF):]: v for k, v in ef.items()
                    if k.startswith(_POD_EF)})
        if policy is not None:
            comm = CompressedComm(
                inner, policy,
                ef={k: v for k, v in ef.items()
                    if not k.startswith(_POD_EF)})
        else:
            comm = inner
        out = cellprog.cell(comm, t, d, s)
        comm.finalize()
        ef_out = dict(comm.ef_out) if policy is not None else {}
        if topo is not None:
            ef_out.update({_POD_EF + k: v
                           for k, v in inner.hier_ef_out.items()})
        return out, ef_out

    # EF residuals are private per cell: blocked over every grid axis
    vm = jax.vmap(one_cell_c,
                  in_axes=(None, in_axes(cellprog.data_specs, "model"),
                           in_axes(cellprog.state_specs, "model"), 0),
                  axis_name=_GRID_MODEL)
    vm = jax.vmap(vm,
                  in_axes=(None, in_axes(cellprog.data_specs, "data"),
                           in_axes(cellprog.state_specs, "data"), 0),
                  axis_name=_GRID_DATA)
    if topo is not None:
        vm = jax.vmap(vm,
                      in_axes=(None, in_axes(cellprog.data_specs, "data"),
                               in_axes(cellprog.state_specs, "data"), 0),
                      axis_name=_GRID_POD)

    def step_c(t, data, full_state):
        state, ef = full_state
        if G > 1:
            data = _split_pods(data, cellprog.data_specs, G)
            state = _split_pods(state, cellprog.state_specs, G)
            ef = {k: v.reshape((G, v.shape[0] // G) + v.shape[1:])
                  for k, v in ef.items()}
        out, ef_out = vm(t, data, state, ef)
        if G > 1:
            out = _merge_pods(out)
            ef_out = _merge_pods(ef_out)
        return _drop_replicas(out, cellprog.state_specs), ef_out

    return jax.jit(step_c)


# -- mesh engines (shard_map; sync and bounded-staleness) -------------------

def _mesh_pspec(ds: DimSpec, daxes, model_axis):
    entries = []
    for a in ds:
        if a == "data":
            entries.append(daxes if len(daxes) > 1 else daxes[0])
        elif a == "model":
            entries.append(model_axis)
        else:
            entries.append(None)
    return P(*entries)


def _pvary_missing(tree_vals, specs, axis_map):
    """Promote operands to fully varying over the mesh axes their
    dim-spec does not split them over (replicated inputs must be
    promoted before mixing with varying values on recent JAX)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_vals)
    out = []
    for v, ds in zip(leaves, _spec_leaves(specs)):
        missing = ()
        if "data" not in ds:
            missing += axis_map["data"]
        if "model" not in ds:
            missing += axis_map["model"]
        out.append(pvary(v, missing))
    return jax.tree_util.tree_unflatten(treedef, out)


def mesh_step_fn(cellprog: CellProgram, mesh, *, data_axis="data",
                 model_axis: str = "model", staleness: int = 0,
                 compression=None, comm_local: bool = False,
                 overlap: bool = False, topology=None):
    """Raw (unjitted) mesh executor.

    Returns ``step(t, data, state, cbufs) -> (state, cbufs)`` running
    the cell once per device of the (data=P, model=Q) mesh under
    shard_map.  ``cbufs`` is the communication-state pytree -- ``{}``
    when no policy needs state, otherwise up to three sub-dicts of
    per-cell buffers sharded over (data, model):

      * ``cbufs["stale"]`` (``staleness = tau > 0``): one
        ``(P, Q, tau, *cell_result_shape)`` FIFO ring per collective
        (:class:`StaleComm`, or :class:`OverlapComm` when
        ``overlap=True`` -- same numerics, but the ring slots double as
        the in-flight reduction buffers the engine donates; tau = 0
        applies every reduction synchronously via :class:`SyncComm`);
      * ``cbufs["ef"]`` (``compression`` with lossy codecs): one
        ``(P, Q, *payload_shape)`` f32 error-feedback residual per
        compressed collective (:class:`CompressedComm` wrapping the
        sync/stale executor, so compression composes with staleness);
      * ``cbufs["hier_ef"]`` (``topology`` with pods > 1 and a stateful
        cross-pod codec): one ``(P, Q, *payload_shape)`` f32 residual
        per pod-split collective for the hierarchical two-level
        reduction.  ``data_axis`` must then be a >= 2 axis tuple with
        the pod axis leading (e.g. ``("pod", "data")``).
    """
    topo = _norm_topology(topology)
    if comm_local:
        topo = None            # the local twin runs no reductions at all
    daxes = as_axes(data_axis)
    axis_map = {"data": daxes, "model": (model_axis,)}
    sizes = {"data": axes_size(mesh, data_axis),
             "model": axes_size(mesh, model_axis)}
    sched = cellprog.schedule
    policy = compression
    if comm_local and (staleness or policy is not None):
        raise ValueError("comm_local measures the collective-free step; "
                         "it cannot compose with staleness or compression")
    if policy is not None:
        policy.validate(sched)
    ef_names = policy.stateful_names(sched) if policy is not None else ()
    if topo is not None:
        if len(daxes) < 2:
            raise ValueError(
                f"topology pods={topo.pods} needs a pod-split mesh: pass "
                f"data_axis as a >= 2 axis tuple, got {data_axis!r}")
        if axes_size(mesh, daxes[:1]) != topo.pods:
            raise ValueError(
                f"mesh pod axis {daxes[0]!r} has extent "
                f"{axes_size(mesh, daxes[:1])}, topology says "
                f"pods={topo.pods}")
    hier_codec = get_codec(topo.codec) if topo is not None else None
    hnames = hier_ef_names(sched, topo)
    dspec = daxes if len(daxes) > 1 else daxes[0]

    def pspecs(specs):
        return jax.tree_util.tree_map(
            lambda ds: _mesh_pspec(ds, daxes, model_axis), specs,
            is_leaf=_is_dimspec)

    data_pspecs = pspecs(cellprog.data_specs)
    state_pspecs = pspecs(cellprog.state_specs)
    buf_pspecs = {}
    if staleness:
        buf_pspecs["stale"] = {name: P(dspec, model_axis)
                               for name in sched.names}
    if ef_names:
        buf_pspecs["ef"] = {name: P(dspec, model_axis) for name in ef_names}
    if hnames:
        buf_pspecs["hier_ef"] = {name: P(dspec, model_axis)
                                 for name in hnames}

    def kernel(t, data, state, cbufs):
        data = _pvary_missing(data, cellprog.data_specs, axis_map)
        state = _pvary_missing(state, cellprog.state_specs, axis_map)
        t = pvary(t, daxes + (model_axis,))
        if staleness:
            stale_cls = OverlapComm if overlap else StaleComm
            inner = stale_cls(sched, axis_map, sizes, tau=staleness, t=t,
                              bufs={k: b[0, 0]
                                    for k, b in cbufs["stale"].items()})
        else:
            inner = (LocalComm if comm_local else SyncComm)(
                sched, axis_map, sizes)
        if topo is not None:
            inner.set_topology(topo, hier_codec,
                               ef={k: b[0, 0]
                                   for k, b in cbufs.get("hier_ef",
                                                         {}).items()})
        if policy is not None:
            comm = CompressedComm(inner, policy,
                                  ef={k: b[0, 0]
                                      for k, b in cbufs.get("ef",
                                                            {}).items()})
        else:
            comm = inner
        out = cellprog.cell(comm, t, data, state)
        comm.finalize()
        cb_out = {}
        if staleness:
            cb_out["stale"] = {k: b[None, None]
                               for k, b in comm.bufs_out.items()}
        if ef_names:
            cb_out["ef"] = {k: e[None, None]
                            for k, e in comm.ef_out.items()}
        if hnames:
            cb_out["hier_ef"] = {k: e[None, None]
                                 for k, e in inner.hier_ef_out.items()}
        return out, cb_out

    return shard_map(
        kernel, mesh,
        in_specs=(P(), data_pspecs, state_pspecs, buf_pspecs),
        out_specs=(state_pspecs, buf_pspecs))


def probe_collective_shapes(cellprog: CellProgram, data, state, *,
                            sizes, layout: str = "global"):
    """Per-cell avals of every declared collective, via one
    ``eval_shape`` trace of the cell under a ShapeProbeComm (no mesh or
    devices needed).  Returns ``(results, payloads)``: the *result* aval
    sizes the async engine's staleness rings; the *payload* aval (the
    value the cell hands to ``comm``, i.e. what travels the wire) sizes
    error-feedback residuals and the wire accounting.

    ``layout`` names how ``data``/``state`` leaves relate to one cell's
    array: ``"global"`` (mesh layout -- each dim named in the dim-spec
    is divided by its grid extent) or ``"blocked"`` (grid-engine layout
    -- one extra leading block axis per named dim, dropped).
    """
    if layout not in ("global", "blocked"):
        raise ValueError(f"layout={layout!r}; expected 'global' or "
                         "'blocked'")

    def cell_aval(arr, ds):
        arr = jnp.asarray(arr) if not hasattr(arr, "shape") else arr
        if layout == "blocked":
            k = sum(1 for a in ds if a)
            return jax.ShapeDtypeStruct(tuple(arr.shape[k:]), arr.dtype)
        shape = list(arr.shape)
        for i, a in enumerate(ds):
            if a:
                shape[i] //= sizes[a]
        return jax.ShapeDtypeStruct(tuple(shape), arr.dtype)

    def avals(tree_vals, specs):
        leaves, treedef = jax.tree_util.tree_flatten(tree_vals)
        out = [cell_aval(v, ds)
               for v, ds in zip(leaves, _spec_leaves(specs))]
        return jax.tree_util.tree_unflatten(treedef, out)

    record: dict = {}
    payloads: dict = {}
    probe = ShapeProbeComm(cellprog.schedule,
                           {"data": ("data",), "model": ("model",)}, sizes,
                           record, payloads)

    def run(t, d, s):
        out = cellprog.cell(probe, t, d, s)
        probe.finalize()
        return out

    jax.eval_shape(run, jax.ShapeDtypeStruct((), jnp.int32),
                   avals(data, cellprog.data_specs),
                   avals(state, cellprog.state_specs))
    return record, payloads


def comm_accounting(cellprog: CellProgram, data, state, *, sizes,
                    layout: str = "global", compression=None) -> dict:
    """Exact per-step bytes-on-wire of a CellProgram's schedule under a
    compression policy (None = uncompressed), for
    ``EngineProgram.comm_bytes``.  One eval_shape probe, no devices."""
    _, payloads = probe_collective_shapes(cellprog, data, state,
                                          sizes=sizes, layout=layout)
    return wire_accounting(cellprog.schedule, payloads, sizes, compression)


def grid_bind_state(cellprog: CellProgram, data, state0, *, Pn: int, Qn: int,
                    compression=None, topology=None):
    """Engine-state plumbing shared by the grid-engine program builders.

    One build-time probe yields both the wire accounting and (when the
    policy carries error feedback) the zero EF residuals -- one
    ``(P, Q, *payload_shape)`` f32 buffer per stateful-codec collective,
    blocked layout, matching :func:`grid_program`'s ``ef`` operand.
    With a hierarchical ``topology`` the cross-pod codec's residuals
    join the same dict under ``"pod:"``-prefixed keys (sized by the
    payload aval: the intra-pod partial sum a cross-pod residual tracks
    has the per-cell payload shape) and the accounting is rewritten
    into intra/inter tiers.  Returns ``(full_state0, unwrap, acct)``
    where ``unwrap`` recovers the solver state from the full engine
    state (identity when no comm state is carried, so the uncompressed
    flat state layout is untouched)."""
    topo = _norm_topology(topology)
    sizes = {"data": Pn, "model": Qn}
    _, payloads = probe_collective_shapes(cellprog, data, state0,
                                          sizes=sizes, layout="blocked")
    acct = wire_accounting(cellprog.schedule, payloads, sizes, compression)
    acct = hierarchical_accounting(acct, topo, sizes)
    if compression is None and topo is None:
        return state0, (lambda s: s), acct
    ef0 = {}
    if compression is not None:
        ef0.update({
            name: jnp.zeros((Pn, Qn) + payloads[name].shape, jnp.float32)
            for name in compression.stateful_names(cellprog.schedule)})
    for name in hier_ef_names(cellprog.schedule, topo):
        ef0[_POD_EF + name] = jnp.zeros((Pn, Qn) + payloads[name].shape,
                                        jnp.float32)
    return (state0, ef0), (lambda s: s[0]), acct


def mesh_program(cellprog: CellProgram, mesh, data, state0, *,
                 data_axis="data", model_axis: str = "model",
                 staleness: int = 0, compression=None,
                 overlap: bool = False, topology=None):
    """Bind a CellProgram to a mesh: returns ``(step, comm0, acct)``
    where ``step(t, data, (state, comm_state))`` is jitted, ``comm0``
    holds the zero-initialized communication state (staleness rings
    under ``"stale"``, error-feedback residuals under ``"ef"``,
    cross-pod residuals under ``"hier_ef"``; ``{}`` when
    ``staleness == 0`` and no stateful codec runs, in which case the
    jaxpr is exactly the sync engine's), and ``acct`` is the program's
    exact per-step wire accounting (:func:`comm_accounting`, rewritten
    into intra/inter-pod tiers under a hierarchical ``topology``).

    ``overlap=True`` (the overlap engine) runs the cells under
    :class:`~repro.core.comm.OverlapComm` and **donates the full state**
    to the jitted step on accelerator backends, so the staleness rings
    are double-buffered reduction slots XLA can keep in flight across
    steps instead of defensively copying.  Donation is skipped on CPU
    (where it is a no-op) to keep host-side re-stepping from saved
    states -- e.g. phase calibration -- unrestricted there; callers can
    check ``EngineProgram.donated``."""
    topo = _norm_topology(topology)
    daxes = as_axes(data_axis)
    sizes = {"data": axes_size(mesh, data_axis),
             "model": axes_size(mesh, model_axis)}
    policy = compression
    raw = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis, staleness=staleness,
                       compression=policy, overlap=overlap, topology=topo)
    results, payloads = probe_collective_shapes(cellprog, data, state0,
                                                sizes=sizes)
    acct = wire_accounting(cellprog.schedule, payloads, sizes, policy)
    acct = hierarchical_accounting(acct, topo, sizes)
    comm0 = {}
    dspec = daxes if len(daxes) > 1 else daxes[0]
    put = _putter(mesh)
    if staleness > 0:
        comm0["stale"] = {}
        for name, aval in results.items():
            shape = (sizes["data"], sizes["model"], staleness) + aval.shape
            comm0["stale"][name] = put(jnp.zeros(shape, aval.dtype),
                                       P(dspec, model_axis))
    ef_names = policy.stateful_names(cellprog.schedule) \
        if policy is not None else ()
    if ef_names:
        comm0["ef"] = {
            name: put(jnp.zeros((sizes["data"], sizes["model"])
                                + payloads[name].shape, jnp.float32),
                      P(dspec, model_axis))
            for name in ef_names}
    hnames = hier_ef_names(cellprog.schedule, topo)
    if hnames:
        comm0["hier_ef"] = {
            name: put(jnp.zeros((sizes["data"], sizes["model"])
                                + payloads[name].shape, jnp.float32),
                      P(dspec, model_axis))
            for name in hnames}

    def step_fn(t, data, full_state):
        state, cbufs = full_state
        return raw(t, data, state, cbufs)

    donate = bool(overlap) and staleness > 0 and overlap_donates()
    step = jax.jit(step_fn, donate_argnums=(2,)) if donate \
        else jax.jit(step_fn)
    return step, comm0, acct


def overlap_donates() -> bool:
    """Whether the overlap engine donates its state to the jitted step
    on this backend (donation is a no-op on CPU, and skipping it there
    keeps host-side re-stepping from saved states unrestricted)."""
    return jax.default_backend() != "cpu"


def mesh_local_step(cellprog: CellProgram, mesh, *, data_axis="data",
                    model_axis: str = "model"):
    """Jitted collective-free twin of a mesh program's step, for the
    differential phase attribution of :mod:`repro.obs.phases`:
    ``local(t, data, state) -> state`` runs the same shard_map cell with
    every declared reduction executed cell-locally
    (:class:`~repro.core.comm.LocalComm`).  Numerically wrong on
    purpose; only ever timed, never consumed."""
    raw = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis, comm_local=True)

    @jax.jit
    def local(t, data, state):
        out, _ = raw(t, data, state, {})
        return out

    return local


def prepare_shard_map(mesh, X, y, *, data_axis="data", model_axis="model",
                      m_multiple: int | None = None) -> ShardMapData:
    """Pad (X, y) so the mesh divides both axes and place the shards.

    The padding rule is identical to ``partition(..., m_multiple=P*Q)``,
    so a shard_map cell sees the same (n_p, m_q) block as the simulated
    grid's cell (p, q)."""
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    n_pad = _ceil_to(n, Pn)
    m_pad = _ceil_to(m, m_multiple or Qn)
    Xp = np.zeros((n_pad, m_pad), np.float32)
    Xp[:n, :m] = np.asarray(X, np.float32)
    yp = np.zeros((n_pad,), np.float32)
    yp[:n] = np.asarray(y, np.float32)
    maskp = np.zeros((n_pad,), np.float32)
    maskp[:n] = 1.0
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return ShardMapData(
        mesh=mesh,
        x=put(jnp.asarray(Xp), P(daxes, model_axis)),
        y=put(jnp.asarray(yp), P(daxes)),
        mask=put(jnp.asarray(maskp), P(daxes)),
        n=n, m=m, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)
