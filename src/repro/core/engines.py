"""Engine executors for the unified solver framework (``repro.core.solver``).

An *engine* is how the P x Q block grid of the paper is executed.  Since
Engine API v2 each solver contributes ONE :class:`CellProgram` -- its
per-cell step math plus a :class:`~repro.core.comm.CommSchedule`
declaring every cross-cell reduction as a named collective -- and the
engines here execute that single program three ways:

  * ``"simulated"``  -- :func:`grid_program`: the grid is the leading
    axes of blocked arrays and cells run under nested *named* ``vmap``
    on one device; the declared collectives become vmap-axis reductions
    (correctness tests, paper-figure benchmarks);
  * ``"shard_map"``  -- :func:`mesh_program`: a (data=P, model=Q) device
    mesh where each device owns one (n_p, m_q) block in HBM and the
    collectives are mesh reductions, applied synchronously (the
    production path);
  * ``"async"``      -- :func:`mesh_program` with ``staleness=tau``: the
    same mesh execution under a :class:`~repro.core.comm.StaleComm`,
    which applies every declared reduction with bounded staleness tau
    via FIFO buffers carried in the engine state.  ``tau = 0``
    reproduces ``"shard_map"`` exactly (same jaxpr).

Orthogonally to the engine choice, a
:class:`~repro.core.compress.CompressionPolicy` (``compression=``)
routes every declared collective's payload through a codec with error
feedback (:class:`~repro.core.compress.CompressedComm` wraps the
sync/stale executor), and every binding reports exact bytes-on-wire
via :func:`comm_accounting` (``EngineProgram.comm_bytes``).

The executors produce an :class:`EngineProgram` -- initial state, jitted
outer step, extractors for the global primal (and dual) iterates.
Everything else (the outer loop, history, early stopping, warm starts)
lives once in the shared driver.

All engines pad the feature dimension to a multiple of P*Q (columns of
zeros are inert under every update rule), so a cell sees bit-identical
blocks regardless of engine and the executions agree to float
tolerance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .comm import (CommSchedule, LocalComm, ShapeProbeComm, StaleComm,
                   SyncComm)
from .compress import CompressedComm, wire_accounting
from .partition import _ceil_to
from .util import as_axes, axes_size, pvary, shard_map


@dataclasses.dataclass
class EngineProgram:
    """One algorithm bound to one engine: state + step + extractors."""

    state: Any                                    # initial state pytree
    step: Callable[[int, Any], Any]               # (t, state) -> state
    w_of: Callable[[Any], jnp.ndarray]            # state -> global w (m,)
    alpha_of: Optional[Callable[[Any], jnp.ndarray]] = None  # -> alpha (n,)
    #: exact per-step wire accounting of the program's declared
    #: collectives (see ``repro.core.compress.wire_accounting``); None
    #: for programs built outside the generic executors
    comm_bytes: Optional[dict] = None
    #: same cell program with every collective executed cell-locally
    #: (:class:`~repro.core.comm.LocalComm`); jitted lazily, so it costs
    #: nothing unless phase attribution times it.  Numerically wrong by
    #: design -- timing only (see ``repro.obs.phases``)
    local_step: Optional[Callable[[int, Any], Any]] = None
    #: state -> {collective: error-feedback residual array} when the
    #: compression policy carries stateful codecs (telemetry reads the
    #: per-iteration EF norms off it); None otherwise
    ef_of: Optional[Callable[[Any], dict]] = None


def drive(prog: EngineProgram, outer_iters: int, observe=None, *,
          tracer=None, on_step=None):
    """Run the outer loop.  ``observe(t, state) -> bool`` is called after
    every step; returning True stops early.  Returns
    (final state, iterations run, stopped_early).

    Telemetry (both optional, default off -- the untimed loop is
    bit-identical to the pre-telemetry driver and adds no syncs):

      * ``tracer`` -- a :class:`repro.obs.trace.Tracer`; each iteration
        becomes an ``outer_iter`` span with ``step`` / ``observe``
        children, and the step blocks on its device result so the span
        measures real device wall-clock;
      * ``on_step(t, t_begin, step_s)`` -- fires after every timed step
        (the solver driver uses it to synthesize per-collective
        attribution spans and feed per-iter phase fields into history).
    """
    tracing = tracer is not None and getattr(tracer, "enabled", False)
    state = prog.state
    done = 0
    if not tracing and on_step is None:
        for t in range(1, outer_iters + 1):
            state = prog.step(t, state)
            done = t
            if observe is not None and observe(t, state):
                return state, done, True
        return state, done, False

    if tracing:
        tr, clock = tracer, tracer.clock
    else:
        from repro.obs.trace import NULL_TRACER
        tr, clock = NULL_TRACER, time.perf_counter
    for t in range(1, outer_iters + 1):
        with tr.span("outer_iter", iter=t):
            with tr.span("step", iter=t):
                # t0 taken INSIDE the span so the attribution spans
                # on_step synthesizes at t0 nest within it
                t0 = clock()
                state = prog.step(t, state)
                jax.block_until_ready(state)
                step_s = clock() - t0
            if on_step is not None:
                on_step(t, t0, step_s)
            done = t
            if observe is not None:
                with tr.span("observe", iter=t):
                    stop = observe(t, state)
                if stop:
                    return state, done, True
    return state, done, False


def drive_with_callback(prog: EngineProgram, outer_iters: int, callback=None,
                        pass_alpha: bool = False):
    """Driver for the legacy ``*_simulated`` / ``*_distributed`` wrappers:
    relay each iterate to ``callback(t, w[, alpha])``, ignoring its return
    value (legacy callbacks never early-stop).  Returns the final state."""
    observe = None
    if callback is not None:
        def observe(t, state):
            if pass_alpha:
                callback(t, prog.w_of(state), prog.alpha_of(state))
            else:
                callback(t, prog.w_of(state))
            return False
    state, _, _ = drive(prog, outer_iters, observe)
    return state


# ---------------------------------------------------------------------------
# shard_map data preparation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardMapData:
    """Padded global arrays placed on a (data=P, model=Q) mesh."""

    mesh: Any
    x: jnp.ndarray          # (n_pad, m_pad)  sharded (data, model)
    y: jnp.ndarray          # (n_pad,)        sharded (data,)
    mask: jnp.ndarray       # (n_pad,)        sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.x.shape[0]

    @property
    def m_pad(self) -> int:
        return self.x.shape[1]

    @property
    def n_p(self) -> int:
        return self.x.shape[0] // self.P

    @property
    def m_q(self) -> int:
        return self.x.shape[1] // self.Q

    def put(self, arr, spec):
        """device_put onto this mesh with the given PartitionSpec."""
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


@dataclasses.dataclass(frozen=True)
class SparseShardMapData:
    """Padded-ELL global arrays placed on a (data=P, model=Q) mesh.

    The (n_pad, Q*k) ``cols``/``vals`` arrays are sharded
    (data, model): device (p, q) holds exactly the (n_p, k) ELL cell of
    block (p, q), with block-LOCAL column ids in [0, m_q).  Device
    memory for the data block is O(n_p * k) ~ O(nnz), not O(n_p * m_q).
    """

    mesh: Any
    cols: jnp.ndarray       # (n_pad, Q*k) int32  sharded (data, model)
    vals: jnp.ndarray       # (n_pad, Q*k) f32    sharded (data, model)
    y: jnp.ndarray          # (n_pad,)            sharded (data,)
    mask: jnp.ndarray       # (n_pad,)            sharded (data,)
    n: int                  # true observation count
    m: int                  # true feature count
    m_q: int                # padded feature-block width (m_pad = Q * m_q)
    P: int
    Q: int
    data_axis: Any = "data"
    model_axis: str = "model"

    @property
    def n_pad(self) -> int:
        return self.cols.shape[0]

    @property
    def m_pad(self) -> int:
        return self.Q * self.m_q

    @property
    def n_p(self) -> int:
        return self.cols.shape[0] // self.P

    @property
    def k(self) -> int:
        return self.cols.shape[1] // self.Q

    def put(self, arr, spec):
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def zeros_data(self):
        return self.put(jnp.zeros((self.n_pad,)), P(self.data_axis))

    def zeros_model(self):
        return self.put(jnp.zeros((self.m_pad,)), P(self.model_axis))

    def pad_w(self, w):
        wp = np.zeros((self.m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return self.put(jnp.asarray(wp), P(self.model_axis))

    def pad_alpha(self, alpha):
        ap = np.zeros((self.n_pad,), np.float32)
        ap[: self.n] = np.asarray(alpha, np.float32)
        return self.put(jnp.asarray(ap), P(self.data_axis))


def prepare_shard_map_sparse(mesh, X, y, *, data_axis="data",
                             model_axis="model",
                             m_multiple: int | None = None,
                             k_multiple: int = 8) -> SparseShardMapData:
    """Sparse analogue of :func:`prepare_shard_map`.

    ``X`` is a :class:`~repro.data.sparse.CSRMatrix` (or a dense array,
    converted).  Padding matches ``partition_sparse`` bit-for-bit, so a
    shard_map cell sees the same ELL block as the simulated grid's cell.
    """
    from repro.data.sparse import CSRMatrix, csr_from_dense
    from .partition import _ceil_to as ceil_to, _ell_blocks
    if not isinstance(X, CSRMatrix):
        X = csr_from_dense(np.asarray(X))
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    m_pad = ceil_to(m, m_multiple or Qn)
    cols, vals, y_blocks, mask_blocks = _ell_blocks(
        X, y, Pn, Qn, m_pad, k_multiple)
    _, _, n_p, k = cols.shape
    # (P, Q, n_p, k) -> (P*n_p, Q*k): block (p, q) lands at the
    # [p*n_p:(p+1)*n_p, q*k:(q+1)*k] tile, which the (data, model)
    # sharding assigns to device (p, q)
    cols_g = cols.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    vals_g = vals.transpose(0, 2, 1, 3).reshape(Pn * n_p, Qn * k)
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return SparseShardMapData(
        mesh=mesh,
        cols=put(jnp.asarray(cols_g), P(daxes, model_axis)),
        vals=put(jnp.asarray(vals_g), P(daxes, model_axis)),
        y=put(jnp.asarray(y_blocks.reshape(-1)), P(daxes)),
        mask=put(jnp.asarray(mask_blocks.reshape(-1)), P(daxes)),
        n=n, m=m, m_q=m_pad // Qn, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)


def _putter(mesh):
    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh, spec))
    return put


# ---------------------------------------------------------------------------
# Engine API v2: one CellProgram per solver, executed by generic engines
# ---------------------------------------------------------------------------

#: a *dim-spec* annotates one operand: a tuple over its leading array
#: dims naming the logical grid axis each dim is split over ("data",
#: "model", or None for unsplit dims); trailing dims are unsplit.  The
#: same spec drives the shard_map PartitionSpec, the grid engine's vmap
#: in_axes, and which axes an input must be pvary-promoted over.
DimSpec = Tuple[Optional[str], ...]


def _is_dimspec(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def _spec_leaves(specs):
    return jax.tree_util.tree_leaves(specs, is_leaf=_is_dimspec)


@dataclasses.dataclass(frozen=True)
class CellProgram:
    """One solver's per-cell step math plus its communication contract.

    ``cell(comm, t, data, state) -> state`` operates on PER-CELL arrays
    (the (n_p, m_q) block a device owns) and performs every cross-cell
    reduction through the :class:`~repro.core.comm.Comm` it is handed --
    never via inline ``lax.psum``.  ``data_specs`` / ``state_specs`` are
    pytrees matching ``data`` / ``state`` whose leaves are dim-specs
    (see :data:`DimSpec`).  One CellProgram serves every engine.
    """

    schedule: CommSchedule
    cell: Callable[..., Any]
    data_specs: Any
    state_specs: Any


# -- grid engine (named vmap on one device) ---------------------------------

_GRID_DATA, _GRID_MODEL = "grid_data", "grid_model"


def _drop_replicas(out, state_specs):
    """Collectives replicate results along the reduced axis exactly
    (every cell sees the same psum), so dropping replicas is exact."""
    leaves, treedef = jax.tree_util.tree_flatten(out)
    spec_leaves = _spec_leaves(state_specs)
    kept = []
    for leaf, ds in zip(leaves, spec_leaves):
        if "data" not in ds:
            leaf = leaf[0]
            if "model" not in ds:
                leaf = leaf[0]
        elif "model" not in ds:
            leaf = leaf[:, 0]
        kept.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, kept)


def grid_program(cellprog: CellProgram, Pn: int, Qn: int, *,
                 compression=None, comm_local: bool = False):
    """Named-``vmap`` executor: the P x Q grid is the leading block axes
    of the operands and the declared collectives run as vmap-axis
    reductions.  Returns a jitted ``step(t, data, state) -> state``
    where ``data``/``state`` are BLOCKED pytrees: each leaf carries one
    leading block axis per logical axis in its dim-spec, in
    (data, model) order, with the per-cell extent left in place (so a
    cell sees exactly the array a shard_map device would own).

    With ``compression`` (a validated
    :class:`~repro.core.compress.CompressionPolicy`) the step signature
    becomes ``step(t, data, (state, ef)) -> (state, ef)``: every
    collective payload runs through its codec under a
    :class:`~repro.core.compress.CompressedComm`, and ``ef`` maps each
    compressed collective to its (P, Q, *payload) error-feedback
    residuals (allocate with :func:`grid_comm_state`).  ``None`` builds
    the exact uncompressed program.

    ``comm_local=True`` substitutes :class:`~repro.core.comm.LocalComm`
    for the sync executor: every collective runs cell-locally, same
    avals, zero reduction work.  Timing-only (``EngineProgram.
    local_step``); incompatible with ``compression`` (a local program's
    wire cost is zero by construction).
    """
    axis_map = {"data": (_GRID_DATA,), "model": (_GRID_MODEL,)}
    sizes = {"data": Pn, "model": Qn}
    sched = cellprog.schedule
    policy = compression
    if comm_local and policy is not None:
        raise ValueError("comm_local measures the collective-free step; "
                         "it cannot compose with a compression policy")
    if policy is not None:
        policy.validate(sched)
    comm_cls = LocalComm if comm_local else SyncComm

    def in_axes(specs, axis):
        return jax.tree_util.tree_map(
            lambda ds: 0 if axis in ds else None, specs,
            is_leaf=_is_dimspec)

    if policy is None:
        def one_cell(t, d, s):
            comm = comm_cls(sched, axis_map, sizes)
            out = cellprog.cell(comm, t, d, s)
            comm.finalize()
            return out

        inner = jax.vmap(one_cell,
                         in_axes=(None, in_axes(cellprog.data_specs, "model"),
                                  in_axes(cellprog.state_specs, "model")),
                         axis_name=_GRID_MODEL)
        outer = jax.vmap(inner,
                         in_axes=(None, in_axes(cellprog.data_specs, "data"),
                                  in_axes(cellprog.state_specs, "data")),
                         axis_name=_GRID_DATA)

        def step(t, data, state):
            out = outer(t, data, state)     # every leaf gains (P, Q) leading
            return _drop_replicas(out, cellprog.state_specs)

        return jax.jit(step)

    def one_cell_c(t, d, s, ef):
        comm = CompressedComm(SyncComm(sched, axis_map, sizes), policy,
                              ef=ef)
        out = cellprog.cell(comm, t, d, s)
        comm.finalize()
        return out, comm.ef_out

    # EF residuals are private per cell: blocked over both grid axes
    inner = jax.vmap(one_cell_c,
                     in_axes=(None, in_axes(cellprog.data_specs, "model"),
                              in_axes(cellprog.state_specs, "model"), 0),
                     axis_name=_GRID_MODEL)
    outer = jax.vmap(inner,
                     in_axes=(None, in_axes(cellprog.data_specs, "data"),
                              in_axes(cellprog.state_specs, "data"), 0),
                     axis_name=_GRID_DATA)

    def step_c(t, data, full_state):
        state, ef = full_state
        out, ef_out = outer(t, data, state, ef)
        return _drop_replicas(out, cellprog.state_specs), ef_out

    return jax.jit(step_c)


# -- mesh engines (shard_map; sync and bounded-staleness) -------------------

def _mesh_pspec(ds: DimSpec, daxes, model_axis):
    entries = []
    for a in ds:
        if a == "data":
            entries.append(daxes if len(daxes) > 1 else daxes[0])
        elif a == "model":
            entries.append(model_axis)
        else:
            entries.append(None)
    return P(*entries)


def _pvary_missing(tree_vals, specs, axis_map):
    """Promote operands to fully varying over the mesh axes their
    dim-spec does not split them over (replicated inputs must be
    promoted before mixing with varying values on recent JAX)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_vals)
    out = []
    for v, ds in zip(leaves, _spec_leaves(specs)):
        missing = ()
        if "data" not in ds:
            missing += axis_map["data"]
        if "model" not in ds:
            missing += axis_map["model"]
        out.append(pvary(v, missing))
    return jax.tree_util.tree_unflatten(treedef, out)


def mesh_step_fn(cellprog: CellProgram, mesh, *, data_axis="data",
                 model_axis: str = "model", staleness: int = 0,
                 compression=None, comm_local: bool = False):
    """Raw (unjitted) mesh executor.

    Returns ``step(t, data, state, cbufs) -> (state, cbufs)`` running
    the cell once per device of the (data=P, model=Q) mesh under
    shard_map.  ``cbufs`` is the communication-state pytree -- ``{}``
    when no policy needs state, otherwise up to two sub-dicts of
    per-cell buffers sharded over (data, model):

      * ``cbufs["stale"]`` (``staleness = tau > 0``): one
        ``(P, Q, tau, *cell_result_shape)`` FIFO ring per collective
        (:class:`StaleComm`; tau = 0 applies every reduction
        synchronously via :class:`SyncComm`);
      * ``cbufs["ef"]`` (``compression`` with lossy codecs): one
        ``(P, Q, *payload_shape)`` f32 error-feedback residual per
        compressed collective (:class:`CompressedComm` wrapping the
        sync/stale executor, so compression composes with staleness).
    """
    daxes = as_axes(data_axis)
    axis_map = {"data": daxes, "model": (model_axis,)}
    sizes = {"data": axes_size(mesh, data_axis),
             "model": axes_size(mesh, model_axis)}
    sched = cellprog.schedule
    policy = compression
    if comm_local and (staleness or policy is not None):
        raise ValueError("comm_local measures the collective-free step; "
                         "it cannot compose with staleness or compression")
    if policy is not None:
        policy.validate(sched)
    ef_names = policy.stateful_names(sched) if policy is not None else ()
    dspec = daxes if len(daxes) > 1 else daxes[0]

    def pspecs(specs):
        return jax.tree_util.tree_map(
            lambda ds: _mesh_pspec(ds, daxes, model_axis), specs,
            is_leaf=_is_dimspec)

    data_pspecs = pspecs(cellprog.data_specs)
    state_pspecs = pspecs(cellprog.state_specs)
    buf_pspecs = {}
    if staleness:
        buf_pspecs["stale"] = {name: P(dspec, model_axis)
                               for name in sched.names}
    if ef_names:
        buf_pspecs["ef"] = {name: P(dspec, model_axis) for name in ef_names}

    def kernel(t, data, state, cbufs):
        data = _pvary_missing(data, cellprog.data_specs, axis_map)
        state = _pvary_missing(state, cellprog.state_specs, axis_map)
        t = pvary(t, daxes + (model_axis,))
        if staleness:
            inner = StaleComm(sched, axis_map, sizes, tau=staleness, t=t,
                              bufs={k: b[0, 0]
                                    for k, b in cbufs["stale"].items()})
        else:
            inner = (LocalComm if comm_local else SyncComm)(
                sched, axis_map, sizes)
        if policy is not None:
            comm = CompressedComm(inner, policy,
                                  ef={k: b[0, 0]
                                      for k, b in cbufs.get("ef",
                                                            {}).items()})
        else:
            comm = inner
        out = cellprog.cell(comm, t, data, state)
        comm.finalize()
        cb_out = {}
        if staleness:
            cb_out["stale"] = {k: b[None, None]
                               for k, b in comm.bufs_out.items()}
        if ef_names:
            cb_out["ef"] = {k: e[None, None]
                            for k, e in comm.ef_out.items()}
        return out, cb_out

    return shard_map(
        kernel, mesh,
        in_specs=(P(), data_pspecs, state_pspecs, buf_pspecs),
        out_specs=(state_pspecs, buf_pspecs))


def probe_collective_shapes(cellprog: CellProgram, data, state, *,
                            sizes, layout: str = "global"):
    """Per-cell avals of every declared collective, via one
    ``eval_shape`` trace of the cell under a ShapeProbeComm (no mesh or
    devices needed).  Returns ``(results, payloads)``: the *result* aval
    sizes the async engine's staleness rings; the *payload* aval (the
    value the cell hands to ``comm``, i.e. what travels the wire) sizes
    error-feedback residuals and the wire accounting.

    ``layout`` names how ``data``/``state`` leaves relate to one cell's
    array: ``"global"`` (mesh layout -- each dim named in the dim-spec
    is divided by its grid extent) or ``"blocked"`` (grid-engine layout
    -- one extra leading block axis per named dim, dropped).
    """
    if layout not in ("global", "blocked"):
        raise ValueError(f"layout={layout!r}; expected 'global' or "
                         "'blocked'")

    def cell_aval(arr, ds):
        arr = jnp.asarray(arr) if not hasattr(arr, "shape") else arr
        if layout == "blocked":
            k = sum(1 for a in ds if a)
            return jax.ShapeDtypeStruct(tuple(arr.shape[k:]), arr.dtype)
        shape = list(arr.shape)
        for i, a in enumerate(ds):
            if a:
                shape[i] //= sizes[a]
        return jax.ShapeDtypeStruct(tuple(shape), arr.dtype)

    def avals(tree_vals, specs):
        leaves, treedef = jax.tree_util.tree_flatten(tree_vals)
        out = [cell_aval(v, ds)
               for v, ds in zip(leaves, _spec_leaves(specs))]
        return jax.tree_util.tree_unflatten(treedef, out)

    record: dict = {}
    payloads: dict = {}
    probe = ShapeProbeComm(cellprog.schedule,
                           {"data": ("data",), "model": ("model",)}, sizes,
                           record, payloads)

    def run(t, d, s):
        out = cellprog.cell(probe, t, d, s)
        probe.finalize()
        return out

    jax.eval_shape(run, jax.ShapeDtypeStruct((), jnp.int32),
                   avals(data, cellprog.data_specs),
                   avals(state, cellprog.state_specs))
    return record, payloads


def comm_accounting(cellprog: CellProgram, data, state, *, sizes,
                    layout: str = "global", compression=None) -> dict:
    """Exact per-step bytes-on-wire of a CellProgram's schedule under a
    compression policy (None = uncompressed), for
    ``EngineProgram.comm_bytes``.  One eval_shape probe, no devices."""
    _, payloads = probe_collective_shapes(cellprog, data, state,
                                          sizes=sizes, layout=layout)
    return wire_accounting(cellprog.schedule, payloads, sizes, compression)


def grid_bind_state(cellprog: CellProgram, data, state0, *, Pn: int, Qn: int,
                    compression=None):
    """Engine-state plumbing shared by the grid-engine program builders.

    One build-time probe yields both the wire accounting and (when the
    policy carries error feedback) the zero EF residuals -- one
    ``(P, Q, *payload_shape)`` f32 buffer per stateful-codec collective,
    blocked layout, matching :func:`grid_program`'s ``ef`` operand.
    Returns ``(full_state0, unwrap, acct)`` where ``unwrap`` recovers
    the solver state from the full engine state (identity when
    ``compression`` is None, so the uncompressed state layout is
    untouched)."""
    sizes = {"data": Pn, "model": Qn}
    _, payloads = probe_collective_shapes(cellprog, data, state0,
                                          sizes=sizes, layout="blocked")
    acct = wire_accounting(cellprog.schedule, payloads, sizes, compression)
    if compression is None:
        return state0, (lambda s: s), acct
    ef0 = {name: jnp.zeros((Pn, Qn) + payloads[name].shape, jnp.float32)
           for name in compression.stateful_names(cellprog.schedule)}
    return (state0, ef0), (lambda s: s[0]), acct


def mesh_program(cellprog: CellProgram, mesh, data, state0, *,
                 data_axis="data", model_axis: str = "model",
                 staleness: int = 0, compression=None):
    """Bind a CellProgram to a mesh: returns ``(step, comm0, acct)``
    where ``step(t, data, (state, comm_state))`` is jitted, ``comm0``
    holds the zero-initialized communication state (staleness rings
    under ``"stale"``, error-feedback residuals under ``"ef"``; ``{}``
    when ``staleness == 0`` and no lossy codec runs, in which case the
    jaxpr is exactly the sync engine's), and ``acct`` is the program's
    exact per-step wire accounting (:func:`comm_accounting`)."""
    daxes = as_axes(data_axis)
    sizes = {"data": axes_size(mesh, data_axis),
             "model": axes_size(mesh, model_axis)}
    policy = compression
    raw = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis, staleness=staleness,
                       compression=policy)
    results, payloads = probe_collective_shapes(cellprog, data, state0,
                                                sizes=sizes)
    acct = wire_accounting(cellprog.schedule, payloads, sizes, policy)
    comm0 = {}
    dspec = daxes if len(daxes) > 1 else daxes[0]
    put = _putter(mesh)
    if staleness > 0:
        comm0["stale"] = {}
        for name, aval in results.items():
            shape = (sizes["data"], sizes["model"], staleness) + aval.shape
            comm0["stale"][name] = put(jnp.zeros(shape, aval.dtype),
                                       P(dspec, model_axis))
    ef_names = policy.stateful_names(cellprog.schedule) \
        if policy is not None else ()
    if ef_names:
        comm0["ef"] = {
            name: put(jnp.zeros((sizes["data"], sizes["model"])
                                + payloads[name].shape, jnp.float32),
                      P(dspec, model_axis))
            for name in ef_names}

    @jax.jit
    def step(t, data, full_state):
        state, cbufs = full_state
        return raw(t, data, state, cbufs)

    return step, comm0, acct


def mesh_local_step(cellprog: CellProgram, mesh, *, data_axis="data",
                    model_axis: str = "model"):
    """Jitted collective-free twin of a mesh program's step, for the
    differential phase attribution of :mod:`repro.obs.phases`:
    ``local(t, data, state) -> state`` runs the same shard_map cell with
    every declared reduction executed cell-locally
    (:class:`~repro.core.comm.LocalComm`).  Numerically wrong on
    purpose; only ever timed, never consumed."""
    raw = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis, comm_local=True)

    @jax.jit
    def local(t, data, state):
        out, _ = raw(t, data, state, {})
        return out

    return local


def prepare_shard_map(mesh, X, y, *, data_axis="data", model_axis="model",
                      m_multiple: int | None = None) -> ShardMapData:
    """Pad (X, y) so the mesh divides both axes and place the shards.

    The padding rule is identical to ``partition(..., m_multiple=P*Q)``,
    so a shard_map cell sees the same (n_p, m_q) block as the simulated
    grid's cell (p, q)."""
    Pn = axes_size(mesh, data_axis)
    Qn = axes_size(mesh, model_axis)
    if m_multiple is not None and m_multiple % Qn:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Qn}")
    n, m = X.shape
    n_pad = _ceil_to(n, Pn)
    m_pad = _ceil_to(m, m_multiple or Qn)
    Xp = np.zeros((n_pad, m_pad), np.float32)
    Xp[:n, :m] = np.asarray(X, np.float32)
    yp = np.zeros((n_pad,), np.float32)
    yp[:n] = np.asarray(y, np.float32)
    maskp = np.zeros((n_pad,), np.float32)
    maskp[:n] = 1.0
    daxes = as_axes(data_axis)
    put = _putter(mesh)
    return ShardMapData(
        mesh=mesh,
        x=put(jnp.asarray(Xp), P(daxes, model_axis)),
        y=put(jnp.asarray(yp), P(daxes)),
        mask=put(jnp.asarray(maskp), P(daxes)),
        n=n, m=m, P=Pn, Q=Qn,
        data_axis=data_axis, model_axis=model_axis)
