"""Unified solver framework: one API over D3CA / RADiSA / SFK / ADMM.

The paper's three doubly distributed optimizers -- plus the stochastic
Fang--Klabjan scheme of the follow-up paper -- share one P x Q execution
story (the way CoCoA frames local solvers as pluggable subproblems and
SCOPE separates the outer cooperative loop from the local computation).
This module provides that story once:

  * a :class:`Solver` protocol with a registry --
    ``get_solver("d3ca" | "radisa" | "sfk" | "admm")`` returns the
    solver class;
  * orthogonal knobs threaded end-to-end:
      - ``engine="simulated" | "shard_map" | "async" | "overlap"`` --
        vmap grid on one device, one block per device on a
        (data=P, model=Q) mesh with synchronous reductions, the same
        mesh execution with bounded-staleness reductions, or the
        communication-overlap engine (async consumption contract plus
        donated in-flight reduction slots and selective host syncs so
        the local solve overlaps the wire; ``"sync"`` is accepted as an
        alias for ``"shard_map"``);
      - ``staleness=tau``  -- async/overlap engines: every collective
        the solver's CommSchedule declares is applied with delay tau
        (tau = 0 reproduces the sync engine bit for bit);
      - ``topology="pods=G[:codec]"``  -- hierarchical topology-aware
        reductions: full-precision psum within each of G pods,
        codec-compressed (with error feedback) across pods, on both
        the grid and mesh engines;
      - ``local_backend="ref" | "pallas"``    -- pure-jnp cell-local
        solver vs the Pallas TPU kernels (interpret mode on CPU), used
        inside the vmap grid and inside each shard_map cell alike;
      - ``block_format="dense" | "sparse"``   -- per-cell (n_p, m_q)
        dense tiles vs padded-ELL sparse cells whose memory scales with
        the nonzero count (news20-scale instances; accepts a
        :class:`~repro.data.sparse.CSRMatrix` without ever densifying);
      - ``compression=...``  -- a codec spec / CompressionPolicy mapping
        the solver's declared collectives to compression codecs
        (``"int8"``, ``"fp8"``, ``"topk:0.1"``, or per-collective
        ``"w_contrib=int8,dalpha=identity"``) with error feedback;
        ``None`` builds the exact uncompressed program, and the
        identity codec is bit-identical to it.  ``"adaptive..."``
        specs build a :class:`~repro.core.compress.CompressionSchedule`
        -- staged codec switching (top-k early, int8 near convergence)
        driven by the observed ``rel_opt`` slope, each stage a
        warm-started program rebuild.  Every program reports exact
        bytes-on-wire (``SolveResult.comm_bytes`` + cumulative
        ``comm_bytes`` per history entry);
  * a shared outer driver: objective / duality-gap history, early
    stopping, warm starts from a previous ``w`` / ``alpha``.

Example::

    from repro.core.solver import get_solver

    solver = get_solver("d3ca")(engine="async", staleness=2,
                                local_backend="pallas",
                                block_format="sparse")
    res = solver.solve("hinge", X, y, P=4, Q=2,
                       cfg=D3CAConfig(lam=1e-2, outer_iters=20),
                       f_star=f_star, tol=1e-2)
    res.w, res.history[-1]["objective"], res.converged

Engine x backend support matrix: see README ("Unified solver API").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Type

from .admm import (ADMMConfig, admm_shard_map_program, admm_simulated_program,
                   make_admm_step)
from .comm_model import as_topology
from .compress import CompressionSchedule, as_compression
from .d3ca import (D3CAConfig, d3ca_shard_map_program, d3ca_simulated_program,
                   make_d3ca_step)
from .engines import (EngineProgram, drive, prepare_shard_map,
                      prepare_shard_map_sparse)
from .losses import get_loss
from .partition import partition, partition_sparse
from .radisa import (RADiSAConfig, make_radisa_step,
                     radisa_shard_map_program, radisa_simulated_program)
from .reference import rel_opt
from .sfk import (SFKConfig, make_sfk_step, sfk_shard_map_program,
                  sfk_simulated_program)
from .util import axes_size

ENGINES = ("simulated", "shard_map", "async", "overlap")
#: "sync" names today's synchronous mesh policy explicitly (the
#: CommSchedule terminology); it is the same engine as "shard_map".
ENGINE_ALIASES = {"sync": "shard_map"}
LOCAL_BACKENDS = ("ref", "pallas")
BLOCK_FORMATS = ("dense", "sparse")


@dataclasses.dataclass
class SolveResult:
    """Outcome of :meth:`Solver.solve`."""

    w: Any                          # (m,) global primal iterate
    alpha: Optional[Any]            # (n,) global dual iterate (D3CA only)
    history: List[Dict[str, float]]  # per-iter: iter, time_s, objective,
    #                                  [duality_gap], [rel_opt]; timed
    #                                  solves (tracer=/registry=) add
    #                                  step_s, local_s, comm_s, host_s
    iters: int                      # outer iterations actually run
    converged: bool                 # True iff early stopping triggered
    solver: str
    engine: str
    local_backend: str
    block_format: str = "dense"
    staleness: int = 0
    compression: Optional[str] = None   # canonical policy/schedule spec
    topology: Optional[str] = None      # canonical topology spec, or None
    #: exact per-step wire accounting of the declared collectives (see
    #: repro.core.compress.wire_accounting); history entries carry the
    #: cumulative "comm_bytes" derived from it
    comm_bytes: Optional[Dict] = None


def _unpack_warm_start(warm_start):
    if warm_start is None:
        return None, None
    if isinstance(warm_start, SolveResult):
        return warm_start.w, warm_start.alpha
    if isinstance(warm_start, (tuple, list)):
        w0 = warm_start[0] if len(warm_start) > 0 else None
        alpha0 = warm_start[1] if len(warm_start) > 1 else None
        return w0, alpha0
    return warm_start, None         # bare w


class Solver:
    """Base class: one doubly distributed optimizer under two engines.

    Subclasses bind the algorithm (config class + the two
    ``EngineProgram`` builders); everything about *running* a solve --
    data prep and padding, the outer loop, history, early stopping, warm
    starts -- lives here, once.
    """

    name: str = ""
    config_cls: Type = None
    has_dual: bool = False
    #: ADMM's inner solve is a cached Cholesky; it accepts the knob but
    #: has no kernel to dispatch to.
    uses_local_backend: bool = True
    #: True when the solver's cell program accepts a per-row activity
    #: gate (the incremental online-update path; D3CA only).
    supports_row_gate: bool = False

    def __init__(self, engine: str = "simulated", local_backend: str = "ref",
                 block_format: str = "dense", staleness: int = 0,
                 compression=None, topology=None,
                 program_cache: bool = False):
        engine = ENGINE_ALIASES.get(engine, engine)
        if engine not in ENGINES:
            raise ValueError(f"engine={engine!r}; expected one of {ENGINES}")
        if local_backend not in LOCAL_BACKENDS:
            raise ValueError(f"local_backend={local_backend!r}; expected one "
                             f"of {LOCAL_BACKENDS}")
        if block_format not in BLOCK_FORMATS:
            raise ValueError(f"block_format={block_format!r}; expected one "
                             f"of {BLOCK_FORMATS}")
        staleness = int(staleness)
        if staleness < 0:
            raise ValueError(f"staleness={staleness} must be >= 0 (the "
                             "reduction delay tau of the async/overlap "
                             "engines)")
        if staleness > 0 and engine not in ("async", "overlap"):
            raise ValueError(
                f"staleness={staleness} needs engine='async' or "
                f"engine='overlap'; the {engine!r} engine applies every "
                "reduction synchronously.  Pass engine='async' or "
                "engine='overlap' (staleness=0 on either reproduces "
                "'shard_map' exactly).")
        self.engine = engine
        self.local_backend = local_backend
        self.block_format = block_format
        self.staleness = staleness
        #: normalized CompressionPolicy or CompressionSchedule (None =
        #: no compression machinery at all -- the engines build the
        #: exact uncompressed program).  Validated against the solver's
        #: declared CommSchedule when the program is built.
        self.compression = as_compression(compression)
        #: hierarchical reduction topology (None = flat reductions)
        self.topology = as_topology(topology)
        #: current CompressionSchedule stage (policies are per-stage)
        self._stage = 0
        #: reuse jitted step callables across repeated program builds
        #: with constant shapes (always on inside :meth:`update`, where
        #: shapes are constant by design).  Keyed on (solver, engine,
        #: loss, cfg-minus-outer_iters, backend, format, gate-ness,
        #: shapes, grid); bypassed under compression / topology /
        #: staleness / overlap, whose programs carry per-build device
        #: state (EF residuals, rings, donated buffers).
        self.program_cache = bool(program_cache)
        self._prog_cache: Dict = {}

    @property
    def compression_spec(self) -> Optional[str]:
        return self.compression.spec if self.compression is not None else None

    @property
    def active_policy(self):
        """The CompressionPolicy the *current* program runs under: the
        schedule's current stage, or the fixed policy, or None."""
        if isinstance(self.compression, CompressionSchedule):
            return self.compression.stages[self._stage]
        return self.compression

    @property
    def topology_spec(self) -> Optional[str]:
        return self.topology.spec if self.topology is not None else None

    # ---- subclass hooks ---------------------------------------------------
    def _simulated_program(self, loss, data, cfg, w0, alpha0,
                           cache=None) -> EngineProgram:
        raise NotImplementedError

    def _shard_map_program(self, loss, sdata, cfg, w0, alpha0,
                           staleness: int = 0, cache=None) -> EngineProgram:
        raise NotImplementedError

    def _build_cache(self, loss_name, cfg, X, P, Q, mesh, gated: bool):
        """The per-key dict the program builders memoize their jitted
        steps in, or None when caching is off / unsafe (compression,
        topology, staleness and overlap programs carry per-build device
        state -- EF residuals, staleness rings, donated ring slots)."""
        if not self.program_cache:
            return None
        if (self.active_policy is not None or self.topology is not None
                or self.staleness > 0 or self.engine == "overlap"):
            return None
        key = (self.name, self.engine, loss_name,
               dataclasses.replace(cfg, outer_iters=0),
               self.local_backend, self.block_format, gated,
               tuple(X.shape), P, Q, mesh)
        return self._prog_cache.setdefault(key, {})

    # ---- program construction --------------------------------------------
    def program(self, loss_name: str, X, y, *, P: int = None, Q: int = None,
                cfg=None, mesh=None, warm_start=None,
                data_axis="data", model_axis: str = "model",
                row_gate=None) -> EngineProgram:
        """Bind the solver to data under the configured engine/backend.

        Pads the feature dimension to a multiple of P*Q (identically for
        both engines and both block formats) so RADiSA's P sub-blocks
        always divide m_q and the engines see bit-identical blocks.
        ``block_format="sparse"`` accepts a
        :class:`~repro.data.sparse.CSRMatrix` ``X`` and never
        materializes the dense matrix; dense ``X`` is converted cell by
        cell.  ``block_format="dense"`` densifies a CSR input.

        Args:
          loss_name: a key of :data:`repro.core.losses.LOSSES`.
          X, y: the (n, m) training matrix and (n,) labels.
          P, Q: observation/feature partition counts (required unless a
            ``mesh`` carrying both axes is given).
          cfg: the solver's config dataclass (``config_cls()`` default).
          mesh: an explicit jax mesh for the mesh engines.
          warm_start: a :class:`SolveResult`, a ``(w, alpha)`` tuple, or
            a bare ``w`` to initialize the iterates from.
          data_axis, model_axis: mesh axis names.
          row_gate: optional (n,) 0/1 per-row activity gate restricting
            dual updates to gated-on rows -- the incremental
            online-update path.  Only solvers with
            ``supports_row_gate`` accept it.

        Returns:
          An :class:`EngineProgram` ready for :func:`engines.drive`.

        Raises:
          ValueError: on a missing grid spec, a mesh/grid mismatch, an
            unsupported ``row_gate``, or a topology that does not
            divide P.
        """
        loss = get_loss(loss_name)
        cfg = cfg if cfg is not None else self.config_cls()
        if row_gate is not None and not self.supports_row_gate:
            raise ValueError(
                f"solver {self.name!r} has no incremental row-gate path; "
                "gated warm-started passes are a dual-solver feature "
                "(use 'd3ca')")
        gate_kw = {} if row_gate is None else {"row_gate": row_gate}
        cache = self._build_cache(loss_name, cfg, X, P, Q, mesh,
                                  row_gate is not None)
        w0, alpha0 = _unpack_warm_start(warm_start)
        sparse = self.block_format == "sparse"
        topo = self.topology
        pods = topo.pods if topo is not None else 1
        if not sparse and hasattr(X, "toarray"):
            X = X.toarray()       # CSR input under block_format="dense"
        if self.engine == "simulated":
            if P is None or Q is None:
                raise ValueError("engine='simulated' needs P and Q")
            if pods > 1 and P % pods:
                raise ValueError(f"topology pods={pods} must divide P={P}")
            if sparse:
                data = partition_sparse(X, y, P, Q, m_multiple=P * Q)
            else:
                data = partition(X, y, P, Q, m_multiple=P * Q)
            return self._simulated_program(loss, data, cfg, w0, alpha0,
                                           cache=cache, **gate_kw)
        if mesh is None:
            if P is None or Q is None:
                raise ValueError(f"engine={self.engine!r} needs a mesh "
                                 "or P and Q")
            from repro.launch.mesh import make_grid_mesh, make_mesh
            if pods > 1:
                # hierarchical reductions want the pod split as a real
                # mesh axis: (pod=G, data=P/G, model=Q)
                if P % pods:
                    raise ValueError(f"topology pods={pods} must divide "
                                     f"P={P}")
                mesh = make_mesh((pods, P // pods, Q),
                                 ("pod", "data", "model"))
                data_axis = ("pod", "data")
            else:
                mesh = make_grid_mesh(P, Q)
        elif pods > 1 and data_axis == "data" and "pod" in mesh.axis_names:
            data_axis = ("pod", "data")   # pod-split mesh supplied directly
        Pn = axes_size(mesh, data_axis)
        Qn = axes_size(mesh, model_axis)
        if (P is not None and P != Pn) or (Q is not None and Q != Qn):
            raise ValueError(f"mesh is {Pn}x{Qn} but P={P}, Q={Q} requested")
        prep = prepare_shard_map_sparse if sparse else prepare_shard_map
        sdata = prep(mesh, X, y, data_axis=data_axis,
                     model_axis=model_axis, m_multiple=Pn * Qn)
        return self._shard_map_program(loss, sdata, cfg, w0, alpha0,
                                       staleness=self.staleness,
                                       cache=cache, **gate_kw)

    # ---- the shared outer driver ------------------------------------------
    def solve(self, loss_name: str, X, y, *, P: int = None, Q: int = None,
              cfg=None, mesh=None, warm_start=None,
              tol: Optional[float] = None, f_star: Optional[float] = None,
              record_history: bool = True,
              callback: Optional[Callable] = None,
              tracer=None, registry=None, monitor=None,
              row_gate=None) -> SolveResult:
        """Run the solver.

        Early stopping (when ``tol`` is given) uses, in order of
        preference: relative optimality vs ``f_star``; the duality gap
        (dual solvers); the relative objective change between iterates.
        ``callback(t, w, alpha)`` fires every iteration.

        Under an adaptive :class:`CompressionSchedule` the solve runs as
        a sequence of warm-started stages -- one program build per codec
        stage, advanced when the convergence metric's log10 slope
        flattens below the schedule's ``slope_tol`` -- and the merged
        history tags every entry with ``stage`` and ``codec``.

        Args:
          loss_name, X, y, P, Q, cfg, mesh, warm_start, row_gate: see
            :meth:`program`.
          tol: early-stopping tolerance (None disables early stopping).
          f_star: reference optimum enabling the ``rel_opt`` history
            field and rel-opt early stopping.
          record_history: collect per-iteration history entries.
          callback: ``callback(t, w, alpha)`` per outer iteration.
          tracer: a :class:`repro.obs.Tracer` (enables the timed path).
          registry: a :class:`repro.obs.Registry` for per-iter metrics.
          monitor: a :class:`repro.obs.HealthMonitor`; polled once per
            outer iteration (rules read the registry only -- iterates
            are untouched).

        Returns:
          A :class:`SolveResult`.

        Raises:
          ValueError: propagated from :meth:`program` (bad grid spec,
            unsupported ``row_gate``, ...).
        """
        cfg = cfg if cfg is not None else self.config_cls()
        sched = (self.compression
                 if isinstance(self.compression, CompressionSchedule)
                 else None)
        if sched is None:
            res, _ = self._solve_stage(
                loss_name, X, y, P=P, Q=Q, cfg=cfg, mesh=mesh,
                warm_start=warm_start, tol=tol, f_star=f_star,
                record_history=record_history, callback=callback,
                tracer=tracer, registry=registry, monitor=monitor,
                row_gate=row_gate)
            return res
        history: List[Dict[str, float]] = []
        warm = warm_start
        iters_done = 0
        time_off, bytes_off = 0.0, 0
        res = None
        try:
            for si in range(len(sched.stages)):
                remaining = cfg.outer_iters - iters_done
                if remaining <= 0:
                    break
                self._stage = si
                last = si == len(sched.stages) - 1
                stage_cfg = dataclasses.replace(cfg, outer_iters=remaining)
                res, advanced = self._solve_stage(
                    loss_name, X, y, P=P, Q=Q, cfg=stage_cfg, mesh=mesh,
                    warm_start=warm, tol=tol, f_star=f_star,
                    record_history=record_history, callback=callback,
                    tracer=tracer, registry=registry, monitor=monitor,
                    row_gate=row_gate,
                    advance=None if last else sched,
                    iter_offset=iters_done, time_offset=time_off,
                    bytes_offset=bytes_off, stage=si)
                history.extend(res.history)
                iters_done += res.iters
                if res.history:
                    time_off = res.history[-1]["time_s"]
                    bytes_off = res.history[-1].get("comm_bytes", bytes_off)
                warm = res
                if res.converged or not advanced:
                    break
        finally:
            self._stage = 0
        return dataclasses.replace(res, history=history, iters=iters_done,
                                   compression=sched.spec)

    def update(self, loss_name: str, X, y, *, touched, warm_start,
               P: int = None, Q: int = None, cfg=None, mesh=None,
               passes: int = 1, tracer=None, registry=None, monitor=None,
               record_history: bool = True) -> SolveResult:
        """Incremental-update entry point for the online service.

        Runs ``passes`` warm-started outer iterations in which dual
        updates are restricted to the ``touched`` rows (the cells whose
        row partition received new observations); every other row's
        alpha is frozen, but the primal-dual map still sums the full
        dual, so the returned ``w`` is exact for the whole buffer.

        The compiled-program cache is always on here: the observation
        buffer has a constant shape by design, so every update after the
        first reuses the previously traced+compiled step instead of
        paying the ~seconds of per-update program rebuild.

        Args:
          loss_name, X, y, P, Q, cfg, mesh: see :meth:`solve`.  ``X``
            is the full observation buffer (constant shape across
            updates keeps the jit cache warm).
          touched: integer row indices that may move their dual.
          warm_start: the previous iterates (required -- an incremental
            update without a warm start is just a truncated cold
            solve).
          passes: warm-started outer iterations over the touched cells.
          tracer, registry: see :meth:`solve`.

        Returns:
          A :class:`SolveResult` whose ``w``/``alpha`` fold the new
          observations into the previous model.

        Raises:
          ValueError: when this solver has no row-gate path
            (``supports_row_gate`` is False) or ``warm_start`` is None.
        """
        if warm_start is None:
            raise ValueError("incremental update needs warm_start=(w, "
                             "alpha); for a cold model run solve()")
        import numpy as np
        gate = np.zeros((X.shape[0],), dtype=np.float32)
        gate[np.asarray(touched, dtype=np.int64)] = 1.0
        cfg = cfg if cfg is not None else self.config_cls()
        cfg = dataclasses.replace(cfg, outer_iters=int(passes))
        prev_cache = self.program_cache
        self.program_cache = True
        try:
            return self.solve(loss_name, X, y, P=P, Q=Q, cfg=cfg, mesh=mesh,
                              warm_start=warm_start, row_gate=gate,
                              tracer=tracer, registry=registry,
                              monitor=monitor,
                              record_history=record_history)
        finally:
            self.program_cache = prev_cache

    def _solve_stage(self, loss_name: str, X, y, *, P: int = None,
                     Q: int = None, cfg=None, mesh=None, warm_start=None,
                     tol: Optional[float] = None,
                     f_star: Optional[float] = None,
                     record_history: bool = True,
                     callback: Optional[Callable] = None,
                     tracer=None, registry=None, monitor=None,
                     row_gate=None,
                     advance=None, iter_offset: int = 0,
                     time_offset: float = 0.0, bytes_offset: int = 0,
                     stage: Optional[int] = None):
        """One program build + outer loop.  Returns ``(result,
        advanced)`` where ``advanced`` reports an adaptive-schedule
        stage switch (``advance.should_advance`` fired on the observed
        convergence metric; the result is then a warm-start point, not
        a converged solve).

        Telemetry (both default off; the untimed path is the exact
        legacy loop, bit-identical results):

          * ``tracer`` -- a :class:`repro.obs.Tracer`.  The solve emits
            ``solve > data_prep / calibrate / outer_iter > step /
            observe`` spans, and phase attribution (``repro.obs.
            phases``) synthesizes ``local_solve`` and ``comm/<name>``
            child spans inside every measured step -- one per collective
            the solver's CommSchedule declares, sized by the program's
            exact bytes-on-wire;
          * ``registry`` -- a :class:`repro.obs.Registry`.  Per-iter
            metrics (``solver/objective``, ``solver/step_s``, phase
            histograms, cumulative ``solver/comm_bytes``, per-collective
            ``compress/ef_norm/*`` when error feedback is active,
            ``async/ring_occupancy`` under staleness) land in it, keyed
            by ``{solver=..., engine=...}`` labels.

        Either one switches the driver to its timed path, which adds a
        per-step device sync and per-iter ``step_s`` / ``local_s`` /
        ``comm_s`` / ``host_s`` fields to the history; the iterates
        themselves are unchanged.
        """
        from repro.obs import as_tracer, calibrate_phases
        from repro.obs.phases import bench_codecs
        tr = as_tracer(tracer)
        reg = registry
        timed = tr.enabled or reg is not None
        loss = get_loss(loss_name)
        cfg = cfg if cfg is not None else self.config_cls()
        policy = self.active_policy
        labels = {"solver": self.name, "engine": self.engine}
        with tr.span("solve", loss=loss_name, **labels):
            with tr.span("data_prep"):
                prog = self.program(loss_name, X, y, P=P, Q=Q, cfg=cfg,
                                    mesh=mesh, warm_start=warm_start,
                                    row_gate=row_gate)
            split = None
            if timed:
                with tr.span("calibrate"):
                    split = calibrate_phases(prog)
                if policy is not None:
                    codec_s = bench_codecs(policy,
                                           prog.comm_bytes or {})
                    for cname, secs in codec_s.items():
                        if reg is not None:
                            reg.gauge(f"compress/codec_s/{cname}",
                                      **labels).set(secs)
                    if codec_s:
                        tr.instant("codec_bench", **codec_s)
            lam = cfg.lam
            history: List[Dict[str, float]] = []
            need_obs = (record_history or callback is not None
                        or tol is not None or advance is not None)
            prev_f = [None]
            advanced = [False]
            metric_vals: List[float] = []
            bytes_per_step = (prog.comm_bytes or {}).get("bytes_per_step")
            t0 = time.perf_counter()
            last_phase: Dict[str, float] = {}

            def on_step(t, t_begin, step_s):
                last_phase.clear()
                last_phase["step_s"] = step_s
                if split is not None:
                    att = split.attribute(step_s)
                    last_phase["local_s"] = att["local_s"]
                    last_phase["comm_s"] = att["comm_s"]
                    for key in ("comm_exposed_s", "comm_hidden_s"):
                        if key in att:
                            last_phase[key] = att[key]
                    tr.record("local_solve", t_begin, att["local_s"], iter=t)
                    off = t_begin + att["local_s"]
                    for name, secs in att["collectives"].items():
                        tr.record(f"comm/{name}", off, secs, iter=t)
                        off += secs
                if reg is not None:
                    reg.histogram("solver/step_s", **labels).observe(step_s)
                    if split is not None:
                        reg.histogram("solver/local_s", **labels).observe(
                            last_phase["local_s"])
                        reg.histogram("solver/comm_s", **labels).observe(
                            last_phase["comm_s"])
                        if "comm_exposed_s" in last_phase:
                            reg.histogram("solver/comm_exposed_s",
                                          **labels).observe(
                                last_phase["comm_exposed_s"])
                    if bytes_per_step is not None:
                        reg.counter("solver/comm_bytes", **labels).inc(
                            bytes_per_step)

            def observe(t, state):
                if not need_obs:
                    return False
                th0 = time.perf_counter()
                w = prog.w_of(state)
                alpha = prog.alpha_of(state) if prog.alpha_of else None
                f = float(loss.objective(X, y, w, lam))
                entry = {"iter": t + iter_offset,
                         "time_s": time.perf_counter() - t0 + time_offset,
                         "objective": f}
                if stage is not None:
                    entry["stage"] = stage
                    entry["codec"] = policy.spec if policy is not None \
                        else None
                if timed:
                    entry.update(last_phase)
                if bytes_per_step is not None:
                    # cumulative bytes-on-wire after t outer steps (every
                    # declared collective launches once per step)
                    entry["comm_bytes"] = bytes_offset + bytes_per_step * t
                if alpha is not None:
                    entry["duality_gap"] = float(
                        f - loss.dual_objective(X, y, alpha, lam))
                if f_star is not None:
                    entry["rel_opt"] = float(rel_opt(f, f_star))
                if timed:
                    # objective / gap / rel_opt eval is the host phase
                    entry["host_s"] = time.perf_counter() - th0
                if reg is not None:
                    reg.counter("solver/iters", **labels).inc()
                    reg.gauge("solver/objective", **labels).set(
                        entry["objective"])
                    if "duality_gap" in entry:
                        reg.gauge("solver/duality_gap", **labels).set(
                            entry["duality_gap"])
                    if "rel_opt" in entry:
                        reg.gauge("solver/rel_opt", **labels).set(
                            entry["rel_opt"])
                    if "host_s" in entry:
                        reg.histogram("solver/host_s", **labels).observe(
                            entry["host_s"])
                    if prog.ef_of is not None:
                        import numpy as np
                        for cname, buf in prog.ef_of(state).items():
                            reg.gauge(f"compress/ef_norm/{cname}",
                                      **labels).set(
                                float(np.linalg.norm(np.asarray(buf))))
                    if self.staleness > 0:
                        # filled FIFO slots / ring capacity (the rings
                        # are seeded full at t=1; before that they hold
                        # the first reduction, so occupancy ramps once)
                        reg.gauge("async/ring_occupancy", **labels).set(
                            min(t, self.staleness) / self.staleness)
                if record_history:
                    history.append(entry)
                if callback is not None:
                    callback(t + iter_offset, w, alpha)
                stop = False
                if tol is not None:
                    if f_star is not None:
                        stop = entry["rel_opt"] < tol
                    elif "duality_gap" in entry:
                        stop = entry["duality_gap"] < tol
                    elif prev_f[0] is not None:
                        stop = abs(f - prev_f[0]) <= tol * max(1.0, abs(f))
                prev_f[0] = f
                if advance is not None and not stop:
                    metric_vals.append(entry.get("rel_opt", f))
                    if advance.should_advance(metric_vals):
                        advanced[0] = True
                        stop = True
                return stop

            state, iters, stopped = drive(
                prog, cfg.outer_iters, observe,
                tracer=tr if tr.enabled else None,
                on_step=on_step if timed else None,
                monitor=monitor)
            res = SolveResult(
                w=prog.w_of(state),
                alpha=prog.alpha_of(state) if prog.alpha_of else None,
                history=history, iters=iters,
                converged=stopped and not advanced[0],
                solver=self.name, engine=self.engine,
                local_backend=self.local_backend,
                block_format=self.block_format,
                staleness=self.staleness,
                compression=policy.spec if policy is not None else None,
                topology=self.topology_spec,
                comm_bytes=prog.comm_bytes)
            return res, advanced[0]


# ---------------------------------------------------------------------------
# the four solvers
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Solver]] = {}


def register_solver(cls: Type[Solver]) -> Type[Solver]:
    """Class decorator adding a :class:`Solver` subclass to the registry
    under its ``name`` attribute.  Returns the class unchanged, so it
    stacks with other decorators."""
    _REGISTRY[cls.name] = cls
    return cls


def get_solver(name: str) -> Type[Solver]:
    """Look up a solver class by name; instantiate with
    ``get_solver(name)(engine=..., local_backend=...)``.

    Raises:
      KeyError: for an unregistered name (the message lists what IS
        registered).
    """
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown solver {name!r}; available: "
                       f"{available_solvers()}") from None


def available_solvers():
    """Sorted names of every registered solver
    (``["admm", "d3ca", "radisa", "sfk"]``)."""
    return sorted(_REGISTRY)


@register_solver
class D3CASolver(Solver):
    name = "d3ca"
    config_cls = D3CAConfig
    has_dual = True
    supports_row_gate = True                   # incremental online updates
    make_step = staticmethod(make_d3ca_step)   # for dry-run lowering

    def _simulated_program(self, loss, data, cfg, w0, alpha0,
                           row_gate=None, cache=None):
        return d3ca_simulated_program(loss, data, cfg,
                                      local_backend=self.local_backend,
                                      w0=w0, alpha0=alpha0,
                                      compression=self.active_policy,
                                      topology=self.topology,
                                      row_gate=row_gate, cache=cache)

    def _shard_map_program(self, loss, sdata, cfg, w0, alpha0,
                           staleness: int = 0, row_gate=None, cache=None):
        return d3ca_shard_map_program(loss, sdata, cfg,
                                      local_backend=self.local_backend,
                                      w0=w0, alpha0=alpha0,
                                      staleness=staleness,
                                      compression=self.active_policy,
                                      overlap=self.engine == "overlap",
                                      topology=self.topology,
                                      row_gate=row_gate, cache=cache)


@register_solver
class RADiSASolver(Solver):
    name = "radisa"
    config_cls = RADiSAConfig
    make_step = staticmethod(make_radisa_step)

    def _simulated_program(self, loss, data, cfg, w0, alpha0, cache=None):
        return radisa_simulated_program(loss, data, cfg,
                                        local_backend=self.local_backend,
                                        w0=w0,
                                        compression=self.active_policy,
                                        topology=self.topology,
                                        cache=cache)

    def _shard_map_program(self, loss, sdata, cfg, w0, alpha0,
                           staleness: int = 0, cache=None):
        return radisa_shard_map_program(loss, sdata, cfg,
                                        local_backend=self.local_backend,
                                        w0=w0, staleness=staleness,
                                        compression=self.active_policy,
                                        overlap=self.engine == "overlap",
                                        topology=self.topology,
                                        cache=cache)


@register_solver
class SFKSolver(Solver):
    """Stochastic Fang--Klabjan sampling scheme (arXiv 1803.11287): a
    primal solver whose outer iteration subsamples the observations --
    minibatch anchor gradients plus variance-reduced local steps on the
    sampled rows only (see :mod:`repro.core.sfk`)."""
    name = "sfk"
    config_cls = SFKConfig
    make_step = staticmethod(make_sfk_step)

    def _simulated_program(self, loss, data, cfg, w0, alpha0, cache=None):
        return sfk_simulated_program(loss, data, cfg,
                                     local_backend=self.local_backend,
                                     w0=w0,
                                     compression=self.active_policy,
                                     topology=self.topology, cache=cache)

    def _shard_map_program(self, loss, sdata, cfg, w0, alpha0,
                           staleness: int = 0, cache=None):
        return sfk_shard_map_program(loss, sdata, cfg,
                                     local_backend=self.local_backend,
                                     w0=w0, staleness=staleness,
                                     compression=self.active_policy,
                                     overlap=self.engine == "overlap",
                                     topology=self.topology, cache=cache)


@register_solver
class ADMMSolver(Solver):
    name = "admm"
    config_cls = ADMMConfig
    uses_local_backend = False     # knob accepted, inner solve is Cholesky
    make_step = staticmethod(make_admm_step)

    def _simulated_program(self, loss, data, cfg, w0, alpha0, cache=None):
        return admm_simulated_program(loss, data, cfg, w0=w0,
                                      compression=self.active_policy,
                                      topology=self.topology, cache=cache)

    def _shard_map_program(self, loss, sdata, cfg, w0, alpha0,
                           staleness: int = 0, cache=None):
        return admm_shard_map_program(loss, sdata, cfg, w0=w0,
                                      staleness=staleness,
                                      compression=self.active_policy,
                                      overlap=self.engine == "overlap",
                                      topology=self.topology, cache=cache)
