"""Reference serial solvers and metrics.

* ``serial_sdca`` -- plain single-machine SDCA (= CoCoA/D3CA with P=Q=1);
  run long enough it gives the ``f*`` used by the paper's
  relative-optimality-difference metric (f_t - f*) / f*.
* ``duality_gap`` -- F(w(alpha)) - D(alpha), a certificate of optimality.
* ``rel_opt`` -- the paper's convergence metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .losses import Loss, get_loss


def serial_sdca(loss_name: str, X, y, *, lam, epochs=100, seed=0):
    """Exact serial SDCA on dense (X, y). Returns (w, alpha, history)."""
    loss = get_loss(loss_name)
    X, y = jnp.asarray(X), jnp.asarray(y)
    n, m = X.shape
    x_sq = jnp.sum(X * X, axis=1)
    key0 = jax.random.PRNGKey(seed)

    @jax.jit
    def epoch(carry, key):
        alpha, w = carry
        idx = jax.random.permutation(key, n)

        def body(carry, i):
            alpha, w = carry
            d = loss.sdca_delta(alpha[i], x_sq[i], X[i] @ w, y[i],
                                lam, n, 1, beta=None)
            w = w + (d / (lam * n)) * X[i]
            alpha = alpha.at[i].add(d)
            return (alpha, w), None

        (alpha, w), _ = jax.lax.scan(body, (alpha, w), idx)
        return (alpha, w), None

    alpha = jnp.zeros((n,))
    w = jnp.zeros((m,))
    keys = jax.random.split(key0, epochs)
    (alpha, w), _ = jax.lax.scan(epoch, (alpha, w), keys)
    return w, alpha


def duality_gap(loss_name: str, X, y, w, alpha, lam):
    loss = get_loss(loss_name)
    return (loss.objective(X, y, w, lam)
            - loss.dual_objective(X, y, alpha, lam))


def rel_opt(f_t, f_star):
    """The paper's relative optimality difference (f_t - f*) / f*."""
    return (f_t - f_star) / abs(f_star)


def objective(loss_name: str, X, y, w, lam):
    return get_loss(loss_name).objective(X, y, w, lam)
