"""Doubly distributed P x Q partitioning of the training matrix.

The paper stores block ``x_[p,q]`` (observations p, features q) on worker
(p, q) of a K = P*Q node cluster.  We provide:

  * ``DoublyPartitioned`` -- a padded, block-major view of (X, y) shaped
    ``(P, Q, n_p, m_q)`` used by the *simulated* grid execution (vmap over
    cells on one device) and, row/column-sharded, by the shard_map execution
    where each device holds exactly one ``(n_p, m_q)`` block in HBM.
  * helpers to scatter/gather the global primal/dual vectors to/from blocks.

Padding: rows are padded with x = 0 and mask = 0 so they contribute nothing
to objectives/gradients; columns are padded with zero features (harmless --
the corresponding w coordinates stay 0 under every update rule because the
data column is identically zero, and the regularizer only shrinks them).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def _ceil_to(x: int, k: int) -> int:
    return (x + k - 1) // k * k


@dataclasses.dataclass(frozen=True)
class DoublyPartitioned:
    """Block-major view of the training set."""

    x_blocks: jnp.ndarray   # (P, Q, n_p, m_q)
    y_blocks: jnp.ndarray   # (P, n_p)
    mask: jnp.ndarray       # (P, n_p)   1.0 = real row, 0.0 = padding
    n: int                  # true number of observations
    m: int                  # true number of features
    P: int
    Q: int

    @property
    def n_p(self) -> int:
        return self.x_blocks.shape[2]

    @property
    def m_q(self) -> int:
        return self.x_blocks.shape[3]

    # ---- global <-> block conversions -------------------------------------
    def w_to_blocks(self, w):
        """(m,) -> (Q, m_q), zero-padding the tail."""
        m_pad = self.Q * self.m_q
        wp = jnp.zeros((m_pad,), w.dtype).at[: self.m].set(w)
        return wp.reshape(self.Q, self.m_q)

    def w_from_blocks(self, w_blocks):
        """(Q, m_q) -> (m,)."""
        return w_blocks.reshape(-1)[: self.m]

    def alpha_to_blocks(self, alpha):
        n_pad = self.P * self.n_p
        ap = jnp.zeros((n_pad,), alpha.dtype).at[: self.n].set(alpha)
        return ap.reshape(self.P, self.n_p)

    def alpha_from_blocks(self, alpha_blocks):
        return alpha_blocks.reshape(-1)[: self.n]

    def dense(self):
        """Reassemble the (possibly padded) dense matrix (n, m) and labels."""
        Xp = jnp.transpose(self.x_blocks, (0, 2, 1, 3)).reshape(
            self.P * self.n_p, self.Q * self.m_q
        )
        return Xp[: self.n, : self.m], self.y_blocks.reshape(-1)[: self.n]


def partition(X, y, P: int, Q: int, *,
              m_multiple: int | None = None) -> DoublyPartitioned:
    """Split (X, y) into the P x Q doubly distributed block grid.

    ``m_multiple`` pads the feature dimension to a multiple of that value
    instead of just Q.  The solver framework passes P*Q so that RADiSA's
    P sub-blocks divide every feature block and both engines see
    bit-identical blocks.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    if m_multiple is not None and m_multiple % Q:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Q}")
    n, m = X.shape
    n_pad, m_pad = _ceil_to(n, P), _ceil_to(m, m_multiple or Q)
    n_p, m_q = n_pad // P, m_pad // Q

    Xp = jnp.zeros((n_pad, m_pad), X.dtype).at[:n, :m].set(X)
    yp = jnp.zeros((n_pad,), y.dtype).at[:n].set(y)
    mask = jnp.zeros((n_pad,), X.dtype).at[:n].set(1.0)

    x_blocks = Xp.reshape(P, n_p, Q, m_q).transpose(0, 2, 1, 3)
    y_blocks = yp.reshape(P, n_p)
    mask_blocks = mask.reshape(P, n_p)
    return DoublyPartitioned(x_blocks, y_blocks, mask_blocks, n, m, P, Q)


def subblock_slices(m_q: int, P: int):
    """RADiSA pre-splits every feature block [., q] into P sub-blocks.

    Returns the sub-block width (padded so P | m_q is not required at call
    sites -- callers should pass an m_q that P divides; ``partition`` +
    config code arranges this).
    """
    if m_q % P != 0:
        raise ValueError(f"m_q={m_q} must be divisible by P={P} for RADiSA; "
                         "repartition with padding first")
    return m_q // P


def numpy_partition_indices(n: int, P: int):
    """Host-side helper: index ranges of each observation partition."""
    n_pad = _ceil_to(n, P)
    n_p = n_pad // P
    return [(p * n_p, min((p + 1) * n_p, n)) for p in range(P)]
