"""Doubly distributed P x Q partitioning of the training matrix.

The paper stores block ``x_[p,q]`` (observations p, features q) on worker
(p, q) of a K = P*Q node cluster.  We provide:

  * ``DoublyPartitioned`` -- a padded, block-major view of (X, y) shaped
    ``(P, Q, n_p, m_q)`` used by the *simulated* grid execution (vmap over
    cells on one device) and, row/column-sharded, by the shard_map execution
    where each device holds exactly one ``(n_p, m_q)`` block in HBM.
  * helpers to scatter/gather the global primal/dual vectors to/from blocks.

Padding: rows are padded with x = 0 and mask = 0 so they contribute nothing
to objectives/gradients; columns are padded with zero features (harmless --
the corresponding w coordinates stay 0 under every update rule because the
data column is identically zero, and the regularizer only shrinks them).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def _ceil_to(x: int, k: int) -> int:
    return (x + k - 1) // k * k


@dataclasses.dataclass(frozen=True)
class DoublyPartitioned:
    """Block-major view of the training set."""

    x_blocks: jnp.ndarray   # (P, Q, n_p, m_q)
    y_blocks: jnp.ndarray   # (P, n_p)
    mask: jnp.ndarray       # (P, n_p)   1.0 = real row, 0.0 = padding
    n: int                  # true number of observations
    m: int                  # true number of features
    P: int
    Q: int

    @property
    def n_p(self) -> int:
        return self.x_blocks.shape[2]

    @property
    def m_q(self) -> int:
        return self.x_blocks.shape[3]

    # ---- global <-> block conversions -------------------------------------
    def w_to_blocks(self, w):
        """(m,) -> (Q, m_q), zero-padding the tail."""
        m_pad = self.Q * self.m_q
        wp = jnp.zeros((m_pad,), w.dtype).at[: self.m].set(w)
        return wp.reshape(self.Q, self.m_q)

    def w_from_blocks(self, w_blocks):
        """(Q, m_q) -> (m,)."""
        return w_blocks.reshape(-1)[: self.m]

    def alpha_to_blocks(self, alpha):
        n_pad = self.P * self.n_p
        ap = jnp.zeros((n_pad,), alpha.dtype).at[: self.n].set(alpha)
        return ap.reshape(self.P, self.n_p)

    def alpha_from_blocks(self, alpha_blocks):
        return alpha_blocks.reshape(-1)[: self.n]

    def dense(self):
        """Reassemble the (possibly padded) dense matrix (n, m) and labels."""
        Xp = jnp.transpose(self.x_blocks, (0, 2, 1, 3)).reshape(
            self.P * self.n_p, self.Q * self.m_q
        )
        return Xp[: self.n, : self.m], self.y_blocks.reshape(-1)[: self.n]


def partition(X, y, P: int, Q: int, *,
              m_multiple: int | None = None) -> DoublyPartitioned:
    """Split (X, y) into the P x Q doubly distributed block grid.

    ``m_multiple`` pads the feature dimension to a multiple of that value
    instead of just Q.  The solver framework passes P*Q so that RADiSA's
    P sub-blocks divide every feature block and both engines see
    bit-identical blocks.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    if m_multiple is not None and m_multiple % Q:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Q}")
    n, m = X.shape
    n_pad, m_pad = _ceil_to(n, P), _ceil_to(m, m_multiple or Q)
    n_p, m_q = n_pad // P, m_pad // Q

    Xp = jnp.zeros((n_pad, m_pad), X.dtype).at[:n, :m].set(X)
    yp = jnp.zeros((n_pad,), y.dtype).at[:n].set(y)
    mask = jnp.zeros((n_pad,), X.dtype).at[:n].set(1.0)

    x_blocks = Xp.reshape(P, n_p, Q, m_q).transpose(0, 2, 1, 3)
    y_blocks = yp.reshape(P, n_p)
    mask_blocks = mask.reshape(P, n_p)
    return DoublyPartitioned(x_blocks, y_blocks, mask_blocks, n, m, P, Q)


# ---------------------------------------------------------------------------
# sparse (padded ELL) cell format
# ---------------------------------------------------------------------------

def ell_gather(w, cols, vals):
    """Row inner products of an ELL block with a dense vector.

    ``w (m_q,)``, ``cols``/``vals`` ``(..., n_p, k)`` -> ``(..., n_p)``:
    each row's x_i . w as a gather of w at the row's column ids.
    Padding slots (col=0, val=0) read w[0] and contribute nothing.
    The single definition of the gather every sparse engine/cell uses.
    """
    return jnp.sum(vals * w[cols], axis=-1)


def ell_scatter_add(m_q: int, cols, vals, coef):
    """Column accumulation of an ELL cell: sum_i coef[i] * x_i -> (m_q,).

    ``cols``/``vals`` ``(n_p, k)``, ``coef (n_p,)``.  Scatter-ADD, so the
    duplicate index-0 padding slots (val=0) are inert.  The single
    definition of the scatter every sparse engine/cell uses (vmap it for
    block grids).
    """
    return jnp.zeros((m_q,), vals.dtype).at[cols].add(vals * coef[:, None])


def _ell_blocks(csr, y, P: int, Q: int, m_pad: int, k_multiple: int):
    """Host-side: bucket CSR rows into the P x Q grid as padded ELL cells.

    For every (p, q) cell each local row stores at most ``k`` entries as
    (block-local column id, value); ``k`` is the max per-cell-row nonzero
    count over the WHOLE grid, rounded up to ``k_multiple`` (lane
    alignment for the TPU kernels).  Padding slots use (col=0, val=0.0):
    every consumer either gathers (x0 reads are harmless) or scatter-ADDs
    (zero increments are inert), so the duplicate index-0 slots never
    change a result.

    Returns numpy ``cols (P, Q, n_p, k) int32``, ``vals (..., k) f32``,
    ``y_blocks (P, n_p)``, ``mask (P, n_p)``.
    """
    import numpy as onp
    n = csr.shape[0]
    n_pad = _ceil_to(n, P)
    n_p, m_q = n_pad // P, m_pad // Q

    # per (row, q) nonzero count -> global k
    q_of = onp.minimum(csr.indices // m_q, Q - 1)
    row = csr.row_ids()
    counts = onp.zeros((n, Q), dtype=onp.int64)
    onp.add.at(counts, (row, q_of), 1)
    k_max = int(counts.max()) if counts.size else 0
    k = max(_ceil_to(max(k_max, 1), k_multiple), k_multiple)

    cols = onp.zeros((P, Q, n_p, k), dtype=onp.int32)
    vals = onp.zeros((P, Q, n_p, k), dtype=onp.float32)
    # ELL slot of each entry = its rank within its (row, q) group (stable
    # sort keeps the CSR entry order inside every group)
    pair = row * Q + q_of
    perm = onp.argsort(pair, kind="stable")
    sp = pair[perm]
    is_start = onp.r_[True, sp[1:] != sp[:-1]] if sp.size else \
        onp.zeros((0,), dtype=bool)
    run_id = onp.cumsum(is_start) - 1
    run_starts = onp.flatnonzero(is_start)
    ranks = onp.empty((csr.nnz,), dtype=onp.int64)
    ranks[perm] = onp.arange(csr.nnz, dtype=onp.int64) - run_starts[run_id]
    p_of = row // n_p
    r_loc = row % n_p
    c_loc = csr.indices - q_of * m_q
    cols[p_of, q_of, r_loc, ranks] = c_loc.astype(onp.int32)
    vals[p_of, q_of, r_loc, ranks] = csr.data.astype(onp.float32)

    yp = onp.zeros((n_pad,), dtype=onp.float32)
    yp[:n] = onp.asarray(y, dtype=onp.float32)
    maskp = onp.zeros((n_pad,), dtype=onp.float32)
    maskp[:n] = 1.0
    return cols, vals, yp.reshape(P, n_p), maskp.reshape(P, n_p)


@dataclasses.dataclass(frozen=True)
class SparseDoublyPartitioned:
    """Block-major padded-ELL view of a sparse training set.

    The per-(p, q) cell is ``cols[p, q] (n_p, k) int32`` (block-local
    column ids in [0, m_q)) + ``vals[p, q] (n_p, k) f32``; peak block
    memory scales with the nonzero count (k ~= max cell-row nnz), not
    with m_q -- that is the whole point.
    """

    cols: jnp.ndarray       # (P, Q, n_p, k) int32, block-local columns
    vals: jnp.ndarray       # (P, Q, n_p, k) f32
    y_blocks: jnp.ndarray   # (P, n_p)
    mask: jnp.ndarray       # (P, n_p)   1.0 = real row, 0.0 = padding
    n: int                  # true number of observations
    m: int                  # true number of features
    m_q: int                # padded feature-block width
    P: int
    Q: int

    @property
    def n_p(self) -> int:
        return self.cols.shape[2]

    @property
    def k(self) -> int:
        return self.cols.shape[3]

    # ---- global <-> block conversions (same padding rule as dense) --------
    def w_to_blocks(self, w):
        m_pad = self.Q * self.m_q
        wp = jnp.zeros((m_pad,), w.dtype).at[: self.m].set(w)
        return wp.reshape(self.Q, self.m_q)

    def w_from_blocks(self, w_blocks):
        return w_blocks.reshape(-1)[: self.m]

    def alpha_to_blocks(self, alpha):
        n_pad = self.P * self.n_p
        ap = jnp.zeros((n_pad,), alpha.dtype).at[: self.n].set(alpha)
        return ap.reshape(self.P, self.n_p)

    def alpha_from_blocks(self, alpha_blocks):
        return alpha_blocks.reshape(-1)[: self.n]

    def dense(self):
        """Reassemble the dense (n, m) matrix and labels (tests only)."""
        Pn, Qn, n_p, k = self.cols.shape
        X = np.zeros((Pn * n_p, Qn * self.m_q), dtype=np.float32)
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals)
        p, q, r, s = np.meshgrid(np.arange(Pn), np.arange(Qn),
                                 np.arange(n_p), np.arange(k),
                                 indexing="ij")
        np.add.at(X, (p * n_p + r, q * self.m_q + cols), vals)
        y = np.asarray(self.y_blocks).reshape(-1)
        return X[: self.n, : self.m], y[: self.n]


def partition_sparse(X, y, P: int, Q: int, *, m_multiple: int | None = None,
                     k_multiple: int = 8) -> SparseDoublyPartitioned:
    """Split (X, y) into the sparse P x Q padded-ELL block grid.

    ``X`` may be a :class:`~repro.data.sparse.CSRMatrix` (preferred --
    never densifies) or a dense array (converted row-wise).  The padding
    rule matches ``partition(..., m_multiple=...)`` exactly, so sparse
    and dense runs see the same logical blocks.
    """
    from repro.data.sparse import CSRMatrix, csr_from_dense
    if not isinstance(X, CSRMatrix):
        X = csr_from_dense(np.asarray(X))
    if m_multiple is not None and m_multiple % Q:
        raise ValueError(f"m_multiple={m_multiple} not a multiple of Q={Q}")
    n, m = X.shape
    m_pad = _ceil_to(m, m_multiple or Q)
    cols, vals, y_blocks, mask = _ell_blocks(X, y, P, Q, m_pad, k_multiple)
    return SparseDoublyPartitioned(
        cols=jnp.asarray(cols), vals=jnp.asarray(vals),
        y_blocks=jnp.asarray(y_blocks), mask=jnp.asarray(mask),
        n=n, m=m, m_q=m_pad // Q, P=P, Q=Q)


def numpy_partition_indices(n: int, P: int):
    """Host-side helper: index ranges of each observation partition."""
    n_pad = _ceil_to(n, P)
    n_p = n_pad // P
    return [(p * n_p, min((p + 1) * n_p, n)) for p in range(P)]
