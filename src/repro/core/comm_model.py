"""Alpha-beta wire-time model for the declared collectives.

The CommSchedule (Engine API v2) names every cross-cell reduction and
``wire_accounting`` (PR 5) already reports *exact bytes per step*; this
module turns those bytes into **predicted seconds** on a modelled
interconnect, so the fig benchmarks can report predicted-vs-measured
wall-clock per codec x tau x topology instead of just counting bytes.

Model
-----
A link is ``(alpha, beta)``: per-message latency in seconds and
per-byte inverse bandwidth in s/byte.  For an allreduce of ``n`` bytes
over ``k`` participants:

  * ring:  ``T = 2 (k - 1) alpha + 2 (k - 1)/k * n * beta``
           (reduce-scatter + all-gather, the classic 2(k-1)/k factor --
           bandwidth-optimal, latency grows linearly in k);
  * tree:  ``T = 2 ceil(log2 k) (alpha + n beta)``
           (recursive halving/doubling counted as log-depth full-vector
           hops -- latency-optimal, pays the full vector per hop).

For an allgather of ``n`` bytes contributed per participant:

  * ring:  ``T = (k - 1) (alpha + n beta)``
  * tree:  ``T = ceil(log2 k) alpha + (k - 1) n beta``

``pmean`` costs the same wire time as ``psum`` (the division is local).

Topology
--------
``Topology`` describes a two-level machine: ``pods`` groups along one
logical axis (default ``"data"``), a fat intra-pod link and a thin
inter-pod link, and an optional cross-pod codec.  A collective over the
pod-split axis is executed hierarchically (full-precision reduce
within the pod, codec-compressed across pods -- exactly what the
hierarchical executors in :mod:`repro.core.comm` do), and its predicted
time is the sum of the two stages.  Collectives over other axes ride
the intra-pod link.

Calibration
-----------
``fit_link`` least-squares fits ``(alpha, beta)`` from measured
per-step ``comm_s`` samples (each sample: a schedule's accounting dict
plus a measured time), clamping both at >= 0.  The fig benchmarks fit
on their own sweep and report per-cell predicted seconds + relative
error, which is how "predictions within 15% of measured" is checked.

Overlap
-------
``overlap_split`` applies the PR 6 phase attribution to the overlap
engine: with ``tau`` steps of local work available to hide the wire,
``hidden = min(comm_s, tau * local_s)`` and the *exposed* remainder is
what lands on the critical path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "LinkModel", "Topology", "INTRA_POD_LINK", "INTER_POD_LINK",
    "collective_time", "predict_comm_s", "fit_link", "overlap_split",
    "hierarchical_accounting",
]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One interconnect link: ``alpha_s`` per-message latency and
    ``beta_s_per_byte`` inverse bandwidth."""

    alpha_s: float
    beta_s_per_byte: float
    name: str = "link"

    def __post_init__(self):
        if self.alpha_s < 0 or self.beta_s_per_byte < 0:
            raise ValueError("LinkModel parameters must be >= 0")

    @property
    def bandwidth_gbps(self) -> float:
        """Bidirectional bandwidth implied by beta, in GB/s."""
        if self.beta_s_per_byte == 0:
            return math.inf
        return 1.0 / self.beta_s_per_byte / 1e9


# Defaults roughly shaped like a TPU/GPU pod: a fat intra-pod ICI/NVLink
# link and a thin inter-pod DCN link.  These are *priors* -- the fig
# benchmarks re-fit alpha/beta from their own measured comm_s.
INTRA_POD_LINK = LinkModel(1e-6, 1.0 / 300e9, name="intra_pod")
INTER_POD_LINK = LinkModel(10e-6, 1.0 / 25e9, name="inter_pod")


def _allreduce_time(nbytes: float, k: int, link: LinkModel,
                    algo: str) -> float:
    if k <= 1 or nbytes <= 0:
        return 0.0
    a, b = link.alpha_s, link.beta_s_per_byte
    if algo == "ring":
        return 2 * (k - 1) * a + 2 * (k - 1) / k * nbytes * b
    if algo == "tree":
        h = math.ceil(math.log2(k))
        return 2 * h * (a + nbytes * b)
    raise ValueError(f"unknown collective algorithm {algo!r} "
                     "(expected 'ring' or 'tree')")


def _allgather_time(nbytes: float, k: int, link: LinkModel,
                    algo: str) -> float:
    if k <= 1 or nbytes <= 0:
        return 0.0
    a, b = link.alpha_s, link.beta_s_per_byte
    if algo == "ring":
        return (k - 1) * (a + nbytes * b)
    if algo == "tree":
        return math.ceil(math.log2(k)) * a + (k - 1) * nbytes * b
    raise ValueError(f"unknown collective algorithm {algo!r} "
                     "(expected 'ring' or 'tree')")


def collective_time(op: str, nbytes: float, k: int, link: LinkModel,
                    algo: str = "ring") -> float:
    """Predicted seconds for one ``op`` of ``nbytes`` (per participant)
    over ``k`` participants on ``link``."""
    if op in ("psum", "pmean"):
        return _allreduce_time(nbytes, k, link, algo)
    if op == "allgather":
        return _allgather_time(nbytes, k, link, algo)
    raise ValueError(f"unknown collective op {op!r}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level machine model for the hierarchical executors.

    ``pods`` groups along logical ``axis`` (the leading mesh/vmap axis
    of the two-level split); ``codec`` names the cross-pod payload
    codec ("identity" disables compression); ``algo`` selects the
    wire-time formula.  ``pods == 1`` is the flat machine (the
    executors then take the ordinary single-psum path).
    """

    pods: int = 1
    codec: str = "identity"
    algo: str = "ring"
    axis: str = "data"
    intra: LinkModel = INTRA_POD_LINK
    inter: LinkModel = INTER_POD_LINK

    def __post_init__(self):
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {self.pods}")
        if self.algo not in ("ring", "tree"):
            raise ValueError(f"algo must be 'ring' or 'tree', "
                             f"got {self.algo!r}")

    @classmethod
    def from_spec(cls, spec) -> "Topology":
        """Parse ``"pods=2"``, ``"pods=4:int8"``, ``"pods=2:int8:tree"``
        (codec and algo optional, in that order)."""
        if isinstance(spec, Topology):
            return spec
        if not isinstance(spec, str) or not spec.strip():
            raise ValueError(f"bad topology spec {spec!r}")
        parts = [p.strip() for p in spec.strip().split(":")]
        head = parts[0]
        if not head.startswith("pods="):
            raise ValueError(
                f"bad topology spec {spec!r}: expected 'pods=G[:codec[:algo]]'")
        try:
            pods = int(head[len("pods="):])
        except ValueError:
            raise ValueError(f"bad pod count in topology spec {spec!r}")
        codec, algo = "identity", "ring"
        if len(parts) >= 2 and parts[1]:
            codec = parts[1]
        if len(parts) >= 3 and parts[2]:
            algo = parts[2]
        if len(parts) > 3:
            raise ValueError(f"bad topology spec {spec!r}: too many fields")
        return cls(pods=pods, codec=codec, algo=algo)

    @property
    def spec(self) -> str:
        return f"pods={self.pods}:{self.codec}:{self.algo}"

    def hierarchical(self) -> bool:
        return self.pods > 1


def as_topology(spec) -> Optional[Topology]:
    """None | spec-string | Topology -> Optional[Topology]."""
    if spec is None:
        return None
    return Topology.from_spec(spec)


def _codec_nbytes(codec_name: str, nbytes: float) -> float:
    """Cross-pod payload bytes after the topology codec.  Uses the
    codec registry's per-element payload accounting on a synthetic f32
    vector of the same byte size (collectives here are f32 payloads)."""
    if codec_name in (None, "identity"):
        return nbytes
    from .compress import get_codec
    codec = get_codec(codec_name)
    numel = max(int(round(nbytes / 4.0)), 1)
    return float(codec.payload_nbytes((numel,), "float32"))


def hierarchical_accounting(acct: dict, topology: Optional[Topology],
                            sizes: Dict[str, int]) -> dict:
    """Rewrite a ``wire_accounting`` dict for a two-level topology.

    For each collective over the pod-split axis, the flat bytes become
    an intra-pod stage (full precision, unchanged per-cell bytes) plus
    an inter-pod stage (one codec-compressed contribution per pod).
    Other collectives are unchanged.  Adds ``intra_bytes_per_step`` /
    ``inter_bytes_per_step`` totals so the emitters can report both
    tiers; ``bytes_per_step`` stays the total.
    """
    if topology is None or not topology.hierarchical():
        return acct
    out = {k: v for k, v in acct.items() if k != "collectives"}
    out["collectives"] = {}
    out["topology"] = topology.spec
    total = intra_total = inter_total = 0.0
    for name, c in acct["collectives"].items():
        c = dict(c)
        if c.get("axis") == topology.axis and sizes.get(topology.axis, 1) > 1:
            per_cell = c["payload_bytes_per_cell"]   # post-policy payload
            k_total = sizes[topology.axis]
            pods = topology.pods
            cells = c["cells"]
            other = cells // k_total       # independent reductions in flight
            intra = per_cell * k_total * other
            inter_per_pod = _codec_nbytes(topology.codec, per_cell)
            inter = inter_per_pod * pods * other
            c["intra_bytes_per_step"] = intra
            c["inter_bytes_per_step"] = inter
            c["bytes_per_step"] = intra + inter
            intra_total += intra
            inter_total += inter
        else:
            intra_total += c["bytes_per_step"]
            c["intra_bytes_per_step"] = c["bytes_per_step"]
            c["inter_bytes_per_step"] = 0.0
        total += c["bytes_per_step"]
        out["collectives"][name] = c
    out["bytes_per_step"] = total
    out["intra_bytes_per_step"] = intra_total
    out["inter_bytes_per_step"] = inter_total
    return out


def predict_comm_s(acct: dict, sizes: Dict[str, int], *,
                   topology: Optional[Topology] = None,
                   link: LinkModel = INTRA_POD_LINK,
                   algo: str = "ring") -> dict:
    """Predicted per-step communication seconds for a schedule.

    ``acct`` is the ``wire_accounting`` dict attached to every
    ``EngineProgram`` (``prog.comm_bytes``); ``sizes`` the logical axis
    extents (``{"data": P, "model": Q}``).  Collectives are serial
    within a step (each one is a data dependency of the next cell
    phase), so the total is the sum over collectives.  With a
    hierarchical topology the pod-split collectives cost
    ``intra_stage + inter_stage``; independent reductions over the
    *other* axis are modelled as perfectly parallel (disjoint links).

    Returns ``{"collectives": {name: {...}}, "total_s": float}``.
    """
    out: dict = {"collectives": {}, "total_s": 0.0, "algo": algo}
    for name, c in acct["collectives"].items():
        axis = c.get("axis")
        k = int(sizes.get(axis, 1))
        per_cell = float(c["payload_bytes_per_cell"])
        op = c.get("op", "psum")
        entry: dict = {"axis": axis, "k": k, "bytes": per_cell}
        if (topology is not None and topology.hierarchical()
                and axis == topology.axis and k > 1):
            k_in = k // topology.pods
            intra = collective_time(op, per_cell, k_in, topology.intra,
                                    topology.algo)
            inter_bytes = _codec_nbytes(topology.codec, per_cell)
            inter = collective_time(op, inter_bytes, topology.pods,
                                    topology.inter, topology.algo)
            entry.update(intra_s=intra, inter_s=inter,
                         wire_s=intra + inter)
        else:
            tlink = link if topology is None else topology.intra
            talgo = algo if topology is None else topology.algo
            entry["wire_s"] = collective_time(op, per_cell, k, tlink, talgo)
        out["collectives"][name] = entry
        out["total_s"] += entry["wire_s"]
    return out


def _coeffs(acct: dict, sizes: Dict[str, int], algo: str) -> Tuple[float,
                                                                   float]:
    """(alpha, beta) coefficients of the linear model for one schedule:
    predicted_s = A * alpha + B * beta on a single flat link."""
    A = B = 0.0
    for c in acct["collectives"].values():
        k = int(sizes.get(c.get("axis"), 1))
        n = float(c["payload_bytes_per_cell"])
        if k <= 1 or n <= 0:
            continue
        op = c.get("op", "psum")
        if op in ("psum", "pmean"):
            if algo == "ring":
                A += 2 * (k - 1)
                B += 2 * (k - 1) / k * n
            else:
                A += 2 * math.ceil(math.log2(k))
                B += 2 * math.ceil(math.log2(k)) * n
        else:                                   # allgather
            if algo == "ring":
                A += (k - 1)
                B += (k - 1) * n
            else:
                A += math.ceil(math.log2(k))
                B += (k - 1) * n
    return A, B


def fit_link(samples: Sequence[Tuple[dict, Dict[str, int], float]], *,
             algo: str = "ring", name: str = "fitted") -> LinkModel:
    """Least-squares fit of ``(alpha, beta)`` from measured comm times.

    Each sample is ``(acct, sizes, measured_comm_s)``.  Solves the 2x2
    normal equations, clamps both parameters at >= 0 (re-solving the
    1-parameter problem when one clamps), so the result is always a
    valid :class:`LinkModel`.  With fewer than two samples (or a
    singular system) it falls back to a pure-bandwidth fit.
    """
    rows: List[Tuple[float, float, float]] = []
    for acct, sizes, t in samples:
        A, B = _coeffs(acct, sizes, algo)
        if A > 0 or B > 0:
            rows.append((A, B, max(float(t), 0.0)))
    if not rows:
        return LinkModel(0.0, 0.0, name=name)
    saa = sum(a * a for a, _, _ in rows)
    sbb = sum(b * b for _, b, _ in rows)
    sab = sum(a * b for a, b, _ in rows)
    sat = sum(a * t for a, _, t in rows)
    sbt = sum(b * t for _, b, t in rows)
    det = saa * sbb - sab * sab
    if det > 1e-30 * max(saa * sbb, 1e-30):
        alpha = (sat * sbb - sbt * sab) / det
        beta = (saa * sbt - sab * sat) / det
    else:
        alpha, beta = -1.0, -1.0                # force the clamp path
    if alpha < 0 or beta < 0:
        # Clamp + re-solve each 1-parameter problem, keep the better fit.
        cand = []
        if sbb > 0:
            cand.append((0.0, max(sbt / sbb, 0.0)))
        if saa > 0:
            cand.append((max(sat / saa, 0.0), 0.0))
        if not cand:
            return LinkModel(0.0, 0.0, name=name)

        def sse(ab):
            a0, b0 = ab
            return sum((a * a0 + b * b0 - t) ** 2 for a, b, t in rows)
        alpha, beta = min(cand, key=sse)
    return LinkModel(float(alpha), float(beta), name=name)


def overlap_split(comm_s: float, local_s: float, tau: int) -> dict:
    """Split measured ``comm_s`` into hidden vs exposed under the
    overlap engine: tau steps of local solve are available to hide the
    wire, so ``hidden = min(comm_s, tau * local_s)``.  tau = 0 (or the
    sync/async engines) exposes everything."""
    comm_s = max(float(comm_s), 0.0)
    hidden = min(comm_s, max(int(tau), 0) * max(float(local_s), 0.0))
    return {"comm_hidden_s": hidden, "comm_exposed_s": comm_s - hidden}
