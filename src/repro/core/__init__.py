"""Core: the paper's doubly distributed optimization algorithms."""
from .admm import (ADMMConfig, admm_distributed,
                   admm_setup_simulated, admm_simulated)
from .comm import Comm, CommSchedule, OverlapComm, StaleComm, SyncComm
from .comm_model import (LinkModel, Topology, as_topology, fit_link,
                         overlap_split, predict_comm_s)
from .compress import (CompressedComm, CompressionPolicy,
                       CompressionSchedule, as_compression, as_policy,
                       available_codecs, get_codec, wire_accounting)
from .d3ca import (D3CAConfig, d3ca_distributed, d3ca_simulated,
                   make_d3ca_step, make_d3ca_step_sparse)
from .engines import (CellProgram, EngineProgram, comm_accounting, drive,
                      grid_program, mesh_program, prepare_shard_map,
                      prepare_shard_map_sparse)
from .losses import LOSSES, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        partition, partition_sparse)
from .radisa import (RADiSAConfig, make_radisa_step, make_radisa_step_sparse,
                     radisa_distributed, radisa_simulated)
from .reference import duality_gap, objective, rel_opt, serial_sdca
from .sfk import SFKConfig, make_sfk_step, sfk_simulated
from .solver import (BLOCK_FORMATS, ENGINES, LOCAL_BACKENDS, SolveResult,
                     Solver, available_solvers, get_solver, register_solver)

__all__ = [
    "ADMMConfig", "admm_distributed", "admm_setup_simulated",
    "admm_simulated",
    "Comm", "CommSchedule", "OverlapComm", "StaleComm", "SyncComm",
    "LinkModel", "Topology", "as_topology", "fit_link", "overlap_split",
    "predict_comm_s",
    "CompressedComm", "CompressionPolicy", "CompressionSchedule",
    "as_compression", "as_policy", "available_codecs",
    "get_codec", "wire_accounting",
    "D3CAConfig", "d3ca_distributed", "d3ca_simulated", "make_d3ca_step",
    "make_d3ca_step_sparse",
    "CellProgram", "EngineProgram", "comm_accounting", "drive",
    "grid_program", "mesh_program",
    "prepare_shard_map", "prepare_shard_map_sparse",
    "LOSSES", "get_loss",
    "DoublyPartitioned", "SparseDoublyPartitioned", "partition",
    "partition_sparse",
    "RADiSAConfig", "make_radisa_step", "make_radisa_step_sparse",
    "radisa_distributed", "radisa_simulated",
    "duality_gap", "objective", "rel_opt", "serial_sdca",
    "SFKConfig", "make_sfk_step", "sfk_simulated",
    "BLOCK_FORMATS", "ENGINES", "LOCAL_BACKENDS", "SolveResult", "Solver",
    "available_solvers", "get_solver", "register_solver",
]
