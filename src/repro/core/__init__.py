"""Core: the paper's doubly distributed optimization algorithms."""
from .admm import (ADMMConfig, admm_distributed,
                   admm_setup_simulated, admm_simulated)
from .d3ca import D3CAConfig, d3ca_distributed, d3ca_simulated, make_d3ca_step
from .losses import LOSSES, get_loss
from .partition import DoublyPartitioned, partition
from .radisa import (RADiSAConfig, make_radisa_step, radisa_distributed,
                     radisa_simulated)
from .reference import duality_gap, objective, rel_opt, serial_sdca

__all__ = [
    "ADMMConfig", "admm_distributed", "admm_setup_simulated",
    "admm_simulated",
    "D3CAConfig", "d3ca_distributed", "d3ca_simulated", "make_d3ca_step",
    "LOSSES", "get_loss",
    "DoublyPartitioned", "partition",
    "RADiSAConfig", "make_radisa_step", "radisa_distributed",
    "radisa_simulated",
    "duality_gap", "objective", "rel_opt", "serial_sdca",
]
