"""SFK -- the stochastic Fang--Klabjan scheme (arXiv 1803.11287).

Fang & Klabjan's follow-up to the source paper targets the streaming
regime: observations keep arriving, so a full anchor-gradient pass over
every row per outer iteration (RADiSA) is wasted work.  Their sampling
scheme keeps the doubly distributed P x Q layout but makes the outer
iteration *stochastic in the observations*: every round, each row
partition draws a uniform random subset of its local rows, the anchor
gradient becomes an unbiased minibatch estimate over just that subset,
and the local variance-reduced inner loop only moves on sampled rows.

Per outer iteration t, each cell (p, q):

  1. draws the row subsample ``S_p(t)`` (Bernoulli ``sample_frac``;
     the PRNG key is folded by (t, p) only, so all Q feature blocks of
     one row partition agree on the subset -- the same trick D3CA uses
     for its coordinate order);
  2. anchor inner products ``z = psum_q x_b @ w_b`` (every row, exact:
     margins are cheap, gradients are not);
  3. minibatch anchor gradient ``mu = psum_p g(z)|_S @ x_b / (n * s)``
     -- dividing by the *expected* sample count ``n * sample_frac``
     keeps the estimate unbiased and engine-independent;
  4. L local SVRG-style steps on a randomly assigned disjoint feature
     sub-block (shared permutation, exactly RADiSA's recombination),
     with the row mask restricted to ``S_p(t)``: unsampled rows
     contribute only the anchor-drift term, sampled rows the full
     variance-reduced correction;
  5. disjoint sub-block deltas are concatenated by ``psum_p``.

The whole scheme is ONE :class:`~repro.core.engines.CellProgram` with
the same CommSchedule shape as RADiSA::

    CommSchedule().psum("z", axis="model")
                  .psum("grad", axis="data")
                  .psum("dw", axis="data")

so every engine (simulated / shard_map / async / overlap), both local
backends (the SVRG Pallas kernel runs unchanged -- sampling only edits
the row mask) and both block formats execute it via the generic
executors, and the full equivalence grid of the other three solvers
applies verbatim.

Approximation note: PAPERS.md carries only the title/abstract of
arXiv 1803.11287, so this module implements the *scheme* -- per-round
uniform observation subsampling feeding a variance-reduced doubly
distributed update -- not a line-by-line transcription of their
pseudocode.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .comm import CommSchedule
from .engines import (CellProgram, EngineProgram, SparseShardMapData,
                      cached_build, drive_with_callback, grid_bind_state,
                      grid_program, mesh_local_step, mesh_program,
                      mesh_step_fn, overlap_donates)
from .local import local_svrg, local_svrg_sparse
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_gather, ell_scatter_add)
from .radisa import _check_subblocks


@dataclasses.dataclass(frozen=True)
class SFKConfig:
    """Knobs of the stochastic Fang--Klabjan solver.

    Attributes:
      lam: global L2 regularization strength.
      L: inner SVRG steps per outer iteration (default: n_p).
      gamma: step-size constant; eta_t = gamma / (1 + sqrt(t - 1)).
      sample_frac: per-round Bernoulli row-sampling probability in
        (0, 1]; 1.0 degenerates to a full-gradient RADiSA-style round.
      outer_iters: outer iterations T.
      seed: PRNG seed (drives sampling, sub-block permutation and the
        inner-loop row draws identically under every engine).
    """
    lam: float = 1e-3
    L: int | None = None
    gamma: float = 1.0
    sample_frac: float = 0.5
    outer_iters: int = 20
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.sample_frac <= 1.0:
            raise ValueError(f"sample_frac={self.sample_frac} must be in "
                             "(0, 1]")

    def eta(self, t):
        return self.gamma / (1.0 + jnp.sqrt(jnp.maximum(t - 1.0, 0.0)))


def sfk_schedule() -> CommSchedule:
    """SFK's three reduction points (same shape as RADiSA's: the
    sampling scheme changes what feeds the wire, not the wire)."""
    return (CommSchedule()
            .psum("z", axis="model")
            .psum("grad", axis="data")
            .psum("dw", axis="data"))


def sfk_cell_program(loss: Loss, cfg: SFKConfig, *, n: int, n_p: int,
                     m_q: int, sparse: bool = False,
                     local_backend: str = "ref",
                     per_problem: bool = False) -> CellProgram:
    """The ONE SFK program every engine executes.

    Per-cell data: ``(key0, x_b[, vals_b], y_b, mask_b)``; per-cell
    state: ``w_b (m_q,)``.  Requires P | m_q (the unified Solver API
    pads the feature dimension to a multiple of P*Q).
    ``per_problem=True`` appends runtime ``(lam_v, n_v)`` scalars to the
    data tuple (the fleet path).
    """
    lam = cfg.lam
    L = cfg.L or n_p

    def cell(comm, t, data, state):
        if per_problem:
            *data, lam_t, n_t = data
        else:
            lam_t, n_t = lam, n
        if sparse:
            key0, cols_b, vals_b, y_b, mask_b = data
            x_parts = (cols_b, vals_b)
            local = local_svrg_sparse
        else:
            key0, x_b, y_b, mask_b = data
            x_parts = (x_b,)
            local = local_svrg
        w_b = state
        Pn = comm.axis_size("data")
        Qn = comm.axis_size("model")
        m_sub = m_q // Pn
        eta = cfg.eta(t)
        key_t = jax.random.fold_in(key0, t)
        p = comm.axis_index("data")
        q = comm.axis_index("model")
        # (1) row subsample S_p(t): folded by (t, p) ONLY, so every
        # feature block of partition p draws the same subset
        key_s = jax.random.fold_in(jax.random.fold_in(key_t, 2), p)
        smask = mask_b * (jax.random.uniform(key_s, mask_b.shape)
                          < cfg.sample_frac).astype(mask_b.dtype)
        # (2) anchor inner products (exact, every row)
        z_local = (ell_gather(w_b, cols_b, vals_b) if sparse
                   else x_b @ w_b)
        z = comm("z", z_local)                               # (n_p,)
        # (3) unbiased minibatch anchor gradient over the sample
        gz = loss.grad(z, y_b) * smask
        gcol = (ell_scatter_add(m_q, cols_b, vals_b, gz) if sparse
                else gz @ x_b)
        mu = comm("grad", gcol) / (n_t * cfg.sample_frac) + lam_t * w_b
        # (4) disjoint sub-block assignment + local inner loop on S_p(t)
        perm = jax.random.permutation(jax.random.fold_in(key_t, 0), Pn)
        key_pq = jax.random.fold_in(jax.random.fold_in(key_t, 1),
                                    p * Qn + q)
        lo = perm[p] * m_sub
        w_anchor = jax.lax.dynamic_slice(w_b, (lo,), (m_sub,))
        mu_sub = jax.lax.dynamic_slice(mu, (lo,), (m_sub,))
        w_new = local(loss, *x_parts, y_b, smask, z, w_anchor, mu_sub,
                      lam=lam_t, L=L, eta=eta, key=key_pq, lo=lo,
                      backend=local_backend)
        # (5) concatenate disjoint sub-block deltas
        delta = jnp.zeros_like(w_b)
        delta = jax.lax.dynamic_update_slice(delta, w_new - w_anchor, (lo,))
        return w_b + comm("dw", delta)

    x_specs = ((("data", "model"), ("data", "model")) if sparse
               else (("data", "model"),))
    pp_specs = (((), ()) if per_problem else ())
    data_specs = ((),) + x_specs + (("data",), ("data",)) + pp_specs
    state_specs = ("model",)
    return CellProgram(sfk_schedule(), cell, data_specs, state_specs)


# ----------------------------------------------------------------------------
# simulated grid engine
# ----------------------------------------------------------------------------

def sfk_simulated_program(loss: Loss, data: DoublyPartitioned,
                          cfg: SFKConfig, *, local_backend: str = "ref",
                          w0=None, compression=None,
                          topology=None, cache=None) -> EngineProgram:
    """Named-vmap grid engine.  State: w_blocks (Q, m_q).

    Requires P | m_q (pre-pad with ``partition(..., m_multiple=P*Q)``);
    ``data`` may be dense or sparse (padded-ELL cells); ``compression``
    routes the three declared collectives through their policy codecs.
    """
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    _check_subblocks(data.m_q, Pn, False)
    cellprog = sfk_cell_program(loss, cfg, n=data.n, n_p=data.n_p,
                                m_q=data.m_q, sparse=sparse,
                                local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (data.cols, data.vals) if sparse else (data.x_blocks,)
    gdata = (key0, *x_parts, data.y_blocks, data.mask)
    step = cached_build(cache, "step",
                        lambda: grid_program(cellprog, Pn, Qn,
                                             compression=compression,
                                             topology=topology))

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    full0, unwrap, acct = grid_bind_state(cellprog, gdata, w_init,
                                          Pn=Pn, Qn=Qn,
                                          compression=compression,
                                          topology=topology)
    local = cached_build(cache, "local",
                         lambda: grid_program(cellprog, Pn, Qn,
                                              comm_local=True))
    wrapped = full0 is not w_init
    return EngineProgram(
        state=full0,
        step=lambda t, s: step(t, gdata, s),
        w_of=lambda s: data.w_from_blocks(unwrap(s)),
        comm_bytes=acct,
        local_step=lambda t, s: local(t, gdata, unwrap(s)),
        ef_of=(lambda s: s[1]) if wrapped else None)


def sfk_simulated(loss_name: str, data: DoublyPartitioned, cfg: SFKConfig,
                  callback=None, local_backend: str = "ref"):
    """Convenience driver for the grid engine.  Returns the final w."""
    prog = sfk_simulated_program(get_loss(loss_name), data, cfg,
                                 local_backend=local_backend)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ----------------------------------------------------------------------------
# mesh engines (shard_map sync + bounded-staleness async + overlap)
# ----------------------------------------------------------------------------

def make_sfk_step(loss: Loss, mesh, cfg: SFKConfig, *, n: int, n_p: int,
                  m_q: int, data_axis: str = "data",
                  model_axis: str = "model", local_backend: str = "ref"):
    """Build the jitted distributed SFK outer step (sync reductions).

    Layouts: x (n, m) sharded (data, model); y/mask (n,) (data,);
    w (m,) (model,) replicated over data.
    """
    from .util import axes_size
    Pn = axes_size(mesh, data_axis)
    _check_subblocks(m_q, Pn, False)
    cellprog = sfk_cell_program(loss, cfg, n=n, n_p=n_p, m_q=m_q,
                                local_backend=local_backend)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(t, key0, x, y, mask, w):
        w_new, _ = run(t, (key0, x, y, mask), w, {})
        return w_new

    return jax.jit(step)


def sfk_shard_map_program(loss: Loss, sdata, cfg: SFKConfig, *,
                          local_backend: str = "ref", w0=None,
                          staleness: int = 0, compression=None,
                          overlap: bool = False,
                          topology=None, cache=None) -> EngineProgram:
    """Mesh engine.  State: (w (m_pad,) sharded over model, comm_state).
    ``staleness=tau > 0`` selects the bounded-staleness async policy;
    ``overlap``/``topology`` select donated-ring dispatch and the
    hierarchical pod-split reduction -- identical contracts to the
    other three solvers."""
    from .util import axes_size
    sparse = isinstance(sdata, SparseShardMapData)
    Pn = axes_size(sdata.mesh, sdata.data_axis)
    _check_subblocks(sdata.m_q, Pn, False)
    cellprog = sfk_cell_program(
        loss, cfg, n=sdata.n, n_p=sdata.n_p, m_q=sdata.m_q, sparse=sparse,
        local_backend=local_backend)
    key0 = jax.random.PRNGKey(cfg.seed)
    x_parts = (sdata.cols, sdata.vals) if sparse else (sdata.x,)
    mdata = (key0, *x_parts, sdata.y, sdata.mask)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    step, comm0, acct = cached_build(
        cache, "step",
        lambda: mesh_program(
            cellprog, sdata.mesh, mdata, w_init,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            staleness=staleness, compression=compression,
            overlap=overlap, topology=topology))
    local = cached_build(
        cache, "local",
        lambda: mesh_local_step(cellprog, sdata.mesh,
                                data_axis=sdata.data_axis,
                                model_axis=sdata.model_axis))
    is_overlap = bool(overlap) and staleness > 0
    return EngineProgram(
        state=(w_init, comm0),
        step=lambda t, s: step(t, mdata, s),
        w_of=lambda s: s[0][: sdata.m],
        comm_bytes=acct,
        local_step=lambda t, s: local(t, mdata, s[0]),
        ef_of=(lambda s: s[1]["ef"]) if "ef" in comm0 else None,
        staleness=staleness, overlap=is_overlap,
        sync_of=(lambda s: s[0]) if is_overlap else None,
        donated=is_overlap and overlap_donates())
