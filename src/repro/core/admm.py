"""Block-splitting ADMM baseline (Parikh & Boyd 2014) for doubly
distributed data.

The paper compares D3CA/RADiSA against the block-splitting ADMM -- the only
prior doubly distributed optimizer.  We implement the graph-form
consensus/exchange splitting specialized to

    min_w  (1/n) sum_i f_i(x_i . w) + lam ||w||^2

with the data split into the same P x Q block grid.  Introducing partial
predictions s_pq = A_pq w_q, the augmented Lagrangian alternates:

  1. *exchange* (rows; one reduction over the "model" axis):
       v_p   = sum_q (A_pq w_q - u_pq)
       z_p   = prox_{(Q/(rho)) f_p}(v_p)          (elementwise prox of the loss)
       s_pq  = c_pq + (z_p - v_p) / Q
  2. *ridge solve* (columns; one reduction over the "data" axis):
       (2 lam/rho I + sum_p A_pq^T A_pq) w_q = sum_p A_pq^T (s_pq + u_pq)
     The normal matrix is factorized (Cholesky) ONCE at setup and cached,
     exactly as the paper caches the factorization (and, like the paper, the
     factorization time is excluded from benchmark timings).
  3. dual ascent: u_pq += s_pq - A_pq w_q.

All three loss proxes are provided (hinge / squared / logistic-Newton).

ADMM has no stochastic local solver, so the ``local_backend`` knob of the
unified framework is accepted and ignored (its inner solve is the cached
Cholesky back-substitution -- see the support matrix in the README).
Both engines are exposed as ``EngineProgram`` builders like d3ca/radisa.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve
from jax.sharding import PartitionSpec as P

from .engines import (EngineProgram, SparseShardMapData,
                      drive_with_callback)
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_gather, ell_scatter_add)
from .util import pvary, shard_map


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    lam: float = 1e-2
    rho: float = 1e-2      # paper sets rho = lam
    outer_iters: int = 50


# ---------------------------------------------------------------------------
# elementwise proxes of c * f(., y)
# ---------------------------------------------------------------------------

def prox_loss(loss_name: str, v, y, c):
    """prox_{c f(., y)}(v) = argmin_z c f(z, y) + 0.5 (z - v)^2."""
    if loss_name == "hinge":
        yv = y * v
        z = jnp.where(yv >= 1.0, v,
                      jnp.where(yv <= 1.0 - c, v + c * y, y))
        return z
    if loss_name == "squared":
        return (v + 2.0 * c * y) / (1.0 + 2.0 * c)
    if loss_name == "logistic":
        def body(z, _):
            g = z - v - c * y * jax.nn.sigmoid(-y * z)
            gp = 1.0 + c * (y * y) * jax.nn.sigmoid(-y * z) * jax.nn.sigmoid(y * z)
            return z - g / gp, None
        z, _ = jax.lax.scan(body, v, None, length=12)
        return z
    raise ValueError(loss_name)


# ---------------------------------------------------------------------------
# simulated grid engine
# ---------------------------------------------------------------------------

def _sparse_Aw(data: SparseDoublyPartitioned, w_blocks):
    """A_pq w_q for every cell -> (P, Q, n_p), by per-row gathers."""
    def pq(cols_pq, vals_pq, w_q):
        return ell_gather(w_q, cols_pq, vals_pq)
    return jax.vmap(lambda cp, vp: jax.vmap(pq)(cp, vp, w_blocks))(
        data.cols, data.vals)


def _sparse_rhs(data: SparseDoublyPartitioned, b):
    """sum_p A_pq^T b_pq -> (Q, m_q), by per-cell scatter-adds."""
    m_q = data.m_q

    def pq(cols_pq, vals_pq, b_pq):
        return ell_scatter_add(m_q, cols_pq, vals_pq, b_pq)
    per_cell = jax.vmap(lambda cp, vp, bp: jax.vmap(pq)(cp, vp, bp))(
        data.cols, data.vals, b)                          # (P, Q, m_q)
    return per_cell.sum(axis=0)


def admm_setup_simulated(data, cfg: ADMMConfig):
    """Cache the per-column-block Cholesky factors (excluded from timing).

    ``data`` may be dense or sparse; the sparse gram is a scatter-add of
    per-row outer products over the ELL entries (padding slots are
    (0, 0.0) and contribute nothing)."""
    # M_q = (2 lam / rho) I + sum_p A_pq^T A_pq   (m_q x m_q)
    if isinstance(data, SparseDoublyPartitioned):
        m_q = data.m_q

        def pq(cols_pq, vals_pq):
            outer = vals_pq[:, :, None] * vals_pq[:, None, :]
            return jnp.zeros((m_q, m_q)).at[
                cols_pq[:, :, None], cols_pq[:, None, :]].add(outer)
        gram = jax.vmap(lambda cp, vp: jax.vmap(pq)(cp, vp))(
            data.cols, data.vals).sum(axis=0)            # (Q, m_q, m_q)
    else:
        gram = jnp.einsum("pqnm,pqnk->qmk", data.x_blocks, data.x_blocks)
    eye = jnp.eye(data.m_q)
    M = gram + (cfg.lam / cfg.rho) * eye[None]
    return jax.vmap(lambda Mq: cho_factor(Mq)[0])(M)     # (Q, m_q, m_q)


def admm_simulated_program(loss: Loss, data: DoublyPartitioned,
                           cfg: ADMMConfig, *, chol=None,
                           w0=None) -> EngineProgram:
    """vmap-over-cells engine.  State: (s (P,Q,n_p), u (P,Q,n_p),
    w_blocks (Q, m_q)).  The Cholesky setup runs at build time.
    ``data`` may be dense or sparse (padded-ELL cells)."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    loss_name = loss.name
    Pn, Qn = data.P, data.Q
    n = data.n
    if chol is None:
        chol = admm_setup_simulated(data, cfg)
    c_prox = Qn / (cfg.rho * n)   # f_p carries the global 1/n factor

    def matvec(w):
        if sparse:
            return _sparse_Aw(data, w)
        return jnp.einsum("pqnm,qm->pqn", data.x_blocks, w)

    @jax.jit
    def step(t, state):
        s, u, w = state
        Aw = matvec(w)
        cmat = Aw - u                                    # c_pq
        v = cmat.sum(axis=1)                             # (P, n_p)
        z = prox_loss(loss_name, v, data.y_blocks, c_prox)
        z = jnp.where(data.mask[:, :] > 0, z, v)         # padded rows: identity
        s = cmat + ((z - v) / Qn)[:, None, :]
        b = s + u
        if sparse:
            rhs = _sparse_rhs(data, b)
        else:
            rhs = jnp.einsum("pqn,pqnm->qm", b, data.x_blocks)
        w = jax.vmap(lambda Lq, r: cho_solve((Lq, False), r))(chol, rhs)
        u = u + s - matvec(w)
        return s, u, w

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    return EngineProgram(
        state=(jnp.zeros((Pn, Qn, data.n_p)), jnp.zeros((Pn, Qn, data.n_p)),
               w_init),
        step=step,
        w_of=lambda st: data.w_from_blocks(st[2]))


def admm_simulated(loss_name: str, data: DoublyPartitioned, cfg: ADMMConfig,
                   callback=None, chol=None):
    prog = admm_simulated_program(get_loss(loss_name), data, cfg, chol=chol)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ---------------------------------------------------------------------------
# shard_map engine
# ---------------------------------------------------------------------------

def make_admm_step(loss_name: str, mesh, cfg: ADMMConfig, *, n: int,
                   data_axis: str = "data", model_axis: str = "model"):
    """Distributed block-splitting ADMM step.

    Layouts: x (n, m) -> (data, model); y/mask (n,) -> (data,);
    s,u (n, Q) -> (data, model) [one column per feature block];
    w (m,) -> (model,); chol (Q, m_q, m_q) -> (model,) on axis 0.
    """
    Qn = mesh.shape[model_axis]
    c_prox = Qn / (cfg.rho * n)

    def step(x, y, mask, s, u, w, chol):
        def cell(x_b, y_b, mask_b, s_b, u_b, w_b, chol_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            w_b = pvary(w_b, (data_axis,))
            chol_b = pvary(chol_b, (data_axis,))
            s_b, u_b = s_b[:, 0], u_b[:, 0]
            Aw = x_b @ w_b
            cvec = Aw - u_b
            v = jax.lax.psum(cvec, model_axis)
            z = prox_loss(loss_name, v, y_b, c_prox)
            z = jnp.where(mask_b > 0, z, v)
            s_new = cvec + (z - v) / Qn
            b = s_new + u_b
            rhs = jax.lax.psum(b @ x_b, data_axis)
            w_new = cho_solve((chol_b[0], False), rhs)
            u_new = u_b + s_new - x_b @ w_new
            return s_new[:, None], u_new[:, None], w_new

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                      P(data_axis, model_axis), P(data_axis, model_axis),
                      P(model_axis), P(model_axis)),
            out_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                       P(model_axis)),
        )(x, y, mask, s, u, w, chol)

    return jax.jit(step)


def admm_setup_distributed(mesh, x, cfg: ADMMConfig, *,
                           data_axis: str = "data", model_axis: str = "model"):
    """Cached Cholesky factors, computed once with a psum over rows."""
    m_q = x.shape[1] // mesh.shape[model_axis]

    def cell(x_b):
        gram = jax.lax.psum(x_b.T @ x_b, data_axis)
        M = gram + (cfg.lam / cfg.rho) * jnp.eye(m_q, dtype=x_b.dtype)
        return cho_factor(M)[0][None]

    return jax.jit(shard_map(
        cell, mesh,
        in_specs=P(data_axis, model_axis),
        out_specs=P(model_axis),
    ))(x)


def make_admm_step_sparse(loss_name: str, mesh, cfg: ADMMConfig, *, n: int,
                          m_q: int, data_axis: str = "data",
                          model_axis: str = "model"):
    """Sparse-cell variant of :func:`make_admm_step`: the two products
    with the local block become a per-row gather (A_pq w_q) and a
    scatter-add (A_pq^T b)."""
    Qn = mesh.shape[model_axis]
    c_prox = Qn / (cfg.rho * n)

    def step(cols, vals, y, mask, s, u, w, chol):
        def cell(cols_b, vals_b, y_b, mask_b, s_b, u_b, w_b, chol_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            w_b = pvary(w_b, (data_axis,))
            chol_b = pvary(chol_b, (data_axis,))
            s_b, u_b = s_b[:, 0], u_b[:, 0]
            cvec = ell_gather(w_b, cols_b, vals_b) - u_b
            v = jax.lax.psum(cvec, model_axis)
            z = prox_loss(loss_name, v, y_b, c_prox)
            z = jnp.where(mask_b > 0, z, v)
            s_new = cvec + (z - v) / Qn
            b = s_new + u_b
            rhs = jax.lax.psum(ell_scatter_add(m_q, cols_b, vals_b, b),
                               data_axis)
            w_new = cho_solve((chol_b[0], False), rhs)
            u_new = u_b + s_new - ell_gather(w_new, cols_b, vals_b)
            return s_new[:, None], u_new[:, None], w_new

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                      P(data_axis), P(data_axis),
                      P(data_axis, model_axis), P(data_axis, model_axis),
                      P(model_axis), P(model_axis)),
            out_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                       P(model_axis)),
        )(cols, vals, y, mask, s, u, w, chol)

    return jax.jit(step)


def admm_setup_distributed_sparse(mesh, cols, vals, m_q: int,
                                  cfg: ADMMConfig, *,
                                  data_axis: str = "data",
                                  model_axis: str = "model"):
    """Cached Cholesky factors from ELL cells: scatter-add of per-row
    outer products, reduced over observation partitions."""
    def cell(cols_b, vals_b):
        outer = vals_b[:, :, None] * vals_b[:, None, :]
        gram = jax.lax.psum(
            jnp.zeros((m_q, m_q)).at[
                cols_b[:, :, None], cols_b[:, None, :]].add(outer),
            data_axis)
        M = gram + (cfg.lam / cfg.rho) * jnp.eye(m_q, dtype=vals_b.dtype)
        return cho_factor(M)[0][None]

    return jax.jit(shard_map(
        cell, mesh,
        in_specs=(P(data_axis, model_axis), P(data_axis, model_axis)),
        out_specs=P(model_axis),
    ))(cols, vals)


def admm_shard_map_program(loss: Loss, sdata, cfg: ADMMConfig,
                           *, w0=None) -> EngineProgram:
    """shard_map engine.  State: (s (n_pad, Q), u (n_pad, Q), w (m_pad,)).

    The cached Cholesky setup runs at build time (excluded from step
    timings, as in the paper).  ``sdata`` is a :class:`ShardMapData` or
    :class:`SparseShardMapData`."""
    mesh = sdata.mesh
    if isinstance(sdata, SparseShardMapData):
        chol = admm_setup_distributed_sparse(
            mesh, sdata.cols, sdata.vals, sdata.m_q, cfg,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis)
        step = make_admm_step_sparse(loss.name, mesh, cfg, n=sdata.n,
                                     m_q=sdata.m_q,
                                     data_axis=sdata.data_axis,
                                     model_axis=sdata.model_axis)

        def run(t, st):
            return step(sdata.cols, sdata.vals, sdata.y, sdata.mask, *st,
                        chol)
    else:
        chol = admm_setup_distributed(mesh, sdata.x, cfg,
                                      data_axis=sdata.data_axis,
                                      model_axis=sdata.model_axis)
        step = make_admm_step(loss.name, mesh, cfg, n=sdata.n,
                              data_axis=sdata.data_axis,
                              model_axis=sdata.model_axis)

        def run(t, st):
            return step(sdata.x, sdata.y, sdata.mask, *st, chol)
    from jax.sharding import NamedSharding
    su_sharding = NamedSharding(mesh, P(sdata.data_axis, sdata.model_axis))
    zeros_su = jax.device_put(jnp.zeros((sdata.n_pad, sdata.Q)), su_sharding)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    return EngineProgram(
        state=(zeros_su, zeros_su, w_init),
        step=run,
        w_of=lambda st: st[2][: sdata.m])


def admm_distributed(loss_name: str, mesh, x, y, mask, cfg: ADMMConfig,
                     callback=None):
    n, m = x.shape
    Qn = mesh.shape["model"]
    chol = admm_setup_distributed(mesh, x, cfg)
    step = make_admm_step(loss_name, mesh, cfg, n=n)
    prog = EngineProgram(
        state=(jnp.zeros((n, Qn)), jnp.zeros((n, Qn)), jnp.zeros((m,))),
        step=lambda t, st: step(x, y, mask, *st, chol),
        w_of=lambda st: st[2])
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return state[2]
