"""Block-splitting ADMM baseline (Parikh & Boyd 2014) for doubly
distributed data.

The paper compares D3CA/RADiSA against the block-splitting ADMM -- the only
prior doubly distributed optimizer.  We implement the graph-form
consensus/exchange splitting specialized to

    min_w  (1/n) sum_i f_i(x_i . w) + lam ||w||^2

with the data split into the same P x Q block grid.  Introducing partial
predictions s_pq = A_pq w_q, the augmented Lagrangian alternates:

  1. *exchange* (rows; one reduction over the "model" axis):
       v_p   = sum_q (A_pq w_q - u_pq)
       z_p   = prox_{(Q/(rho)) f_p}(v_p)          (elementwise prox of the loss)
       s_pq  = c_pq + (z_p - v_p) / Q
  2. *ridge solve* (columns; one reduction over the "data" axis):
       (2 lam/rho I + sum_p A_pq^T A_pq) w_q = sum_p A_pq^T (s_pq + u_pq)
     The normal matrix is factorized (Cholesky) ONCE at setup and cached,
     exactly as the paper caches the factorization (and, like the paper, the
     factorization time is excluded from benchmark timings).
  3. dual ascent: u_pq += s_pq - A_pq w_q.

All three loss proxes are provided (hinge / squared / logistic-Newton).

ADMM has no stochastic local solver, so the ``local_backend`` knob of the
unified framework is accepted and ignored (its inner solve is the cached
Cholesky back-substitution -- see the support matrix in the README).
Both engines are exposed as ``EngineProgram`` builders like d3ca/radisa.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve
from jax.sharding import PartitionSpec as P

from .engines import EngineProgram, ShardMapData, drive_with_callback
from .losses import Loss, get_loss
from .partition import DoublyPartitioned
from .util import pvary, shard_map


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    lam: float = 1e-2
    rho: float = 1e-2      # paper sets rho = lam
    outer_iters: int = 50


# ---------------------------------------------------------------------------
# elementwise proxes of c * f(., y)
# ---------------------------------------------------------------------------

def prox_loss(loss_name: str, v, y, c):
    """prox_{c f(., y)}(v) = argmin_z c f(z, y) + 0.5 (z - v)^2."""
    if loss_name == "hinge":
        yv = y * v
        z = jnp.where(yv >= 1.0, v,
                      jnp.where(yv <= 1.0 - c, v + c * y, y))
        return z
    if loss_name == "squared":
        return (v + 2.0 * c * y) / (1.0 + 2.0 * c)
    if loss_name == "logistic":
        def body(z, _):
            g = z - v - c * y * jax.nn.sigmoid(-y * z)
            gp = 1.0 + c * (y * y) * jax.nn.sigmoid(-y * z) * jax.nn.sigmoid(y * z)
            return z - g / gp, None
        z, _ = jax.lax.scan(body, v, None, length=12)
        return z
    raise ValueError(loss_name)


# ---------------------------------------------------------------------------
# simulated grid engine
# ---------------------------------------------------------------------------

def admm_setup_simulated(data: DoublyPartitioned, cfg: ADMMConfig):
    """Cache the per-column-block Cholesky factors (excluded from timing)."""
    # M_q = (2 lam / rho) I + sum_p A_pq^T A_pq   (m_q x m_q)
    gram = jnp.einsum("pqnm,pqnk->qmk", data.x_blocks, data.x_blocks)
    eye = jnp.eye(data.m_q)
    M = gram + (cfg.lam / cfg.rho) * eye[None]
    return jax.vmap(lambda Mq: cho_factor(Mq)[0])(M)     # (Q, m_q, m_q)


def admm_simulated_program(loss: Loss, data: DoublyPartitioned,
                           cfg: ADMMConfig, *, chol=None,
                           w0=None) -> EngineProgram:
    """vmap-over-cells engine.  State: (s (P,Q,n_p), u (P,Q,n_p),
    w_blocks (Q, m_q)).  The Cholesky setup runs at build time."""
    loss_name = loss.name
    Pn, Qn = data.P, data.Q
    n = data.n
    if chol is None:
        chol = admm_setup_simulated(data, cfg)
    c_prox = Qn / (cfg.rho * n)   # f_p carries the global 1/n factor

    @jax.jit
    def step(t, state):
        s, u, w = state
        Aw = jnp.einsum("pqnm,qm->pqn", data.x_blocks, w)
        cmat = Aw - u                                    # c_pq
        v = cmat.sum(axis=1)                             # (P, n_p)
        z = prox_loss(loss_name, v, data.y_blocks, c_prox)
        z = jnp.where(data.mask[:, :] > 0, z, v)         # padded rows: identity
        s = cmat + ((z - v) / Qn)[:, None, :]
        b = s + u
        rhs = jnp.einsum("pqn,pqnm->qm", b, data.x_blocks)
        w = jax.vmap(lambda Lq, r: cho_solve((Lq, False), r))(chol, rhs)
        u = u + s - jnp.einsum("pqnm,qm->pqn", data.x_blocks, w)
        return s, u, w

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    return EngineProgram(
        state=(jnp.zeros((Pn, Qn, data.n_p)), jnp.zeros((Pn, Qn, data.n_p)),
               w_init),
        step=step,
        w_of=lambda st: data.w_from_blocks(st[2]))


def admm_simulated(loss_name: str, data: DoublyPartitioned, cfg: ADMMConfig,
                   callback=None, chol=None):
    prog = admm_simulated_program(get_loss(loss_name), data, cfg, chol=chol)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ---------------------------------------------------------------------------
# shard_map engine
# ---------------------------------------------------------------------------

def make_admm_step(loss_name: str, mesh, cfg: ADMMConfig, *, n: int,
                   data_axis: str = "data", model_axis: str = "model"):
    """Distributed block-splitting ADMM step.

    Layouts: x (n, m) -> (data, model); y/mask (n,) -> (data,);
    s,u (n, Q) -> (data, model) [one column per feature block];
    w (m,) -> (model,); chol (Q, m_q, m_q) -> (model,) on axis 0.
    """
    Qn = mesh.shape[model_axis]
    c_prox = Qn / (cfg.rho * n)

    def step(x, y, mask, s, u, w, chol):
        def cell(x_b, y_b, mask_b, s_b, u_b, w_b, chol_b):
            y_b = pvary(y_b, (model_axis,))
            mask_b = pvary(mask_b, (model_axis,))
            w_b = pvary(w_b, (data_axis,))
            chol_b = pvary(chol_b, (data_axis,))
            s_b, u_b = s_b[:, 0], u_b[:, 0]
            Aw = x_b @ w_b
            cvec = Aw - u_b
            v = jax.lax.psum(cvec, model_axis)
            z = prox_loss(loss_name, v, y_b, c_prox)
            z = jnp.where(mask_b > 0, z, v)
            s_new = cvec + (z - v) / Qn
            b = s_new + u_b
            rhs = jax.lax.psum(b @ x_b, data_axis)
            w_new = cho_solve((chol_b[0], False), rhs)
            u_new = u_b + s_new - x_b @ w_new
            return s_new[:, None], u_new[:, None], w_new

        return shard_map(
            cell, mesh,
            in_specs=(P(data_axis, model_axis), P(data_axis), P(data_axis),
                      P(data_axis, model_axis), P(data_axis, model_axis),
                      P(model_axis), P(model_axis)),
            out_specs=(P(data_axis, model_axis), P(data_axis, model_axis),
                       P(model_axis)),
        )(x, y, mask, s, u, w, chol)

    return jax.jit(step)


def admm_setup_distributed(mesh, x, cfg: ADMMConfig, *,
                           data_axis: str = "data", model_axis: str = "model"):
    """Cached Cholesky factors, computed once with a psum over rows."""
    m_q = x.shape[1] // mesh.shape[model_axis]

    def cell(x_b):
        gram = jax.lax.psum(x_b.T @ x_b, data_axis)
        M = gram + (cfg.lam / cfg.rho) * jnp.eye(m_q, dtype=x_b.dtype)
        return cho_factor(M)[0][None]

    return jax.jit(shard_map(
        cell, mesh,
        in_specs=P(data_axis, model_axis),
        out_specs=P(model_axis),
    ))(x)


def admm_shard_map_program(loss: Loss, sdata: ShardMapData, cfg: ADMMConfig,
                           *, w0=None) -> EngineProgram:
    """shard_map engine.  State: (s (n_pad, Q), u (n_pad, Q), w (m_pad,)).

    The cached Cholesky setup runs at build time (excluded from step
    timings, as in the paper)."""
    mesh = sdata.mesh
    chol = admm_setup_distributed(mesh, sdata.x, cfg,
                                  data_axis=sdata.data_axis,
                                  model_axis=sdata.model_axis)
    step = make_admm_step(loss.name, mesh, cfg, n=sdata.n,
                          data_axis=sdata.data_axis,
                          model_axis=sdata.model_axis)
    from jax.sharding import NamedSharding
    su_sharding = NamedSharding(mesh, P(sdata.data_axis, sdata.model_axis))
    zeros_su = jax.device_put(jnp.zeros((sdata.n_pad, sdata.Q)), su_sharding)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    return EngineProgram(
        state=(zeros_su, zeros_su, w_init),
        step=lambda t, st: step(sdata.x, sdata.y, sdata.mask, *st, chol),
        w_of=lambda st: st[2][: sdata.m])


def admm_distributed(loss_name: str, mesh, x, y, mask, cfg: ADMMConfig,
                     callback=None):
    n, m = x.shape
    Qn = mesh.shape["model"]
    chol = admm_setup_distributed(mesh, x, cfg)
    step = make_admm_step(loss_name, mesh, cfg, n=n)
    prog = EngineProgram(
        state=(jnp.zeros((n, Qn)), jnp.zeros((n, Qn)), jnp.zeros((m,))),
        step=lambda t, st: step(x, y, mask, *st, chol),
        w_of=lambda st: st[2])
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return state[2]
