"""Block-splitting ADMM baseline (Parikh & Boyd 2014) for doubly
distributed data.

The paper compares D3CA/RADiSA against the block-splitting ADMM -- the only
prior doubly distributed optimizer.  We implement the graph-form
consensus/exchange splitting specialized to

    min_w  (1/n) sum_i f_i(x_i . w) + lam ||w||^2

with the data split into the same P x Q block grid.  Introducing partial
predictions s_pq = A_pq w_q, the augmented Lagrangian alternates:

  1. *exchange* (rows; one reduction over the "model" axis):
       v_p   = sum_q (A_pq w_q - u_pq)
       z_p   = prox_{(Q/(rho)) f_p}(v_p)          (elementwise prox of the loss)
       s_pq  = c_pq + (z_p - v_p) / Q
  2. *ridge solve* (columns; one reduction over the "data" axis):
       (2 lam/rho I + sum_p A_pq^T A_pq) w_q = sum_p A_pq^T (s_pq + u_pq)
     The normal matrix is factorized (Cholesky) ONCE at setup and cached,
     exactly as the paper caches the factorization (and, like the paper, the
     factorization time is excluded from benchmark timings).
  3. dual ascent: u_pq += s_pq - A_pq w_q.

Since Engine API v2 the per-step math is ONE
:class:`~repro.core.engines.CellProgram` with the two reductions
declared as named collectives::

    CommSchedule().psum("v", axis="model")    # exchange (rows)
                  .psum("rhs", axis="data")   # ridge right-hand side

All three loss proxes are provided (hinge / squared / logistic-Newton).

ADMM has no stochastic local solver, so the ``local_backend`` knob of the
unified framework is accepted and ignored (its inner solve is the cached
Cholesky back-substitution -- see the support matrix in the README).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve
from jax.sharding import PartitionSpec as P

from .comm import CommSchedule
from .engines import (CellProgram, EngineProgram, SparseShardMapData,
                      cached_build, drive_with_callback, grid_bind_state,
                      grid_program, mesh_local_step, mesh_program,
                      mesh_step_fn, overlap_donates)
from .losses import Loss, get_loss
from .partition import (DoublyPartitioned, SparseDoublyPartitioned,
                        ell_gather, ell_scatter_add)
from .util import shard_map


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    lam: float = 1e-2
    rho: float = 1e-2      # paper sets rho = lam
    outer_iters: int = 50


# ---------------------------------------------------------------------------
# elementwise proxes of c * f(., y)
# ---------------------------------------------------------------------------

def prox_loss(loss_name: str, v, y, c):
    """prox_{c f(., y)}(v) = argmin_z c f(z, y) + 0.5 (z - v)^2."""
    if loss_name == "hinge":
        yv = y * v
        z = jnp.where(yv >= 1.0, v,
                      jnp.where(yv <= 1.0 - c, v + c * y, y))
        return z
    if loss_name == "squared":
        return (v + 2.0 * c * y) / (1.0 + 2.0 * c)
    if loss_name == "logistic":
        def body(z, _):
            g = z - v - c * y * jax.nn.sigmoid(-y * z)
            gp = 1.0 + c * (y * y) * jax.nn.sigmoid(-y * z) * jax.nn.sigmoid(y * z)
            return z - g / gp, None
        z, _ = jax.lax.scan(body, v, None, length=12)
        return z
    raise ValueError(loss_name)


def admm_schedule() -> CommSchedule:
    """ADMM's two reduction points (exchange rows, ridge rhs columns)."""
    return (CommSchedule()
            .psum("v", axis="model")
            .psum("rhs", axis="data"))


def admm_cell_program(loss_name: str, cfg: ADMMConfig, *, n: int, m_q: int,
                      sparse: bool = False,
                      per_problem: bool = False) -> CellProgram:
    """The ONE ADMM program every engine executes.

    Per-cell data: ``(x_b[, vals_b], y_b, mask_b, chol_b (1, m_q, m_q))``;
    per-cell state: ``(s_b (n_p, 1), u_b (n_p, 1), w_b (m_q,))``.
    ``per_problem=True`` appends a runtime ``n_v`` scalar to the data
    tuple (the fleet path); per-tenant ``lam`` needs no runtime scalar
    because it only enters through the per-tenant Cholesky factor.
    """

    def cell(comm, t, data, state):
        if per_problem:
            *data, n_t = data
        else:
            n_t = n
        if sparse:
            cols_b, vals_b, y_b, mask_b, chol_b = data
            matvec = lambda w: ell_gather(w, cols_b, vals_b)   # noqa: E731
            colsum = lambda b: ell_scatter_add(m_q, cols_b, vals_b, b)  # noqa: E731
        else:
            x_b, y_b, mask_b, chol_b = data
            matvec = lambda w: x_b @ w                         # noqa: E731
            colsum = lambda b: b @ x_b                         # noqa: E731
        s_b, u_b, w_b = state
        Qn = comm.axis_size("model")
        c_prox = Qn / (cfg.rho * n_t)  # f_p carries the global 1/n factor
        s_b, u_b = s_b[:, 0], u_b[:, 0]
        cvec = matvec(w_b) - u_b
        v = comm("v", cvec)
        z = prox_loss(loss_name, v, y_b, c_prox)
        z = jnp.where(mask_b > 0, z, v)      # padded rows: identity
        s_new = cvec + (z - v) / Qn
        b = s_new + u_b
        rhs = comm("rhs", colsum(b))
        w_new = cho_solve((chol_b[0], False), rhs)
        u_new = u_b + s_new - matvec(w_new)
        return s_new[:, None], u_new[:, None], w_new

    x_specs = ((("data", "model"), ("data", "model")) if sparse
               else (("data", "model"),))
    pp_specs = (((),) if per_problem else ())
    data_specs = x_specs + (("data",), ("data",), ("model",)) + pp_specs
    state_specs = (("data", "model"), ("data", "model"), ("model",))
    return CellProgram(admm_schedule(), cell, data_specs, state_specs)


# ---------------------------------------------------------------------------
# simulated grid engine
# ---------------------------------------------------------------------------

def admm_setup_simulated(data, cfg: ADMMConfig):
    """Cache the per-column-block Cholesky factors (excluded from timing).

    ``data`` may be dense or sparse; the sparse gram is a scatter-add of
    per-row outer products over the ELL entries (padding slots are
    (0, 0.0) and contribute nothing)."""
    # M_q = (2 lam / rho) I + sum_p A_pq^T A_pq   (m_q x m_q)
    if isinstance(data, SparseDoublyPartitioned):
        m_q = data.m_q

        def pq(cols_pq, vals_pq):
            outer = vals_pq[:, :, None] * vals_pq[:, None, :]
            return jnp.zeros((m_q, m_q)).at[
                cols_pq[:, :, None], cols_pq[:, None, :]].add(outer)
        gram = jax.vmap(lambda cp, vp: jax.vmap(pq)(cp, vp))(
            data.cols, data.vals).sum(axis=0)            # (Q, m_q, m_q)
    else:
        gram = jnp.einsum("pqnm,pqnk->qmk", data.x_blocks, data.x_blocks)
    eye = jnp.eye(data.m_q)
    M = gram + (cfg.lam / cfg.rho) * eye[None]
    return jax.vmap(lambda Mq: cho_factor(Mq)[0])(M)     # (Q, m_q, m_q)


def admm_simulated_program(loss: Loss, data: DoublyPartitioned,
                           cfg: ADMMConfig, *, chol=None,
                           w0=None, compression=None,
                           topology=None, cache=None) -> EngineProgram:
    """Named-vmap grid engine.  State: (s (P,Q,n_p,1), u (P,Q,n_p,1),
    w_blocks (Q, m_q)).  The Cholesky setup runs at build time.
    ``data`` may be dense or sparse (padded-ELL cells); ``compression``
    routes the exchange/rhs collectives through their policy codecs."""
    sparse = isinstance(data, SparseDoublyPartitioned)
    Pn, Qn = data.P, data.Q
    if chol is None:
        chol = admm_setup_simulated(data, cfg)
    cellprog = admm_cell_program(loss.name, cfg, n=data.n, m_q=data.m_q,
                                 sparse=sparse)
    x_parts = (data.cols, data.vals) if sparse else (data.x_blocks,)
    # blocked layout: one leading block axis per logical axis of the
    # dim-spec, per-cell extents in place -- chol spec is ("model",)
    gdata = (*x_parts, data.y_blocks, data.mask, chol[:, None])
    step = cached_build(cache, "step",
                        lambda: grid_program(cellprog, Pn, Qn,
                                             compression=compression,
                                             topology=topology))

    w_init = (jnp.zeros((Qn, data.m_q)) if w0 is None
              else data.w_to_blocks(jnp.asarray(w0)))
    zeros_su = jnp.zeros((Pn, Qn, data.n_p, 1))
    state0 = (zeros_su, zeros_su, w_init)
    full0, unwrap, acct = grid_bind_state(cellprog, gdata, state0,
                                          Pn=Pn, Qn=Qn,
                                          compression=compression,
                                          topology=topology)
    local = cached_build(cache, "local",
                         lambda: grid_program(cellprog, Pn, Qn,
                                              comm_local=True))
    wrapped = full0 is not state0
    return EngineProgram(
        state=full0,
        step=lambda t, st: step(t, gdata, st),
        w_of=lambda st: data.w_from_blocks(unwrap(st)[2]),
        comm_bytes=acct,
        local_step=lambda t, st: local(t, gdata, unwrap(st)),
        ef_of=(lambda st: st[1]) if wrapped else None)


def admm_simulated(loss_name: str, data: DoublyPartitioned, cfg: ADMMConfig,
                   callback=None, chol=None):
    prog = admm_simulated_program(get_loss(loss_name), data, cfg, chol=chol)
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return prog.w_of(state)


# ---------------------------------------------------------------------------
# mesh engines (shard_map sync + bounded-staleness async)
# ---------------------------------------------------------------------------

def make_admm_step(loss_name: str, mesh, cfg: ADMMConfig, *, n: int,
                   data_axis: str = "data", model_axis: str = "model"):
    """Distributed block-splitting ADMM step (sync reductions).

    Layouts: x (n, m) -> (data, model); y/mask (n,) -> (data,);
    s,u (n, Q) -> (data, model) [one column per feature block];
    w (m,) -> (model,); chol (Q, m_q, m_q) -> (model,) on axis 0.
    """
    cellprog = admm_cell_program(loss_name, cfg, n=n, m_q=None)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(x, y, mask, s, u, w, chol):
        (s2, u2, w2), _ = run(jnp.int32(0), (x, y, mask, chol),
                              (s, u, w), {})
        return s2, u2, w2

    return jax.jit(step)


def admm_setup_distributed(mesh, x, cfg: ADMMConfig, *,
                           data_axis: str = "data", model_axis: str = "model"):
    """Cached Cholesky factors, computed once with a psum over rows."""
    m_q = x.shape[1] // mesh.shape[model_axis]

    def cell(x_b):
        gram = jax.lax.psum(x_b.T @ x_b, data_axis)
        M = gram + (cfg.lam / cfg.rho) * jnp.eye(m_q, dtype=x_b.dtype)
        return cho_factor(M)[0][None]

    return jax.jit(shard_map(
        cell, mesh,
        in_specs=P(data_axis, model_axis),
        out_specs=P(model_axis),
    ))(x)


def make_admm_step_sparse(loss_name: str, mesh, cfg: ADMMConfig, *, n: int,
                          m_q: int, data_axis: str = "data",
                          model_axis: str = "model"):
    """Sparse-cell variant of :func:`make_admm_step`: the two products
    with the local block become a per-row gather (A_pq w_q) and a
    scatter-add (A_pq^T b)."""
    cellprog = admm_cell_program(loss_name, cfg, n=n, m_q=m_q, sparse=True)
    run = mesh_step_fn(cellprog, mesh, data_axis=data_axis,
                       model_axis=model_axis)

    def step(cols, vals, y, mask, s, u, w, chol):
        (s2, u2, w2), _ = run(jnp.int32(0), (cols, vals, y, mask, chol),
                              (s, u, w), {})
        return s2, u2, w2

    return jax.jit(step)


def admm_setup_distributed_sparse(mesh, cols, vals, m_q: int,
                                  cfg: ADMMConfig, *,
                                  data_axis: str = "data",
                                  model_axis: str = "model"):
    """Cached Cholesky factors from ELL cells: scatter-add of per-row
    outer products, reduced over observation partitions."""
    def cell(cols_b, vals_b):
        outer = vals_b[:, :, None] * vals_b[:, None, :]
        gram = jax.lax.psum(
            jnp.zeros((m_q, m_q)).at[
                cols_b[:, :, None], cols_b[:, None, :]].add(outer),
            data_axis)
        M = gram + (cfg.lam / cfg.rho) * jnp.eye(m_q, dtype=vals_b.dtype)
        return cho_factor(M)[0][None]

    return jax.jit(shard_map(
        cell, mesh,
        in_specs=(P(data_axis, model_axis), P(data_axis, model_axis)),
        out_specs=P(model_axis),
    ))(cols, vals)


def admm_shard_map_program(loss: Loss, sdata, cfg: ADMMConfig,
                           *, w0=None, staleness: int = 0,
                           compression=None, overlap: bool = False,
                           topology=None, cache=None) -> EngineProgram:
    """Mesh engine.  State: ((s (n_pad, Q), u (n_pad, Q), w (m_pad,)),
    comm_state), all sharded.

    The cached Cholesky setup runs at build time (excluded from step
    timings, as in the paper).  ``sdata`` is a :class:`ShardMapData` or
    :class:`SparseShardMapData`; ``staleness=tau > 0`` selects the
    bounded-staleness async policy; ``compression`` routes the
    exchange/rhs collectives through their policy codecs."""
    mesh = sdata.mesh
    sparse = isinstance(sdata, SparseShardMapData)
    if sparse:
        chol = admm_setup_distributed_sparse(
            mesh, sdata.cols, sdata.vals, sdata.m_q, cfg,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis)
        x_parts = (sdata.cols, sdata.vals)
    else:
        chol = admm_setup_distributed(mesh, sdata.x, cfg,
                                      data_axis=sdata.data_axis,
                                      model_axis=sdata.model_axis)
        x_parts = (sdata.x,)
    cellprog = admm_cell_program(loss.name, cfg, n=sdata.n, m_q=sdata.m_q,
                                 sparse=sparse)
    mdata = (*x_parts, sdata.y, sdata.mask, chol)
    from jax.sharding import NamedSharding
    su_sharding = NamedSharding(mesh, P(sdata.data_axis, sdata.model_axis))
    zeros_su = jax.device_put(jnp.zeros((sdata.n_pad, sdata.Q)), su_sharding)
    w_init = sdata.zeros_model() if w0 is None else sdata.pad_w(w0)
    state0 = (zeros_su, zeros_su, w_init)
    step, comm0, acct = cached_build(
        cache, "step",
        lambda: mesh_program(
            cellprog, mesh, mdata, state0,
            data_axis=sdata.data_axis, model_axis=sdata.model_axis,
            staleness=staleness, compression=compression,
            overlap=overlap, topology=topology))
    local = cached_build(
        cache, "local",
        lambda: mesh_local_step(cellprog, mesh,
                                data_axis=sdata.data_axis,
                                model_axis=sdata.model_axis))
    is_overlap = bool(overlap) and staleness > 0
    return EngineProgram(
        state=(state0, comm0),
        step=lambda t, st: step(t, mdata, st),
        w_of=lambda st: st[0][2][: sdata.m],
        comm_bytes=acct,
        local_step=lambda t, st: local(t, mdata, st[0]),
        ef_of=(lambda st: st[1]["ef"]) if "ef" in comm0 else None,
        staleness=staleness, overlap=is_overlap,
        sync_of=(lambda st: st[0]) if is_overlap else None,
        donated=is_overlap and overlap_donates())


def admm_distributed(loss_name: str, mesh, x, y, mask, cfg: ADMMConfig,
                     callback=None):
    n, m = x.shape
    Qn = mesh.shape["model"]
    chol = admm_setup_distributed(mesh, x, cfg)
    step = make_admm_step(loss_name, mesh, cfg, n=n)
    prog = EngineProgram(
        state=(jnp.zeros((n, Qn)), jnp.zeros((n, Qn)), jnp.zeros((m,))),
        step=lambda t, st: step(x, y, mask, *st, chol),
        w_of=lambda st: st[2])
    state = drive_with_callback(prog, cfg.outer_iters, callback)
    return state[2]
