"""The paper's own experiment configurations (§IV).

Part 1: dense synthetic SVM instances with 2,000 x 3,000 blocks at
(P,Q) in {(4,2), (5,3), (7,4)}.  Part 2: strong scaling on realsim/news20
-shaped data; weak scaling with 40,000 x 5,000 blocks.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SVMExperiment:
    name: str
    P: int
    Q: int
    block_n: int
    block_m: int
    lam: float
    loss: str = "hinge"
    sparsity: float = 1.0     # fraction of nonzeros (1.0 = dense)

    @property
    def n(self):
        return self.P * self.block_n

    @property
    def m(self):
        return self.Q * self.block_m


# Paper Table I (scaled down ~1/10 per side for CPU benchmarking; the
# benchmark harness also accepts --full for the paper-sized instances).
PART1 = [
    SVMExperiment("4x2", 4, 2, 2000, 3000, 1e-2),
    SVMExperiment("5x3", 5, 3, 2000, 3000, 1e-2),
    SVMExperiment("7x4", 7, 4, 2000, 3000, 1e-2),
]

# strong scaling partition ladders (paper Fig. 5)
STRONG_CONFIGS = [(1, 1), (2, 1), (1, 2), (4, 1), (2, 2), (1, 4),
                  (8, 1), (4, 2), (2, 4), (1, 8)]

# weak scaling (paper Fig. 6): block 40k x 5k, P in 1..7, Q in {2,3,4}
WEAK_P = list(range(1, 8))
WEAK_Q = [2, 3, 4]
WEAK_SPARSITY = [0.01, 0.05]

# Part 2 real datasets (paper §IV): shapes and densities of the LIBSVM
# files.  ``synthetic_profile`` gives the per-block numbers the fig6
# harness uses to run a paper-scale synthetic stand-in when the real
# file is absent -- at these densities the sparse (padded-ELL) block
# format is mandatory: a dense news20 block grid would need ~100 GB.
REAL_DATASETS = {
    # news20.binary: 19,996 x 1,355,191 at ~0.034% density (~9.1M nnz)
    "news20": {"n": 19996, "m": 1355191, "density": 3.4e-4, "lam": 1e-4},
    # real-sim: 72,309 x 20,958 at ~0.24% density (~3.7M nnz)
    "realsim": {"n": 72309, "m": 20958, "density": 2.4e-3, "lam": 1e-4},
}


def synthetic_profile(name: str, max_p: int, Q: int):
    """Per-block (block_n, block_m, density) for a weak-scaling run whose
    LARGEST grid (P=max_p, given Q) reaches the real dataset's size."""
    d = REAL_DATASETS[name]
    return d["n"] // max_p, d["m"] // Q, d["density"]
