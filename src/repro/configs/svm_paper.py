"""The paper's own experiment configurations (§IV).

Part 1: dense synthetic SVM instances with 2,000 x 3,000 blocks at
(P,Q) in {(4,2), (5,3), (7,4)}.  Part 2: strong scaling on realsim/news20
-shaped data; weak scaling with 40,000 x 5,000 blocks.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SVMExperiment:
    name: str
    P: int
    Q: int
    block_n: int
    block_m: int
    lam: float
    loss: str = "hinge"
    sparsity: float = 1.0     # fraction of nonzeros (1.0 = dense)

    @property
    def n(self):
        return self.P * self.block_n

    @property
    def m(self):
        return self.Q * self.block_m


# Paper Table I (scaled down ~1/10 per side for CPU benchmarking; the
# benchmark harness also accepts --full for the paper-sized instances).
PART1 = [
    SVMExperiment("4x2", 4, 2, 2000, 3000, 1e-2),
    SVMExperiment("5x3", 5, 3, 2000, 3000, 1e-2),
    SVMExperiment("7x4", 7, 4, 2000, 3000, 1e-2),
]

# strong scaling partition ladders (paper Fig. 5)
STRONG_CONFIGS = [(1, 1), (2, 1), (1, 2), (4, 1), (2, 2), (1, 4),
                  (8, 1), (4, 2), (2, 4), (1, 8)]

# weak scaling (paper Fig. 6): block 40k x 5k, P in 1..7, Q in {2,3,4}
WEAK_P = list(range(1, 8))
WEAK_Q = [2, 3, 4]
WEAK_SPARSITY = [0.01, 0.05]
