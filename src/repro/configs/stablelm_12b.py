"""stablelm-12b [dense] (hf:stabilityai/stablelm family).

40L, d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=13824, vocab=100352,
    pattern=(ATTN,),
    notes="head_dim 160; full attention -> long_500k skipped",
)
