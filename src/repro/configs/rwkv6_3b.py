"""rwkv6-3b [ssm]: RWKV-6 Finch, data-dependent decay (arXiv:2404.05892).

32L, d_model 2560, attention-free, d_ff 8960, vocab 65536.
"""
from repro.models.config import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    pattern=(RWKV,), rwkv_head_dim=64,
    notes="attn-free; O(1) decode state -> long_500k RUNS",
)
