"""moonshot-v1-16b-a3b [moe] (hf:moonshotai/Moonlight-16B-A3B).

48L, d_model 2048, 16 heads (GQA kv=16 -- MHA), per-expert d_ff 1408,
vocab 163840, 64 experts top-6.
"""
from repro.models.config import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=163840,
    pattern=(ATTN,),
    moe=MoEConfig(n_experts=64, top_k=6),
    notes="64 experts shard over model axis (EP); full attention -> "
          "long_500k skipped",
)
