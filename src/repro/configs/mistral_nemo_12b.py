"""mistral-nemo-12b [dense] (hf:mistralai/Mistral-Nemo-Base-2407).

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 131072, 128k context (rope theta 1e6).
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14336, vocab=131072,
    head_dim=128, pattern=(ATTN,), rope_theta=1e6,
    notes="explicit head_dim=128 (H*hd != d_model); long_500k skipped",
)
