"""granite-20b [dense]: llama-arch code model (arXiv:2405.04324).

52L, d_model 6144, 48 heads (GQA kv=1 -- MQA), d_ff 24576, vocab 49152.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576, vocab=49152,
    pattern=(ATTN,),
    train_accum=16,   # 52L x d6144: 1 seq/device/microbatch to fit HBM
    notes="MQA (single KV head); full attention -> long_500k skipped",
)
