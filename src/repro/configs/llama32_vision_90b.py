"""llama-3.2-vision-90b [vlm] (hf:meta-llama/Llama-3.2-*-Vision).

100L total, d_model 8192, 64 heads (GQA kv=8), d_ff 28672, vocab 128256;
cross-attention image layers every 5th layer; vision frontend is a stub
(precomputed patch embeddings via input_specs).
"""
from repro.models.config import ATTN, XATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv=8, d_ff=28672, vocab=128256,
    pattern=(ATTN, ATTN, ATTN, ATTN, XATTN), encoder_len=1024,
    train_accum=16,   # 100L x d8192: 1 seq/device/microbatch to fit HBM
    notes="cross-attn every 5th layer; stub encoder 1024 patch tokens; "
          "full attention -> long_500k skipped",
)
