"""qwen3-1.7b [dense]: qk-norm + GQA (hf:Qwen/Qwen3 family).

28L, d_model 2048, 16 heads (GQA kv=8), d_ff 6144, vocab 151936.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144, vocab=151936,
    pattern=(ATTN,), qk_norm=True,
    notes="per-head RMS q/k norm; full attention -> long_500k skipped",
)
