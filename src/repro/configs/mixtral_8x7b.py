"""mixtral-8x7b [moe] (arXiv:2401.04088).

32L, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000,
8 experts top-2, sliding-window attention (4096).
"""
from repro.models.config import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    pattern=(ATTN,), swa_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    notes="SWA window 4096 -> long_500k RUNS (rolling KV cache); "
          "8 experts not divisible by model=16 -> expert d_ff is TP-sharded",
)
