"""musicgen-large [audio] (arXiv:2306.05284).

48L decoder-only over EnCodec tokens; d_model 2048, 32 heads (MHA),
d_ff 8192, vocab 2048.  The EnCodec frontend is a stub: input_specs
provides precomputed frame embeddings.
"""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=2048,
    pattern=(ATTN,), embed_input="embeddings",
    notes="stub EnCodec frontend (frame embeddings in); head predicts "
          "codebook tokens (vocab 2048); full attention -> long_500k skipped",
)
