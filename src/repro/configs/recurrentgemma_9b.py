"""recurrentgemma-9b [hybrid]: RG-LRU + local attention 1:2
(arXiv:2402.19427 Griffin).

38L, d_model 4096, 16 heads (GQA kv=1) for the attention layers,
d_ff 12288, vocab 256000; pattern (rglru, rglru, local-attn).
"""
from repro.models.config import LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
    pattern=(RGLRU, RGLRU, LOCAL), local_window=2048,
    notes="38 = 12 full (r,r,a) periods + 2 remainder rglru layers; "
          "O(window) decode -> long_500k RUNS",
)
