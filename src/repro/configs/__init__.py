"""Architecture registry: one module per assigned arch (+ the paper's own
SVM workloads).  ``get_config(name)`` -> ModelConfig; ``ARCHS`` lists all.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "granite_20b",
    "qwen3_1_7b",
    "stablelm_12b",
    "mistral_nemo_12b",
    "rwkv6_3b",
    "llama32_vision_90b",
    "mixtral_8x7b",
    "moonshot_v1_16b_a3b",
    "musicgen_large",
    "recurrentgemma_9b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "granite-20b": "granite_20b",
    "qwen3-1.7b": "qwen3_1_7b",
    "stablelm-12b": "stablelm_12b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "rwkv6-3b": "rwkv6_3b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "mixtral-8x7b": "mixtral_8x7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "musicgen-large": "musicgen_large",
    "recurrentgemma-9b": "recurrentgemma_9b",
})


def get_config(name: str):
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
