"""Roofline model for TPU v5e-like hardware.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / (links * link_bw)

All three in seconds for ONE step; the dominant term is the bottleneck
and its value is the step-time lower bound.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # bf16 FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    ici_links: int           # usable links per chip (2D torus: 4)


HW_V5E = Hardware("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                  ici_bw=50e9, ici_links=4)


def roofline_terms(flops, bytes_accessed, wire_bytes, hw: Hardware = HW_V5E):
    t_c = flops / hw.peak_flops
    t_m = bytes_accessed / hw.hbm_bw
    t_x = wire_bytes / (hw.ici_bw * hw.ici_links)
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                   key=lambda kv: kv[1])
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dominant[0],
        "bound_s": dominant[1],
    }


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the whole step (6*N*D dense / 6*N_active*D).

    N counts backbone + head parameters actually touched per token; for
    decode steps D = batch (one token per sequence), forward only (2ND).
    """
    dm, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv

    per_layer = 0
    from ..models.config import ATTN, LOCAL, RGLRU, RWKV, XATTN
    n_full, n_rem = cfg.n_periods()
    counts = {}
    for j, k in enumerate(cfg.pattern):
        counts[k] = counts.get(k, 0) + n_full + (1 if j < n_rem else 0)

    attn_params = dm * hd * (H + 2 * KV) + H * hd * dm
    rwkv_params = 6 * dm * dm
    rglru_params = 4 * dm * dm
    mixer_params = (counts.get(ATTN, 0) + counts.get(LOCAL, 0)
                    + counts.get(XATTN, 0)) * attn_params \
        + counts.get(RWKV, 0) * rwkv_params \
        + counts.get(RGLRU, 0) * rglru_params

    if cfg.moe is not None:
        active = cfg.moe.top_k
        mlp_params = L * (3 * dm * cfg.d_ff * active + dm * cfg.moe.n_experts)
    elif RWKV in cfg.pattern:
        mlp_params = L * 2 * dm * cfg.d_ff
    else:
        mlp_params = L * 3 * dm * cfg.d_ff

    n_active = mixer_params + mlp_params + dm * V \
        + (dm * V if cfg.embed_input == "tokens" else 0) * 0  # embed is gather

    tokens = shape.batch * (1 if shape.kind == "decode" else shape.seq)
    mult = 6 if shape.kind == "train" else 2
    flops = mult * n_active * tokens

    # attention score/value FLOPs (the quadratic term, not in 6ND)
    if shape.kind != "decode":
        S = shape.seq
        for k, cnt in counts.items():
            if k == ATTN:
                win = cfg.swa_window or S
                eff = min(win, S)
                pairs = S * eff - (eff * (eff - 1)) // 2 if eff < S else \
                    S * (S + 1) // 2
            elif k == LOCAL:
                eff = min(cfg.local_window, S)
                pairs = S * eff - (eff * (eff - 1)) // 2 if eff < S else \
                    S * (S + 1) // 2
            elif k == XATTN:
                pairs = S * cfg.encoder_len
            else:
                continue
            flops += mult // 2 * 2 * 2 * pairs * H * hd * shape.batch
    return float(flops)
