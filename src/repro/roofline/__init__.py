from .hlo import collective_bytes_from_hlo
from .model import HW_V5E, roofline_terms, model_flops
