"""Parse collective traffic out of (post-optimization) HLO text.

``cost_analysis()`` does not report collective bytes, so we scan the HLO
for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instructions and sum their operand/result sizes.

Per-op "bytes on the wire per participating device" model (ring/bidir
approximations, k -> inf):
    all-reduce(N)          ~ 2 N          (reduce-scatter + all-gather)
    all-gather(out N)      ~ N
    reduce-scatter(in N)   ~ N
    all-to-all(N)          ~ N
    collective-permute(N)  ~ N
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sums wire bytes per collective kind from HLO text (one device's
    program under SPMD: shapes are per-shard)."""
    per_kind_bytes = defaultdict(float)
    per_kind_count = defaultdict(int)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue   # async pair: count the -start only
        b = _type_bytes(type_str)
        per_kind_bytes[kind] += b * _WIRE_FACTOR[kind]
        per_kind_count[kind] += 1
    return {
        "total_bytes": float(sum(per_kind_bytes.values())),
        "by_kind_bytes": dict(per_kind_bytes),
        "by_kind_count": dict(per_kind_count),
    }
