"""Fault-tolerant training loop.

Production behaviors exercised here (and tested in tests/test_runtime.py):
  * periodic async checkpointing (atomic, keep-N);
  * NaN/Inf guard: a bad step triggers rollback to the last checkpoint and
    skips ahead past the offending batch (deterministic pipeline => the
    same data is never retried blindly);
  * preemption: SIGTERM/SIGINT request a synchronous save at the next step
    boundary before exiting (standard TPU-pod eviction contract);
  * straggler surveillance: per-step wall times feed an EMA; steps slower
    than ``straggler_factor`` x EMA are logged with their step index
    (on a real pod this is where you fire the re-shard / hot-spare swap);
  * elastic restart: ``Trainer.restore`` re-shards the checkpoint onto the
    CURRENT mesh (chip count can change between runs).
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep_n: int = 3
    async_ckpt: bool = True
    straggler_factor: float = 2.0
    max_rollbacks: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 make_batch: Callable[[int], Any],
                 params, opt_state, start_step: int = 0):
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.params = params
        self.opt_state = opt_state
        self.step = start_step
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep_n=cfg.keep_n)
        self._preempted = False
        self._rollbacks = 0
        self._ema = None
        self.stragglers: list[int] = []
        self.history: list[Dict[str, float]] = []

    # ---- fault tolerance ----
    def _install_signals(self):
        def handler(signum, frame):
            log.warning("preemption signal %s: will checkpoint and stop",
                        signum)
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass   # not on the main thread (tests)

    def _save(self, sync=False):
        tree = {"params": self.params, "opt": self.opt_state,
                "step": jnp.asarray(self.step, jnp.int32)}
        if self.cfg.async_ckpt and not sync:
            self.ckpt.save_async(self.step, tree)
        else:
            self.ckpt.save(self.step, tree)

    def restore(self, shardings=None):
        like = {"params": self.params, "opt": self.opt_state,
                "step": jnp.asarray(self.step, jnp.int32)}
        step, tree = self.ckpt.restore(like, shardings=shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(tree["step"])
        return step

    def _rollback(self, bad_step: int):
        self._rollbacks += 1
        if self._rollbacks > self.cfg.max_rollbacks:
            raise RuntimeError(
                f"aborting: {self._rollbacks} rollbacks (NaN storm)")
        self.ckpt.wait()
        restored = self.restore()
        # skip past the offending batch: replay from the checkpoint but
        # never feed the bad step's batch again
        log.warning("rolled back to step %d after NaN at step %d; "
                    "bad batch will be skipped", restored, bad_step)
        self.skip_steps = {bad_step}

    # ---- main loop ----
    def run(self, num_steps: int):
        self._install_signals()
        self.skip_steps: set[int] = set()
        end = self.step + num_steps
        while self.step < end and not self._preempted:
            s = self.step
            if s in self.skip_steps:
                self.step += 1
                continue
            batch = self.make_batch(s)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0

            if not np.isfinite(loss):
                log.error("non-finite loss %.3g at step %d", loss, s)
                self._rollback(s)
                continue

            self.params, self.opt_state = params, opt_state
            self.step += 1
            self._track_time(s, dt)
            self.history.append({"step": s, "loss": loss, "time_s": dt,
                                 **{k: float(v) for k, v in metrics.items()
                                    if k != "loss"}})
            if self.step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", self.step, loss,
                         dt * 1e3)
            if self.step % self.cfg.ckpt_every == 0:
                self._save()

        self.ckpt.wait()
        self._save(sync=True)
        return self.history

    def _track_time(self, step: int, dt: float):
        if self._ema is None:
            self._ema = dt
        if dt > self.cfg.straggler_factor * self._ema and step > 2:
            self.stragglers.append(step)
            log.warning("straggler step %d: %.0f ms (ema %.0f ms)",
                        step, dt * 1e3, self._ema * 1e3)
        self._ema = 0.9 * self._ema + 0.1 * dt
