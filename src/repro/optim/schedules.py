"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak, warmup_steps, total_steps, floor=0.1):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * s / max(warmup_steps, 1)
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr


def inverse_sqrt(gamma):
    """The paper's RADiSA step size: eta_t = gamma / (1 + sqrt(t - 1))."""
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        return gamma / (1.0 + jnp.sqrt(jnp.maximum(s - 1.0, 0.0)))
    return lr


def constant(v):
    return lambda step: v
