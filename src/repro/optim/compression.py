"""DEPRECATED: moved to :mod:`repro.core.compress`.

The int8 + error-feedback primitives that lived here are now the
``int8`` codec of the compressed-communication subsystem
(``repro.core.compress``), which plugs into every solver's declared
CommSchedule via ``get_solver(...)(compression="int8")`` and adds
simulated-fp8 / top-k codecs, per-collective policies, and exact
bytes-on-wire accounting.

This shim re-exports the legacy tree-level helpers (numerics unchanged,
bit for bit) and warns on import; it will be removed once nothing
imports it.
"""
from __future__ import annotations

import warnings

from repro.core.compress import (compress, decompress,  # noqa: F401
                                 init_error)

warnings.warn(
    "repro.optim.compression is deprecated; use repro.core.compress "
    "(same init_error/compress/decompress helpers, plus codecs, "
    "per-collective CompressionPolicy and wire accounting)",
    DeprecationWarning, stacklevel=2)
