"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound DP all-reduces).

``compress`` quantizes (g + e) per-tensor to int8 with a float scale;
``decompress`` restores; the residual e is carried to the next step
(error feedback keeps SGD/Adam convergence; tested in
tests/test_compression.py).  In the shard_map data-parallel path the
int8 payload is what crosses the "data"/"pod" axes: psum of int8-dequant
halves the DP collective bytes vs bf16 (4x vs fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _q(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(grads, error):
    """Returns (int8 tree, scale tree, new error tree)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = _q(t)
        deq = q.astype(jnp.float32) * s
        return q, s, t - deq

    out = jax.tree.map(one, grads, error)
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    ss = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    es = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return qs, ss, es


def decompress(qs, ss):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, ss)
