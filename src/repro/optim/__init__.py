from . import compression, radisa_svrg
from .adamw import AdamWConfig, global_norm, init as adamw_init, update as adamw_update
from .schedules import constant, inverse_sqrt, warmup_cosine
