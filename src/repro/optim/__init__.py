from . import radisa_svrg
from .adamw import AdamWConfig, global_norm, init as adamw_init, update as adamw_update
from .schedules import constant, inverse_sqrt, warmup_cosine


def __getattr__(name):
    # `compression` is a deprecation shim over repro.core.compress; load
    # it lazily so `import repro.optim` (AdamW users) stays silent and
    # only actual use of the legacy path triggers its DeprecationWarning
    if name == "compression":
        import importlib
        return importlib.import_module(".compression", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
