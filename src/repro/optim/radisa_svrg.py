"""RADiSA-SVRG generalized to deep networks (beyond-paper feature).

The paper's RADiSA updates a random feature sub-block per worker with
SVRG-corrected stochastic gradients.  For a deep net the natural analogue
is *block-coordinate SVRG over parameter tensors*: every outer round an
anchor (params_tilde, full-batch-ish gradient mu_tilde) is refreshed; each
inner step draws a minibatch, evaluates its gradient at BOTH the current
and the anchor parameters, and applies the variance-reduced direction to a
random subset of parameter blocks (the "sub-block exchange").

Usage (see examples/radisa_svrg_train.py):
    state = init(params)
    state = refresh_anchor(state, params, anchor_grads)
    params, state = step(cfg, params, state, grads_now, grads_anchor, key)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RadisaSVRGConfig:
    lr: float = 1e-2
    block_fraction: float = 0.5   # fraction of tensors updated per step


def init(params):
    return {
        "anchor": jax.tree.map(jnp.copy, params),
        "mu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def refresh_anchor(state, params, anchor_grads):
    return {
        "anchor": jax.tree.map(jnp.copy, params),
        "mu": anchor_grads,
        "count": state["count"],
    }


def step(cfg: RadisaSVRGConfig, params, state, grads_now, grads_anchor, key):
    """One inner RADiSA-SVRG step.

    grads_now: minibatch grad at `params`; grads_anchor: same minibatch at
    `state["anchor"]`.  A per-tensor bernoulli mask plays the role of the
    random sub-block assignment.
    """
    leaves, treedef = jax.tree.flatten(params)
    n = len(leaves)
    keep = jax.random.bernoulli(key, cfg.block_fraction, (n,))

    def upd(i, p, g, ga, mu):
        d = (g.astype(jnp.float32) - ga.astype(jnp.float32)
             + mu.astype(jnp.float32))
        return (p.astype(jnp.float32)
                - jnp.where(keep[i], cfg.lr, 0.0) * d).astype(p.dtype)

    gl = jax.tree.leaves(grads_now)
    gal = jax.tree.leaves(grads_anchor)
    mul = jax.tree.leaves(state["mu"])
    new = [upd(i, p, g, ga, mu)
           for i, (p, g, ga, mu) in enumerate(zip(leaves, gl, gal, mul))]
    new_params = jax.tree.unflatten(treedef, new)
    state = dict(state, count=state["count"] + 1)
    return new_params, state
