"""AdamW with decoupled weight decay and global-norm clipping, from scratch.

Optimizer state shards exactly like the parameters (the spec tree is
reused), giving ZeRO-3 style memory scaling for free under the doubly
distributed rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def init(params):
    def zeros(p):
        return jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    count = state["count"] + 1
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr

    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gn = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, gn
