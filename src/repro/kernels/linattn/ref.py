"""Oracle for the chunked RWKV6 linear-attention kernel: the exact
sequential recurrence (same math as repro.models.rwkv.rwkv_scan, layout
(BH, S, D))."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv_linattn_ref(r, k, v, logw, u, state0=None):
    """r,k,v,logw: (BH, S, D); u: (D,). Returns (out (BH,S,D), state (BH,D,D))."""
    BH, S, D = r.shape
    rt = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    kt = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vt = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    wt = jnp.exp(jnp.moveaxis(logw, 1, 0).astype(jnp.float32))
    uf = u.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((BH, D, D), jnp.float32)

    def step(S_, inp):
        r_, k_, v_, w_ = inp
        kv = k_[:, :, None] * v_[:, None, :]
        o = jnp.einsum("bd,bde->be", r_, S_ + uf[None, :, None] * kv)
        return w_[:, :, None] * S_ + kv, o

    state, out = jax.lax.scan(step, state0, (rt, kt, vt, wt))
    return jnp.moveaxis(out, 0, 1), state
