from .linattn import rwkv_linattn_pallas
from .ops import rwkv_linattn
from .ref import rwkv_linattn_ref
