"""Pallas TPU kernel: chunked RWKV6 linear attention (GLA-style).

The sequential per-token recurrence is reformulated chunkwise: within a
chunk of C tokens all pairwise decay products are evaluated from the
in-chunk cumulative log-decay (a (C, C, D) broadcast whose exponents are
all <= 0, so no clamping and no overflow is possible -- see DESIGN.md for
why this beats the factored-matmul form numerically), and the (D, D)
recurrent state advances once per chunk in VMEM.  Grid = (BH, S/C),
sequential over chunks on TPU.

o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
S_t = diag(w_t) S_{t-1} + k_t^T v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, o_ref, state_ref,
            s_vmem, *, C, D, nc):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_vmem[...] = jnp.zeros_like(s_vmem)

    r = r_ref[0].astype(jnp.float32)          # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = logw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, D) -> broadcast
    S0 = s_vmem[...]                          # (D, D)

    logA = jnp.cumsum(logw, axis=0)           # (C, D): sum_{s<=t} log w_s
    logA_prev = logA - logw                   # sum_{s<=t-1}

    # inter-chunk: o_t += (r_t * exp(logA_prev[t])) @ S0
    r_dec = r * jnp.exp(logA_prev)
    o = jax.lax.dot_general(r_dec, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk (i < t): per-channel decay diff, exponents all <= 0
    diff = logA_prev[:, None, :] - logA[None, :, :]          # (C, C, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(diff), axis=-1)
    att = jnp.where(tri, att, 0.0)                           # (C, C)
    o = o + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # current-token bonus: (r_t . (u * k_t)) v_t
    coeff = jnp.sum(r * u * k, axis=1, keepdims=True)        # (C, 1)
    o = o + coeff * v
    o_ref[0] = o.astype(o_ref.dtype)

    # state update: S = diag(prod w) S0 + sum_i diag(decay_i) k_i^T v_i
    decay_all = jnp.exp(logA[-1])                            # (D,)
    k_dec = k * jnp.exp(logA[-1][None, :] - logA)            # (C, D)
    s_vmem[...] = decay_all[:, None] * S0 + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(c == nc - 1)
    def _flush():
        state_ref[0] = s_vmem[...]


def rwkv_linattn_pallas(r, k, v, logw, u, *, chunk=64, interpret=True):
    """r,k,v,logw: (BH, S, D); u: (D,). Returns (out, final_state)."""
    BH, S, D = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nc = S // C
    kern = functools.partial(_kernel, C=C, D=D, nc=nc)
    out, state = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, C, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D), lambda b, c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u[None, :])
    return out, state
