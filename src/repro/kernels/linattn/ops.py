"""Jitted wrapper for the chunked RWKV6 linear-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from .linattn import rwkv_linattn_pallas
from .ref import rwkv_linattn_ref


@partial(jax.jit, static_argnames=("chunk", "backend"))
def rwkv_linattn(r, k, v, logw, u, *, chunk=64, backend="pallas"):
    if backend == "ref":
        return rwkv_linattn_ref(r, k, v, logw, u)
    return rwkv_linattn_pallas(r, k, v, logw, u, chunk=chunk)
