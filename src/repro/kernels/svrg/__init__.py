from .ops import svrg_inner
from .ref import svrg_inner_ref
from .svrg import svrg_inner_pallas
