from .ops import svrg_inner
from .ref import svrg_inner_ref
from .sparse import svrg_inner_sparse_pallas
from .svrg import svrg_inner_pallas

__all__ = ["svrg_inner", "svrg_inner_ref", "svrg_inner_pallas",
           "svrg_inner_sparse_pallas"]
