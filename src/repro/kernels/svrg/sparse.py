"""Pallas TPU kernel: RADiSA inner loop on a padded-ELL sparse block.

Sparse sibling of ``svrg.svrg_inner_pallas``.  The gathered row is the
(1, k) ELL row of the FULL feature block; the assigned sub-block window
``[lo, lo + m_sub)`` is selected inside the kernel by masking the
entries whose block-local column falls in the window.  ``lo`` changes
with the per-iteration sub-block permutation, so it is a runtime
scalar-prefetch input (alongside the minibatch order and eta_t).

The SVRG direction has a dense part (mu + lam * (w - w_anchor), both
VMEM-resident (1, m_sub) blocks) and a sparse part -- the loss-gradient
difference times the row -- applied with a scatter-ADD at the in-window
entries.  ELL padding (col=0, val=0) masks/adds to nothing, exactly as
in the sparse SDCA kernel.  Gather/scatter are exact in interpret mode
(CPU CI); real-TPU lowering rides the ROADMAP kernel-validation
follow-up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _grad(loss, z, y):
    if loss == "hinge":
        return jnp.where(y * z < 1.0, -y, 0.0)
    if loss == "squared":
        return 2.0 * (z - y)
    raise ValueError(loss)


def _kernel(idx_ref,            # scalar prefetch: (L,) int32
            lo_ref,             # scalar prefetch: (1,) int32 window start
            params_ref,         # scalar prefetch: (2,) f32 [eta, lam]
            cols_row_ref,       # (1, k) gathered ELL column ids
            vals_row_ref,       # (1, k) gathered ELL values
            y_row_ref,          # (1, 1)
            mask_row_ref,       # (1, 1)
            z_row_ref,          # (1, 1) anchor inner product
            w_anchor_ref,       # (1, m_sub)
            mu_ref,             # (1, m_sub)
            w_out_ref,          # out: (1, m_sub)
            w_vmem,             # scratch: (1, m_sub) f32
            *, lam, L, m_sub, loss, runtime):
    h = pl.program_id(0)

    @pl.when(h == 0)
    def _init():
        w_vmem[...] = w_anchor_ref[...].astype(jnp.float32)

    ci = cols_row_ref[0, :]
    vi = vals_row_ref[0, :].astype(jnp.float32)
    yj = y_row_ref[0, 0].astype(jnp.float32)
    mj = mask_row_ref[0, 0].astype(jnp.float32)
    zj = z_row_ref[0, 0].astype(jnp.float32)
    wa = w_anchor_ref[0, :].astype(jnp.float32)
    mu = mu_ref[0, :].astype(jnp.float32)
    # runtime mode (fleet): traced lam from the prefetch params;
    # static mode bakes the Python constant (kernel unchanged)
    lam_v = params_ref[1] if runtime else lam

    rel = ci - lo_ref[0]
    sel = ((rel >= 0) & (rel < m_sub)).astype(jnp.float32)
    relc = jnp.clip(rel, 0, m_sub - 1)

    w = w_vmem[0, :]
    diff = w - wa
    corr = jnp.sum(vi * sel * jnp.take(diff, relc, axis=0))
    z = zj + corr
    gscale = (_grad(loss, z, yj) - _grad(loss, zj, yj)) * mj
    g_sparse = jnp.zeros((m_sub,), jnp.float32).at[relc].add(
        gscale * vi * sel)
    w_vmem[0, :] = w - params_ref[0] * (g_sparse + mu + lam_v * diff)

    @pl.when(h == L - 1)
    def _flush():
        w_out_ref[...] = w_vmem[...]


def svrg_inner_sparse_pallas(cols, vals, y, mask, z_anchor, w_anchor, mu_sub,
                             idx, *, lam, eta, lo=0, loss: str = "hinge",
                             interpret: bool = True):
    """Sparse-cell kernel version of the RADiSA inner loop.

    cols/vals: (n_p, k) padded-ELL FULL feature block (block-local column
    ids); w_anchor/mu_sub: (m_sub,) sub-block windows; ``lo`` (runtime
    scalar, may be traced) is the window start within the block.
    Returns the updated (m_sub,) sub-block iterate.
    """
    from repro.kernels.sdca.sdca import _static_scalar
    n_p, k = cols.shape
    m_sub = w_anchor.shape[0]
    L = idx.shape[0]
    lo_arr = jnp.reshape(jnp.asarray(lo, jnp.int32), (1,))
    runtime = not _static_scalar(lam)
    params = jnp.stack([jnp.asarray(eta, jnp.float32),
                        jnp.asarray(lam, jnp.float32)])
    kern = functools.partial(_kernel, lam=None if runtime else float(lam),
                             L=L, m_sub=m_sub, loss=loss, runtime=runtime)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, k), lambda h, idx_ref, lo_, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, k), lambda h, idx_ref, lo_, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, lo_, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, lo_, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, lo_, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, m_sub), lambda h, idx_ref, lo_, e: (0, 0)),
            pl.BlockSpec((1, m_sub), lambda h, idx_ref, lo_, e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_sub),
                               lambda h, idx_ref, lo_, e: (0, 0)),
        scratch_shapes=[pltpu.VMEM((1, m_sub), jnp.float32)],
    )
    w = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, m_sub), jnp.float32),
        interpret=interpret,
    )(idx, lo_arr, params, cols, vals, y[:, None], mask[:, None],
      z_anchor[:, None], w_anchor[None, :], mu_sub[None, :])
    return w[0]
