"""Jitted public wrapper for the SVRG inner-loop kernel."""
from __future__ import annotations

from functools import partial

import jax

from .. import default_interpret
from .ref import svrg_inner_ref
from .svrg import svrg_inner_pallas


@partial(jax.jit, static_argnames=("lam", "loss", "backend", "interpret"))
def svrg_inner(x_sub, y, mask, z_anchor, w_anchor, mu_sub, idx, *,
               lam, eta, loss="hinge", backend="pallas", interpret=None):
    """RADiSA inner loop; ``eta`` is a runtime scalar (it varies per outer
    iteration), not a compile-time constant."""
    if backend == "ref":
        return svrg_inner_ref(x_sub, y, mask, z_anchor, w_anchor, mu_sub,
                              idx, lam=lam, eta=eta, loss=loss)
    if interpret is None:
        interpret = default_interpret()
    return svrg_inner_pallas(x_sub, y, mask, z_anchor, w_anchor, mu_sub,
                             idx, lam=lam, eta=eta, loss=loss,
                             interpret=interpret)
