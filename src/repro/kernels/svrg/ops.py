"""Jitted public wrapper for the SVRG inner-loop kernel."""
from __future__ import annotations

from functools import partial

import jax

from .ref import svrg_inner_ref
from .svrg import svrg_inner_pallas


@partial(jax.jit, static_argnames=("lam", "eta", "loss", "backend"))
def svrg_inner(x_sub, y, mask, z_anchor, w_anchor, mu_sub, idx, *,
               lam, eta, loss="hinge", backend="pallas"):
    if backend == "ref":
        return svrg_inner_ref(x_sub, y, mask, z_anchor, w_anchor, mu_sub,
                              idx, lam=lam, eta=eta, loss=loss)
    return svrg_inner_pallas(x_sub, y, mask, z_anchor, w_anchor, mu_sub,
                             idx, lam=lam, eta=eta, loss=loss)
