"""Pallas TPU kernel: RADiSA inner loop (Algorithm 3 steps 7-10).

Same TPU scheme as the SDCA kernel: sequential step grid, scalar-prefetched
minibatch order driving the row gather (pipelined DMA), sub-block iterate w
and the anchor quantities resident in VMEM for all L steps.  The step size
eta_t = gamma / (1 + sqrt(t-1)) changes every outer iteration, so it is a
runtime scalar-prefetch input rather than a compile-time constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _grad(loss, z, y):
    if loss == "hinge":
        return jnp.where(y * z < 1.0, -y, 0.0)
    if loss == "squared":
        return 2.0 * (z - y)
    raise ValueError(loss)


def _kernel(idx_ref, params_ref, x_row_ref, y_row_ref, mask_row_ref,
            z_row_ref, w_anchor_ref, mu_ref, w_out_ref, w_vmem,
            *, lam, L, loss, runtime):
    h = pl.program_id(0)

    @pl.when(h == 0)
    def _init():
        w_vmem[...] = w_anchor_ref[...].astype(jnp.float32)

    xj = x_row_ref[0, :].astype(jnp.float32)
    yj = y_row_ref[0, 0].astype(jnp.float32)
    mj = mask_row_ref[0, 0].astype(jnp.float32)
    zj = z_row_ref[0, 0].astype(jnp.float32)
    wa = w_anchor_ref[0, :].astype(jnp.float32)
    mu = mu_ref[0, :].astype(jnp.float32)
    # runtime mode (fleet): traced lam from the prefetch params;
    # static mode bakes the Python constant (kernel unchanged)
    lam_v = params_ref[1] if runtime else lam

    w = w_vmem[0, :]
    z = zj + jnp.sum(xj * (w - wa))
    g = (_grad(loss, z, yj) - _grad(loss, zj, yj)) * xj * mj \
        + mu + lam_v * (w - wa)
    w_vmem[0, :] = w - params_ref[0] * g

    @pl.when(h == L - 1)
    def _flush():
        w_out_ref[...] = w_vmem[...]


def svrg_inner_pallas(x_sub, y, mask, z_anchor, w_anchor, mu_sub, idx, *,
                      lam, eta, loss: str = "hinge", interpret: bool = True):
    from repro.kernels.sdca.sdca import _static_scalar
    n_p, m_sub = x_sub.shape
    L = idx.shape[0]
    runtime = not _static_scalar(lam)
    params = jnp.stack([jnp.asarray(eta, jnp.float32),
                        jnp.asarray(lam, jnp.float32)])
    kern = functools.partial(_kernel, lam=None if runtime else float(lam),
                             L=L, loss=loss, runtime=runtime)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, m_sub), lambda h, idx_ref, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, e: (idx_ref[h], 0)),
            pl.BlockSpec((1, m_sub), lambda h, idx_ref, e: (0, 0)),
            pl.BlockSpec((1, m_sub), lambda h, idx_ref, e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_sub), lambda h, idx_ref, e: (0, 0)),
        scratch_shapes=[pltpu.VMEM((1, m_sub), jnp.float32)],
    )
    w = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, m_sub), jnp.float32),
        interpret=interpret,
    )(idx, params, x_sub, y[:, None], mask[:, None], z_anchor[:, None],
      w_anchor[None, :], mu_sub[None, :])
    return w[0]
