"""Pure-jnp oracle for the RADiSA SVRG inner-loop kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _grad(loss, z, y):
    if loss == "hinge":
        return jnp.where(y * z < 1.0, -y, 0.0)
    if loss == "squared":
        return 2.0 * (z - y)
    raise ValueError(loss)


def svrg_inner_ref(x_sub, y, mask, z_anchor, w_anchor, mu_sub, idx, *,
                   lam, eta, loss: str = "hinge"):
    """x_sub: (n_p, m_sub); idx: (L,) minibatch order. Returns w (m_sub,)."""
    x_sub = x_sub.astype(jnp.float32)

    def body(w, j):
        xj = x_sub[j]
        z = z_anchor[j] + xj @ (w - w_anchor)
        g = (_grad(loss, z, y[j]) - _grad(loss, z_anchor[j], y[j])) \
            * xj * mask[j] + mu_sub + lam * (w - w_anchor)
        return w - eta * g, None

    w, _ = jax.lax.scan(body, w_anchor.astype(jnp.float32), idx)
    return w
