"""Pallas TPU kernels for the perf-critical hot spots.

  sdca/    local dual coordinate ascent epoch (paper Algorithm 2)
  svrg/    RADiSA inner loop (paper Algorithm 3 steps 7-10)
  flash/   blockwise causal/windowed attention (LM stack)
  linattn/ chunked RWKV6 data-dependent-decay linear attention

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle).  Validated in interpret mode on CPU;
on TPU pass interpret=False.
"""
import jax as _jax


def default_interpret() -> bool:
    """Interpret mode everywhere but real TPUs (where kernels compile).
    The single source of truth for the ref/pallas dispatch sites."""
    return _jax.default_backend() != "tpu"
