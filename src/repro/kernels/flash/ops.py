"""Jitted GQA-aware wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash import flash_attention_pallas
from .ref import mha_ref


@partial(jax.jit, static_argnames=("causal", "window", "backend",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None, backend="pallas",
                    block_q=512, block_k=512):
    """q: (B,S,H,D); k,v: (B,Skv,KV,D). Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    # expand kv to H heads, flatten (B, H)
    k = jnp.repeat(k, G, axis=2) if G > 1 else k
    v = jnp.repeat(v, G, axis=2) if G > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    if backend == "ref":
        of = mha_ref(qf, kf, vf, causal=causal, window=window)
    else:
        of = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k)
    return of.reshape(B, H, S, D).transpose(0, 2, 1, 3)
